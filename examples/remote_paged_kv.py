"""Multi-node paged serving, step one: KV frames paged in OVER THE FABRIC.

A ``PagedKVManager`` whose frame pool is a ``RemoteFramePool``: when a
preempted sequence is re-activated, its spilled KV pages fault back in
as verbs ``post_read``s against the remote node's memory — destination
faults at the FAULTING landing buffer are resolved by the thesis
mechanism (fault FIFO → tasklet → resolver → RAPF retransmit) and every
page-in completes on a real CompletionQueue.

    PYTHONPATH=src python examples/remote_paged_kv.py
"""

from repro.api import FaultPolicy, Strategy
from repro.memory.kv_cache import PagedKVManager
from repro.vmem import FrameIdPool, RemoteFramePool

for strategy in (Strategy.TOUCH_A_PAGE, Strategy.TOUCH_AHEAD):
    pool = RemoteFramePool.build(n_frames=8, page_elems=0, n_pages=16,
                                 local=FrameIdPool(8))
    kv = PagedKVManager(n_frames=8, page_tokens=4, max_pages_per_seq=8,
                        policy=FaultPolicy(strategy, lookahead=4),
                        pool=pool)
    kv.add_sequence(1)
    kv.append_tokens(1, 32)                        # seq 1 fills the pool
    kv.add_sequence(2)
    kv.append_tokens(2, 16, spill_candidates=[1])  # admission spills seq 1
    n = kv.ensure_resident(1, spill_candidates=[2])
    s = kv.stats
    wcs = pool.cq.poll(max_entries=64) + pool.completions
    print(f"{strategy.value:14s}: {n} KV pages faulted back in over the "
          f"fabric in {s.remote_reads} verbs read(s)")
    print(f"  {'':14s}  completions on CQ: "
          f"{[f'{wc.nbytes}B @ {wc.latency_us:.1f}us' for wc in wcs]}")
    print(f"  {'':14s}  dst_faults={s.remote_dst_faults} "
          f"rapf_retransmits={s.rapf_retransmits} "
          f"simulated fault time={s.simulated_us:.1f}us")

print("\nTouch-Ahead fetches a spilled sequence's block in ONE remote read")
print("(one fault + one RAPF on the cold landing page); Touch-A-Page pays")
print("a read per page — the thesis' contrast, now on the KV spill path.")
