"""End-to-end training driver (deliverable b): trains a ~100M-parameter
qwen3-family model for a few hundred steps on CPU with:

  * microbatched gradient accumulation + per-layer remat,
  * checkpoint/restart (kill it mid-run and start again: it resumes),
  * host-paged optimizer state streamed block-wise with Touch-Ahead
    prefetch — the thesis' mechanism applied to training memory.

    PYTHONPATH=src python examples/train_demand_paged.py [--steps 300]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.distributed.checkpoint import Checkpointer
from repro.memory.offload import PagedAdamW
from repro.models.config import reduced
from repro.models.registry import model_for
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import cosine_with_warmup
from repro.training.trainer import TrainConfig, make_loss_fn

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_demo")
args = ap.parse_args()

# ~100M params: qwen3 family, reduced depth/width
cfg = reduced(get_config("qwen3_14b"), n_layers=6, d_model=512, head_dim=64,
              n_heads=8, n_kv_heads=4, d_ff=1536, vocab_size=32768,
              dtype="float32")
model = model_for(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))
n = sum(x.size for x in jax.tree_util.tree_leaves(params))
print(f"model: {cfg.name}-reduced, {n/1e6:.1f}M params")

opt_cfg = AdamWConfig(lr=3e-4, schedule=cosine_with_warmup(3e-4, 30,
                                                           args.steps))
paged_opt = PagedAdamW(opt_cfg, params, block_elems=1 << 21,
                       )
print(f"optimizer moments: host-paged, device working set "
      f"{paged_opt.device_bytes_resident()/2**20:.0f} MiB "
      f"(vs {2*n*4/2**20:.0f} MiB fully resident)")

tcfg = TrainConfig(microbatches=2, remat=True,
                   optimizer=opt_cfg)
loss_fn = jax.jit(jax.value_and_grad(make_loss_fn(cfg, tcfg)))
ds = SyntheticLM(cfg.vocab_size, seq_len=64, batch_per_shard=8)
ck = Checkpointer()

step0 = 0
restored = ck.restore_latest(args.checkpoint_dir, params)
if restored is not None:
    params, _, step0 = restored
    print(f"resumed from checkpoint at step {step0}")

t0 = time.perf_counter()
for step in range(step0, args.steps):
    tokens, labels = ds.batch_at(step)
    loss, grads = loss_fn(params, tokens, labels)
    params = paged_opt.update(params, grads)
    if (step + 1) % 25 == 0:
        dt = (time.perf_counter() - t0) / 25
        print(f"step {step+1:4d}  loss {float(loss):.4f}  {dt:.2f}s/step  "
              f"opt-blocks streamed {paged_opt.stats.blocks_streamed} "
              f"(prefetch overlap {paged_opt.stats.prefetch_overlapped})")
        t0 = time.perf_counter()
    if (step + 1) % 100 == 0:
        ck.save(args.checkpoint_dir, params, None, step + 1)
        print(f"  checkpoint @ {step+1}")
print("done.")
