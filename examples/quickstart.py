"""Quickstart: the paper's mechanism in six steps.

    PYTHONPATH=src python examples/quickstart.py

1. Build a two-node virtual-address RDMA fabric.
2. mmap buffers WITHOUT pinning (demand paging on).
3. Issue a remote write whose destination pages are not resident.
4. Watch the mechanism: NACK -> fault FIFO -> driver tasklet ->
   Touch-Ahead page-in -> RAPF -> retransmission -> completion.
5. Compare against the pinning baseline.
6. Same idea on the ML data plane: a paged KV pool with a spilled page.
"""

import numpy as np

from repro.core import BufferPrep, RDMAEngine, Strategy
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.memory.kv_cache import PagedKVManager

SRC, DST, SIZE, PD = 0x10_0000_0000, 0x20_0000_0000, 65536, 1

print("== 1-4: remote write with destination faults (Touch-Ahead) ==")
eng = RDMAEngine(n_nodes=2, strategy=Strategy.TOUCH_AHEAD)
eng.map_buffer(0, PD, SRC, SIZE, prep=BufferPrep.TOUCHED)
eng.map_buffer(1, PD, DST, SIZE, prep=BufferPrep.FAULTING)   # not pinned!
t = eng.remote_write(PD, 0, SRC, 1, DST, SIZE)
st = eng.run_transfer(t)
print(f"  64KB write completed in {st.latency_us:.1f} us")
print(f"  faults at dst: {st.dst_faults}, FIFO entries handled: "
      f"{st.fifo_entries_handled} (skipped dups: {st.fifo_entries_skipped})")
print(f"  explicit RAPF retransmissions: {st.rapf_retransmits}, "
      f"timeouts: {st.timeouts}")
print(f"  driver time {st.driver_us:.1f} us, library-thread time "
      f"{st.user_us:.1f} us")

print("\n== 5: the pinning alternative ==")
eng2 = RDMAEngine(n_nodes=2)
c1 = eng2.map_buffer(0, PD, SRC, SIZE, prep=BufferPrep.PINNED)
c2 = eng2.map_buffer(1, PD, DST, SIZE, prep=BufferPrep.PINNED)
t2 = eng2.remote_write(PD, 0, SRC, 1, DST, SIZE)
st2 = eng2.run_transfer(t2)
print(f"  pinned transfer: {st2.latency_us:.1f} us + pin/unpin overhead "
      f"{c1.total_us + c2.total_us:.1f} us on the critical path")
print(f"  (and the memory stays locked — the thesis' utilization argument)")

print("\n== 6: the same mechanism on a paged KV cache ==")
kv = PagedKVManager(n_frames=8, page_tokens=256, max_pages_per_seq=8,
                    strategy=Strategy.TOUCH_AHEAD)
kv.add_sequence(1)
kv.append_tokens(1, 2048)          # fills the pool
kv.add_sequence(2)
kv.append_tokens(2, 512, spill_candidates=[1])   # seq 1 pages spill
print(f"  pool spills while admitting seq 2: {kv.stats.spills}")
n = kv.ensure_resident(1, spill_candidates=[2])  # seq 1 scheduled again
print(f"  re-activating seq 1 faulted {n} pages back in "
      f"({kv.stats.fault_events} fault events — Touch-Ahead blocks)")
print(f"  simulated fault-handling time: {kv.stats.simulated_us:.1f} us")
