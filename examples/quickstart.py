"""Quickstart: the paper's mechanism through the verbs API, in seven steps.

    PYTHONPATH=src python examples/quickstart.py

1. Build a two-node virtual-address RDMA fabric (``Fabric.build``).
2. Open a protection domain (PDID) with a fault policy; register memory
   WITHOUT pinning (demand paging on).
3. Post an asynchronous remote write whose destination pages are not
   resident — ``post_write`` returns a WorkRequest future immediately.
4. Watch the mechanism: NACK -> fault FIFO -> driver tasklet ->
   Touch-Ahead page-in -> RAPF -> retransmission -> completion on the CQ.
5. Compare against the pinning baseline.
6. Multi-tenancy: a second domain on the SAME fabric resolving its faults
   with a different policy (Kernel-RAPF — no user-space hop).
7. Same idea on the ML data plane: a paged KV pool with a spilled page.
"""

from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy)
from repro.memory.kv_cache import PagedKVManager

SRC, DST, SIZE = 0x10_0000_0000, 0x20_0000_0000, 65536

print("== 1-4: async remote write with destination faults (Touch-Ahead) ==")
fabric = Fabric.build(FabricConfig(n_nodes=2))
tenant = fabric.open_domain(1, policy=FaultPolicy(Strategy.TOUCH_AHEAD))
src = tenant.register_memory(0, SRC, SIZE, prep=BufferPrep.TOUCHED)
dst = tenant.register_memory(1, DST, SIZE)                   # not pinned!
cq = fabric.create_cq(depth=16)
wr = tenant.post_write(src, dst, cq=cq)       # returns before completion
print(f"  posted wr_id={wr.wr_id}; done yet? {wr.done}")
(wc,) = cq.wait(1)
st = wc.stats
print(f"  64KB write completed in {wc.latency_us:.1f} us")
print(f"  faults at dst: {st.dst_faults}, FIFO entries handled: "
      f"{st.fifo_entries_handled} (skipped dups: {st.fifo_entries_skipped})")
print(f"  explicit RAPF retransmissions: {st.rapf_retransmits}, "
      f"timeouts: {st.timeouts}")
print(f"  driver time {st.driver_us:.1f} us, library-thread time "
      f"{st.user_us:.1f} us")

print("\n== 5: the pinning alternative ==")
fabric2 = Fabric.build(FabricConfig(n_nodes=2))
dom2 = fabric2.open_domain(1)
p_src = dom2.register_memory(0, SRC, SIZE, prep=BufferPrep.PINNED)
p_dst = dom2.register_memory(1, DST, SIZE, prep=BufferPrep.PINNED)
cq2 = fabric2.create_cq()
dom2.post_write(p_src, p_dst, cq=cq2)
(wc2,) = cq2.wait(1)
print(f"  pinned transfer: {wc2.latency_us:.1f} us + pin/unpin overhead "
      f"{p_src.prep_cost.total_us + p_dst.prep_cost.total_us:.1f} us "
      f"on the critical path")
print("  (and the memory stays locked — the thesis' utilization argument)")

print("\n== 6: second tenant, same fabric, different fault policy ==")
tenant_b = fabric.open_domain(2, policy=FaultPolicy(Strategy.KERNEL_RAPF))
src_b = tenant_b.register_memory(0, SRC, SIZE, prep=BufferPrep.TOUCHED)
dst_b = tenant_b.register_memory(1, 0x30_0000_0000, SIZE)
wr_b = tenant_b.post_write(src_b, dst_b, cq=cq)
wc_b = wr_b.result()
print(f"  tenant A (TOUCH_AHEAD):  user-thread time {st.user_us:.1f} us")
print(f"  tenant B (KERNEL_RAPF):  user-thread time "
      f"{wc_b.stats.user_us:.1f} us (RAPF sent from kernel space)")

print("\n== 7: the same mechanism on a paged KV cache ==")
kv = PagedKVManager(n_frames=8, page_tokens=256, max_pages_per_seq=8,
                    policy=FaultPolicy(Strategy.TOUCH_AHEAD))
kv.add_sequence(1)
kv.append_tokens(1, 2048)          # fills the pool
kv.add_sequence(2)
kv.append_tokens(2, 512, spill_candidates=[1])   # seq 1 pages spill
print(f"  pool spills while admitting seq 2: {kv.stats.spills}")
n = kv.ensure_resident(1, spill_candidates=[2])  # seq 1 scheduled again
print(f"  re-activating seq 1 faulted {n} pages back in "
      f"({kv.stats.fault_events} fault events — Touch-Ahead blocks)")
print(f"  simulated fault-handling time: {kv.stats.simulated_us:.1f} us")
