"""Deep-dive demo: every fault scenario of Chapter 4, side by side.

    PYTHONPATH=src python examples/rdma_fault_demo.py
"""

from repro.api import BufferPrep
from repro.core.experiments import run_remote_write
from repro.core.resolver import Strategy

CASES = [
    ("no faults (pre-touched)", BufferPrep.TOUCHED, BufferPrep.TOUCHED),
    ("fault at destination", BufferPrep.TOUCHED, BufferPrep.FAULTING),
    ("fault at source", BufferPrep.FAULTING, BufferPrep.TOUCHED),
    ("faults at both", BufferPrep.FAULTING, BufferPrep.FAULTING),
]

print(f"{'scenario':28s} {'strategy':14s} {'16KB':>10s} {'64KB':>10s} "
      f"{'timeouts':>9s} {'RAPFs':>6s}")
for name, sp, dp in CASES:
    for strat in (Strategy.TOUCH_A_PAGE, Strategy.TOUCH_AHEAD):
        r16 = run_remote_write(16384, sp, dp, strategy=strat)
        r64 = run_remote_write(65536, sp, dp, strategy=strat)
        print(f"{name:28s} {strat.value:14s} {r16.latency_us:9.1f}us "
              f"{r64.latency_us:9.1f}us {r64.stats.timeouts:9d} "
              f"{r64.stats.rapf_retransmits:6d}")

print("\nKey effects (cf. thesis Figs 4.2-4.6):")
print(" * dst faults recover via explicit RAPF — microseconds;")
print(" * src faults wait for the 1ms timeout — Touch-A-Page pays it per")
print("   page, Touch-Ahead per 16KB block (the ~3.9x);")
print(" * faults on both sides let dst NACKs stand in for src timeouts.")
