"""End-to-end serving driver: batched requests against a paged KV cache
with an UNDERSIZED frame pool, so admission forces spills and
re-activation faults pages back in Touch-Ahead style.

    PYTHONPATH=src python examples/serve_paged_kv.py
"""

import jax
import numpy as np

from repro.api import FaultPolicy, Strategy
from repro.configs import get_config
from repro.models.config import reduced
from repro.models.registry import model_for
from repro.serving.engine import ServingEngine
from repro.serving.sampler import SamplerConfig

cfg = reduced(get_config("h2o_danube_1_8b"), n_layers=3)
model = model_for(cfg)
params = model.init_params(cfg, jax.random.PRNGKey(0))

for strategy in (Strategy.TOUCH_A_PAGE, Strategy.TOUCH_AHEAD):
    eng = ServingEngine(cfg, params, max_batch=2, max_len=96,
                        pool_frames=5,           # undersized on purpose
                        policy=FaultPolicy(strategy=strategy),
                        sampler=SamplerConfig(temperature=0.0))
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=20),
                       max_new_tokens=14) for _ in range(5)]
    eng.run_until_done()
    s = eng.stats
    kv = eng.kv.stats
    print(f"{strategy.value:14s}: {s.tokens_generated} tokens, "
          f"{s.decode_steps} decode steps, spills={kv.spills}, "
          f"fault_events={kv.fault_events}, "
          f"page-ins={kv.fault_page_ins}, "
          f"simulated fault time={kv.simulated_us:.1f}us")
print("\nTouch-Ahead resolves a spilled sequence in block-granular fault")
print("events; Touch-A-Page pays one event per page (the thesis' contrast).")
