"""Pre-registered DMA-able frame pool (the NP-RDMA redirect target).

When speculation mis-translates (stale MTT entry) or finds no resident
page at all, NP-RDMA aborts the block and *redirects* it into a small
pool of frames that were registered (pinned, IOVA-mapped) once at
startup — DMA into them can never fault.  The host then fixes the real
mapping up and copies the data out.

The pool is the backend's bounded resource, and its sizing is the
crossover lever against the thesis mechanism: a redirect can only be
offered while ``block.n_pages`` frames are free, so under heavy churn a
small pool runs dry, aborts stop being sent, and recovery degrades to
the R5's 1 ms retransmission timeout — exactly the regime where RAPF
wins (see ``benchmarks/npr_compare.py``).

Frame lifecycle (conservation checked by ``repro.testing``)::

    free --reserve--> reserved --retire--> retired --refill--> free
                          \\------cancel (unused, clean)------/

* **reserve** is idempotent per block (an abort re-sent for the same
  round must not double-book) and all-or-nothing (``n_pages`` frames);
* **cancel** returns *clean* frames straight to free — the reservation
  was superseded (e.g. a later speculative round completed because the
  pages came back) and nothing was DMA'd into them;
* **retire** parks *dirty* frames after the fix-up copies data out;
  a watermark-driven batch refill re-registers them (one
  ``pool_refill_us`` charge per batch, modelling the amortized
  re-registration NP-RDMA does off the critical path).

Frames come from the node's :class:`~repro.core.pagetable.FrameAllocator`
— the same physical pool backing page tables and the ``repro.vmem``
frame pools — so pool sizing really competes with application memory.
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.costmodel import CostModel
from repro.core.pagetable import FrameAllocator
from repro.core.simulator import EventLoop
from repro.npr.stats import NPRStats

#: pool pages are owned by the NIC, not any protection domain
POOL_PD = -1


class DMAPool:
    """Bounded pool of pre-registered DMA-able frames on one node."""

    def __init__(self, loop: EventLoop, cost: CostModel, n_frames: int,
                 stats: NPRStats, allocator: Optional[FrameAllocator] = None,
                 on_frames_available: Optional[Callable] = None):
        if n_frames < 1:
            raise ValueError(f"DMA pool needs >= 1 frame, got {n_frames}")
        self.loop = loop
        self.cost = cost
        self.capacity = n_frames
        self.stats = stats
        self.allocator = allocator
        self._materialized = False
        self.free: list[int] = []
        self.reserved: dict = {}          # Block -> [frame, ...]
        self.retired: list[int] = []      # dirty, awaiting re-registration
        self.low_watermark = max(1, n_frames // 4)
        self._refill_pending = False
        self._waiters: list = []          # Blocks stalled on reserve()
        self._on_frames_available = on_frames_available

    # --------------------------------------------------------- registration
    def materialize(self) -> None:
        """Register the pool's frames (once, when the backend first gets a
        domain).  Lazy so nodes that never serve an NP_RDMA domain do not
        steal frames from the shared physical pool."""
        if self._materialized:
            return
        self._materialized = True
        if self.allocator is not None:
            # registered once out of the same physical pool backing the
            # page tables — pool sizing competes with application memory
            self.free = [self.allocator.alloc(POOL_PD, -1 - i)
                         for i in range(self.capacity)]
        else:
            self.free = list(range(self.capacity))

    # ------------------------------------------------------------- reserve
    def reserve(self, block) -> bool:
        """Book ``block.n_pages`` landing frames; all-or-nothing,
        idempotent per block.  Failure is counted but schedules nothing —
        callers fall back to the R5 timeout (and may :meth:`add_waiter`)."""
        if block in self.reserved:
            return True
        need = block.n_pages
        if len(self.free) < need:
            self.stats.pool_reserve_failures += 1
            return False
        frames = [self.free.pop() for _ in range(need)]
        self.reserved[block] = frames
        held = sum(len(f) for f in self.reserved.values())
        if held > self.stats.pool_reserved_peak:
            self.stats.pool_reserved_peak = held
        return True

    def cancel(self, block) -> None:
        """Release an unused (clean) reservation back to the free list."""
        frames = self.reserved.pop(block, None)
        if frames:
            self.free.extend(frames)
            self._wake_waiters()

    def retire(self, block) -> None:
        """Park a consumed (dirty) reservation for batched re-registration."""
        frames = self.reserved.pop(block, None)
        if frames:
            self.retired.extend(frames)
        if (len(self.free) < self.low_watermark and self.retired
                and not self._refill_pending):
            self._refill_pending = True
            self.loop.schedule(self.cost.pool_refill_us, self._do_refill)

    def _do_refill(self) -> None:
        self._refill_pending = False
        self.free.extend(self.retired)
        self.retired.clear()
        self.stats.pool_refills += 1
        self._wake_waiters()

    # ------------------------------------------------------------- waiters
    def add_waiter(self, block) -> None:
        """Re-notify ``block`` (FIFO) when frames return to the free list."""
        if block not in self._waiters:
            self._waiters.append(block)

    def _wake_waiters(self) -> None:
        if not self._waiters or self._on_frames_available is None:
            return
        waiters, self._waiters = self._waiters, []
        for block in waiters:
            self._on_frames_available(block)

    # ----------------------------------------------------------- observers
    def frames_accounted(self) -> int:
        """free + reserved + retired — must always equal ``capacity``."""
        return (len(self.free) + sum(len(f) for f in self.reserved.values())
                + len(self.retired))

    @property
    def outstanding_reservations(self) -> int:
        return len(self.reserved)
