"""Speculative-issue engine: the NP-RDMA datapath proper.

The thesis handles RDMA page faults *reactively in hardware*: the SMMU
terminates the access, a fault FIFO + driver tasklet resolve it, and a
RAPF message (or the 1 ms R5 timeout) retransmits.  NP-RDMA
(arXiv 2310.11062) reaches the same no-pinning goal *proactively in the
host*: transfers launch immediately on cached
:class:`~repro.npr.mtt.MTTCache` translations, a host-side verification
step audits every landed page, and mis-speculation triggers
**abort-and-redirect** through the :class:`~repro.npr.pool.DMAPool`
instead of an IOMMU fault.

Per-block protocol (all timings from :class:`~repro.core.costmodel`):

* **source side** (``dispatch``) — pages are translated through the MTT
  as the PLDMA streams.  A resident page with a fresh entry streams at
  full speed (``mtt_hits``); a missing/stale entry costs one host
  ``mtt_fill_us`` (``mtt_misses``/``mtt_stale``).  A *non-resident* page
  pauses the block and fixes up **in microseconds on the host**
  (``npr_fixup_base_us + gup_us``) — where the thesis prototype can only
  wait out the 1 ms retransmission timeout (its single biggest
  source-fault cost, Fig 4.5/4.6);
* **destination side** (``recv_page``) — each landed page is verified
  against the MTT + page table.  Fresh hit → delivered; resident but
  uncached → host installs the entry (fill) and delivers; stale entry or
  non-resident page → the page is *lost* and, once per round, the
  destination reserves pool frames and sends an **abort** to the source
  R5 (``on_npr_abort``).  The abort reuses PR 5's generation-tagged
  tr_ID lifecycle, so an abort that outlives its block's incarnation is
  dropped (``stale_npr_aborts``) instead of redirecting a fresh block;
* **redirect round** — the aborted block re-issues with
  ``block.npr_redirect`` set and lands in the reserved pool frames
  (which cannot fault).  On full delivery the host fix-up pages the real
  destination in, copies the data out
  (``npr_fixup_base_us + gup_us + n × pool_copy_page_us``), installs
  fresh MTT entries (warming the next transfer) and ACKs;
* **pool exhaustion** — no frames, no abort: the destination stays
  silent and the source recovers by the plain 1 ms timeout.  This is the
  deliberate degradation mode that lets the thesis' RAPF datapath win
  under heavy churn with a small pool.

The engine deliberately reuses the surrounding machinery unchanged: the
DMA arbiter (slots, DRR, deschedule-on-fault), the routed interconnect,
tr_ID allocation/recycling, timeouts and ACK bookkeeping all behave
identically for both backends — only the fault handling differs, which
is what makes ``benchmarks/npr_compare.py`` a controlled comparison.
"""

from __future__ import annotations

from repro.core import addresses as A
from repro.core.node import Block, BlockState, Node
from repro.npr.mtt import MTTCache
from repro.npr.pool import DMAPool
from repro.npr.stats import NPRStats


class NPREngine:
    """Per-node NP-RDMA backend: MTT + DMA pool + speculation protocol."""

    def __init__(self, node: Node, mtt_entries: int = 4096,
                 dma_pool_frames: int = 64, speculation: bool = True):
        self.node = node
        self.loop = node.loop
        self.cost = node.cost
        self.speculation = speculation
        self.stats = NPRStats(mtt_capacity=mtt_entries,
                              pool_frames=dma_pool_frames)
        self.mtt = MTTCache(mtt_entries, self.stats)
        self.pool = DMAPool(node.loop, node.cost, dma_pool_frames, self.stats,
                            allocator=node.allocator,
                            on_frames_available=self._pool_wakeup)
        self.domains: dict[int, object] = {}     # pd -> PageTable
        self._hooks: dict[int, object] = {}      # pd -> invalidation hook

    # ------------------------------------------------------------- domains
    def register_domain(self, pd: int, page_table) -> None:
        """Adopt domain ``pd``: translations for it go through the MTT,
        and the page table's invalidation hooks stale the cache exactly
        as they shoot down the SMMU TLB for the thesis datapath."""
        if pd in self.domains:
            return
        self.pool.materialize()
        self.domains[pd] = page_table
        hook = lambda vpn: self.mtt.invalidate(pd, vpn)
        page_table.invalidation_hooks.append(hook)
        self._hooks[pd] = hook

    def unregister_domain(self, pd: int) -> None:
        """Drop domain ``pd`` (``close_domain``): unhook the page table,
        forget its MTT entries wholesale.  No-op for non-NPR domains."""
        pt = self.domains.pop(pd, None)
        if pt is None:
            return
        hook = self._hooks.pop(pd, None)
        if hook is not None:
            try:
                pt.invalidation_hooks.remove(hook)
            except ValueError:
                pass
        self.mtt.drop_domain(pd)

    def invalidate_domain(self, pd: int) -> int:
        """Stale-mark every MTT entry of ``pd`` (its SMMU context bank
        was stolen by the tenancy layer).  No-op for non-NPR domains."""
        if pd not in self.domains:
            return 0
        return self.mtt.invalidate_domain(pd)

    def owns(self, block: Block) -> bool:
        """Is this block's domain served by the NP-RDMA backend?"""
        return block.transfer.pd in self.domains

    # ====================================================== source (send)
    def dispatch(self, block: Block, path, latency_class: bool) -> None:
        """Stream one block, translating source pages through the MTT.

        Called from ``R5Scheduler._dispatch`` in place of the SMMU
        per-page translate loop; the caller has already advanced
        ``round_id`` and arms the timeout after we return.
        """
        # the R5 moved the block to IN_FLIGHT just before delegating here
        # (the assert doubles as the from-state fact for repro.lint)
        assert block.state is BlockState.IN_FLIGHT
        node, cost, loop = self.node, self.cost, self.loop
        transfer = block.transfer
        pd = transfer.pd
        pt = self.domains[pd]
        if block.npr_redirect or not self.speculation:
            # redirect round (or bounce-buffer mode): the block must land
            # in pre-reserved pool frames on the destination
            dst_pool = transfer.dst_node.npr.pool
            if not dst_pool.reserve(block):
                self.stats.pool_stalls += 1
                block.state = BlockState.PAUSED_DST
                node.arbiter.on_block_paused(block)
                dst_pool.add_waiter(block)
                return
            block.npr_redirect = True
        first_vpn = block.src_va >> 12
        last_vpn = (block.src_va + block.nbytes - 1) >> 12
        fill_offset = 0.0
        for i, vpn in enumerate(range(first_vpn, last_vpn + 1)):
            pte = pt.lookup(vpn)
            if not pt.is_resident(vpn):
                self._src_fixup(block, vpn, last_vpn - vpn + 1)
                return
            entry = self.mtt.lookup(pd, vpn)
            if entry is not None and not entry.stale \
                    and entry.frame == pte.frame:
                self.stats.mtt_hits += 1
                transfer.stats.mtt_hits += 1
            else:
                if entry is None:
                    self.stats.mtt_misses += 1
                    transfer.stats.mtt_misses += 1
                else:
                    self.stats.mtt_stale_hits += 1
                    transfer.stats.mtt_stale += 1
                self.mtt.install(pd, vpn, pte.frame)
                node.driver_cpu.reserve(cost.mtt_fill_us)
                transfer.stats.driver_us += cost.mtt_fill_us
                fill_offset += cost.mtt_fill_us
            pg_start = max(block.src_va, vpn << 12)
            pg_end = min(block.src_va + block.nbytes, (vpn + 1) << 12)
            nbytes = pg_end - pg_start
            # same deterministic stream key as R5Scheduler._dispatch:
            # id(block) can alias a collected block's reused address
            delay, interleaved = path.stream_page(
                nbytes, (transfer.tid, block.index),
                latency_class=latency_class)
            block.wire_bytes += nbytes
            loop.schedule(fill_offset + delay, transfer.dst_node.recv_page,
                          block, i, block.round_id, interleaved, nbytes)

    def _src_fixup(self, block: Block, vpn: int, remaining: int) -> None:
        """Source page not resident: pause and fix up host-side, in µs.

        The thesis prototype has no source-side resume at all — recovery
        is by the 1 ms timeout only (§3.2.2).  NP-RDMA's host issues the
        DMA itself, so it can ``get_user_pages`` the block's remaining
        pages, install their translations and requeue immediately.
        """
        assert block.state is BlockState.IN_FLIGHT   # see dispatch()
        node, cost = self.node, self.cost
        transfer = block.transfer
        transfer.stats.src_faults += 1
        self.stats.src_fixups += 1
        block.state = BlockState.PAUSED_SRC
        node.arbiter.on_block_paused(block)
        busy = cost.npr_fixup_base_us + cost.gup_us(remaining)
        transfer.stats.driver_us += busy
        _, end = node.driver_cpu.reserve(busy)
        self.loop.at(end, self._finish_src_fixup, block, vpn, remaining,
                     block.round_id)

    def _finish_src_fixup(self, block: Block, vpn: int, n: int,
                          round_id: int) -> None:
        if block.state is BlockState.DONE or round_id != block.round_id:
            return
        pd = block.transfer.pd
        pt = self.domains[pd]
        got = pt.get_user_pages(vpn, n, write=True)
        if not got:
            # page left the address space entirely: only the timeout can
            # retry this round (mirrors the thesis' SIGSEGV scenario)
            return
        for v in range(vpn, vpn + got):
            self.mtt.install(pd, v, pt.lookup(v).frame)
        if block.timeout_event is not None:
            block.timeout_event.cancel()
        self.node.arbiter.requeue(block)

    # ================================================= destination (recv)
    def recv_page(self, block: Block, page_idx: int, round_id: int,
                  nbytes: int) -> None:
        """Verify one landed page (speculative round) or accept it into
        the pool (redirect round).  Runs on the destination node; the
        caller has already rejected stale rounds."""
        transfer = block.transfer
        if block.npr_redirect:
            # pool frames are pre-registered: this DMA cannot fault
            self.stats.redirect_pages += 1
            transfer.stats.pool_redirect_pages += 1
            block.delivered.add(page_idx)
            if len(block.delivered) == block.n_pages:
                n = block.n_pages
                busy = (self.cost.npr_fixup_base_us + self.cost.gup_us(n)
                        + self.cost.pool_copy_page_us * n)
                transfer.stats.driver_us += busy
                _, end = self.node.driver_cpu.reserve(busy)
                self.loop.at(end, self._finish_redirect, block, round_id)
            return
        pd = transfer.pd
        pt = self.domains[pd]
        vpn = A.page_index(block.dst_va) + page_idx
        entry = self.mtt.lookup(pd, vpn)
        ok = False
        if pt.is_resident(vpn):
            frame = pt.lookup(vpn).frame
            if entry is not None and not entry.stale and entry.frame == frame:
                self.stats.mtt_hits += 1
                transfer.stats.mtt_hits += 1
                ok = True
            elif entry is None:
                # resident but uncached: verification installs the entry
                # and accepts the page (one host fill, RDMAbox-style)
                self.stats.mtt_misses += 1
                transfer.stats.mtt_misses += 1
                self.mtt.install(pd, vpn, frame)
                self.node.driver_cpu.reserve(self.cost.mtt_fill_us)
                transfer.stats.driver_us += self.cost.mtt_fill_us
                ok = True
            else:
                # stale/mismatched entry: the DMA hit a dead frame
                self.stats.mtt_stale_hits += 1
                transfer.stats.mtt_stale += 1
        elif entry is not None:
            # entry for a page that is gone: caught before completion
            self.stats.mtt_stale_hits += 1
            transfer.stats.mtt_stale += 1
        else:
            self.stats.mtt_misses += 1
            transfer.stats.mtt_misses += 1
        if ok:
            block.delivered.add(page_idx)
            if len(block.delivered) == block.n_pages:
                self._complete_speculative(block, round_id)
            return
        # ---- mis-speculation: abort-and-redirect (once per round) ------
        transfer.stats.dst_faults += 1
        if block.nacked_round == round_id:
            return
        block.nacked_round = round_id
        if not self.pool.reserve(block):
            # pool dry: no abort; the source's 1 ms timeout recovers.
            # (reserve() counted the failure — this is the degradation
            # regime where the thesis' RAPF datapath wins.)
            return
        self.stats.aborts_sent += 1
        transfer.stats.npr_aborts += 1
        delay = (self.cost.npr_abort_ctrl_us
                 + self.node.path_to(transfer.src_node.node_id).send_ctrl(8))
        self.loop.schedule(delay, transfer.src_node.r5.on_npr_abort,
                           block.tr_id, block.gen, round_id)

    def _complete_speculative(self, block: Block, round_id: int) -> None:
        # a reservation from an earlier aborted round may be outstanding
        # (the abort was lost/stale and plain retry succeeded): release it
        self.pool.cancel(block)
        delay = (self.cost.ack_us
                 + self.node.path_to(block.transfer.src_node.node_id)
                       .send_ctrl(0))
        self.loop.schedule(delay, block.transfer.src_node.r5.on_ack,
                           block, round_id)

    def _finish_redirect(self, block: Block, round_id: int) -> None:
        """Host fix-up after a redirect round fully landed in the pool:
        page the real destination in, copy out, warm the MTT, ACK."""
        transfer = block.transfer
        if block.state is BlockState.DONE or round_id != block.round_id:
            self.pool.retire(block)      # dirty frames of a dead round
            return
        pd = transfer.pd
        pt = self.domains[pd]
        vpn = A.page_index(block.dst_va)
        got = pt.get_user_pages(vpn, block.n_pages, write=True)
        if got < block.n_pages:
            # destination range (partially) unmapped: give the frames
            # back and let the timeout retry the redirect
            self.pool.retire(block)
            return
        for v in range(vpn, vpn + block.n_pages):
            self.mtt.install(pd, v, pt.lookup(v).frame)
        self.stats.redirected_blocks += 1
        self.pool.retire(block)
        delay = (self.cost.ack_us
                 + self.node.path_to(transfer.src_node.node_id).send_ctrl(0))
        self.loop.schedule(delay, transfer.src_node.r5.on_ack,
                           block, round_id)

    # ------------------------------------------------------------ plumbing
    def _pool_wakeup(self, block: Block) -> None:
        """Frames returned to the destination pool: retry a stalled block
        (on its *source* node's arbiter; requeue is idempotent and skips
        blocks that completed meanwhile)."""
        block.transfer.src_node.arbiter.requeue(block)
