"""Per-node telemetry of the NP-RDMA backend (one record per node).

One dataclass covers all three moving parts — the
:class:`~repro.npr.mtt.MTTCache`, the :class:`~repro.npr.pool.DMAPool`
and the speculative-issue engine — so ``Fabric.protocol_stats()`` can
surface them uniformly next to :class:`~repro.core.node.TrIdStats`
without per-field ``getattr`` fallbacks.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NPRStats:
    """Telemetry of one node's NP-RDMA engine.

    ``stale_completions`` is the backend's central safety counter: a
    page delivered through a translation that an invalidation had
    already flagged stale.  The verification step makes this
    structurally impossible, and ``repro.testing`` asserts it stays 0.
    """

    # ---- MTT (memory translation table) ---------------------------------
    mtt_capacity: int = 0
    mtt_hits: int = 0            # verifications served by a fresh entry
    mtt_misses: int = 0          # lookups with no entry at all
    mtt_fills: int = 0           # entries installed (miss fills + fixups)
    mtt_stale_hits: int = 0      # verifications that caught a stale entry
    mtt_invalidations: int = 0   # entries flagged by page-table hooks
    mtt_evictions: int = 0       # LRU evictions at capacity
    # ---- speculative issue ----------------------------------------------
    aborts_sent: int = 0         # abort-and-redirect control messages
    redirected_blocks: int = 0   # blocks that completed through the pool
    redirect_pages: int = 0      # pages landed in pool frames
    src_fixups: int = 0          # source misses fixed host-side (no 1 ms)
    stale_completions: int = 0   # MUST stay zero (repro.testing invariant)
    # ---- DMA-able pool ---------------------------------------------------
    pool_frames: int = 0
    pool_reserve_failures: int = 0   # reservations refused: pool exhausted
    pool_refills: int = 0            # watermark-driven re-registrations
    pool_reserved_peak: int = 0      # high-water mark of frames held
    pool_stalls: int = 0             # dispatches deferred awaiting frames

    @property
    def active(self) -> bool:
        """Did the engine do any work (beyond configuration echo)?"""
        return any(getattr(self, f.name) for f in dataclasses.fields(self)
                   if f.name not in ("mtt_capacity", "pool_frames"))

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}
