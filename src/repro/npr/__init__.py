"""NP-RDMA no-pinning backend (beyond paper — arXiv 2310.11062).

A *competing* fault-handling datapath next to the thesis' SMMU + fault
FIFO + RAPF mechanism: speculative VA→PA translation through a
host-managed :class:`MTTCache`, with abort-and-redirect through a
:class:`DMAPool` of pre-registered frames on mis-speculation.  Selected
per protection domain via
``FaultPolicy(strategy=Strategy.NP_RDMA)`` and sized by the
``FabricConfig`` knobs ``mtt_entries`` / ``dma_pool_frames`` /
``speculation``.  Head-to-head comparison: ``benchmarks/npr_compare.py``.
"""

from repro.npr.engine import NPREngine
from repro.npr.mtt import MTTCache, MTTEntry
from repro.npr.pool import DMAPool, POOL_PD
from repro.npr.stats import NPRStats

__all__ = ["NPREngine", "MTTCache", "MTTEntry", "DMAPool", "POOL_PD",
           "NPRStats"]
