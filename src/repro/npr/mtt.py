"""Host-managed memory translation table (MTT) cache.

NP-RDMA (arXiv 2310.11062) keeps VA→PA translations in a host-side MTT
the NIC consults to issue DMA *speculatively* — no page pinning, no
IOMMU fault path.  RDMAbox (arXiv 2104.12197) showed the same
translation-cache fast path pays off whenever the working set re-uses
pages.  This module is the cache itself; the speculation/verification
protocol around it lives in :mod:`repro.npr.engine`.

Design points mirrored from the papers:

* **per-domain keys** — entries are ``(pd, vpn) -> frame`` so one node's
  cache serves all its protection domains without aliasing;
* **stale marking, not eviction, on invalidation** — reclaim/khugepaged
  hooks *flag* the entry instead of dropping it.  A flagged entry is the
  detection window: a speculative DMA that raced the invalidation is
  caught by the host-side verification step comparing against the flag
  (dropping the entry would make the race look like a plain miss and
  lose the "this translation was used while dying" signal);
* **bounded LRU** — ``mtt_entries`` caps host memory; eviction is
  least-recently-verified.

The cache mirrors how :class:`~repro.core.fault.SMMU` subscribes its TLB
shoot-down to :attr:`~repro.core.pagetable.PageTable.invalidation_hooks`:
:meth:`~repro.npr.engine.NPREngine.register_domain` registers
:meth:`MTTCache.invalidate` on the same hook list, so the *same*
``FaultInjection`` churn (reclaim, khugepaged collapse, munmap) that
faults the thesis datapath stales this one.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional

from repro.npr.stats import NPRStats


class MTTEntry:
    """One cached translation: the frame plus its staleness flag."""

    __slots__ = ("frame", "stale")

    def __init__(self, frame: int):
        self.frame = frame
        self.stale = False


class MTTCache:
    """Bounded per-node VA→PA translation cache with stale marking."""

    def __init__(self, capacity: int, stats: NPRStats):
        if capacity < 1:
            raise ValueError(f"MTT capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.stats = stats
        self._entries: "OrderedDict[tuple[int, int], MTTEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, pd: int, vpn: int) -> Optional[MTTEntry]:
        """The entry for ``(pd, vpn)``, stale or not; None on a miss.

        A hit refreshes LRU order (stale entries included — they are
        about to be either refreshed by a fill or consulted by the
        verification step, both recency signals).  Hit/miss/stale
        *counters* are the caller's job: only the engine knows whether a
        lookup was a speculative verify or a plain probe.
        """
        e = self._entries.get((pd, vpn))
        if e is not None:
            self._entries.move_to_end((pd, vpn))
        return e

    def install(self, pd: int, vpn: int, frame: int) -> MTTEntry:
        """Install/refresh the translation for ``(pd, vpn)`` (a *fill*)."""
        key = (pd, vpn)
        e = self._entries.get(key)
        if e is None:
            e = MTTEntry(frame)
            self._entries[key] = e
            if len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.mtt_evictions += 1
        else:
            e.frame = frame
            e.stale = False
        self._entries.move_to_end(key)
        self.stats.mtt_fills += 1
        return e

    def invalidate(self, pd: int, vpn: int) -> None:
        """Page-table hook: the mapping changed under the cache."""
        e = self._entries.get((pd, vpn))
        if e is not None and not e.stale:
            e.stale = True
            self.stats.mtt_invalidations += 1

    def invalidate_domain(self, pd: int) -> int:
        """Stale-mark every entry of ``pd`` (its SMMU bank was stolen).

        Same detection-window semantics as per-page :meth:`invalidate`:
        a speculative DMA racing the bank steal is caught by the
        verification step instead of completing against a translation
        the SMMU no longer backs.  Returns entries newly staled.
        """
        staled = 0
        # lint: allow(det-dict-iter): per-entry idempotent staling, order-free
        for (epd, _), e in self._entries.items():
            if epd == pd and not e.stale:
                e.stale = True
                staled += 1
        self.stats.mtt_invalidations += staled
        return staled

    def drop_domain(self, pd: int) -> int:
        """Remove every entry of ``pd`` outright (``close_domain`` —
        nothing can race a closed domain, so no detection window is
        needed).  Returns entries dropped."""
        keys = [k for k in self._entries if k[0] == pd]
        for k in keys:
            del self._entries[k]
        return len(keys)

    def entries(self):
        """Iterate ``((pd, vpn), entry)`` — for invariant checkers."""
        return self._entries.items()
