"""Whisper-style encoder–decoder backbone.

The audio frontend (two convs over log-mel) is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, T_src, d) and
the encoder consumes them directly.  Decoder self-attention KV is paged;
cross-attention KV is computed once at encode time and *pinned* — the
enc-dec counterpart of the thesis' pinned-vs-paged split (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import (apply_attention,
                                    apply_attention_decode_paged,
                                    init_attention)
from repro.models.attention_ops import flash_attention_xla, mha_reference
from repro.models.config import ModelConfig
from repro.models.decoder import _identity_page_table, _stack
from repro.models.layers import (apply_mlp, apply_norm, dense_init, dtype_of,
                                 embed_init, init_mlp, init_norm,
                                 sinusoid_positions)


def _init_cross(key, cfg: ModelConfig, dtype):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, H * hd, dtype),
            "wk": dense_init(ks[1], d, H * hd, dtype),
            "wv": dense_init(ks[2], d, H * hd, dtype),
            "wo": dense_init(ks[3], H * hd, d, dtype)}


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    keys = jax.random.split(key, n_enc + 2 * cfg.n_layers + 4)
    enc_layers = [{"norm1": init_norm(cfg.d_model, cfg.norm),
                   "attn": init_attention(keys[i], cfg, dtype),
                   "norm2": init_norm(cfg.d_model, cfg.norm),
                   "mlp": init_mlp(keys[i + 1], cfg.d_model, cfg.d_ff,
                                   cfg.act, dtype)}
                  for i in range(n_enc)]
    dec_layers = [{"norm1": init_norm(cfg.d_model, cfg.norm),
                   "self_attn": init_attention(keys[n_enc + i], cfg, dtype),
                   "norm_x": init_norm(cfg.d_model, cfg.norm),
                   "cross": _init_cross(keys[n_enc + cfg.n_layers + i], cfg,
                                        dtype),
                   "norm2": init_norm(cfg.d_model, cfg.norm),
                   "mlp": init_mlp(keys[n_enc + i + 2], cfg.d_model, cfg.d_ff,
                                   cfg.act, dtype)}
                  for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "pos_dec": embed_init(keys[-2], cfg.max_target_positions,
                              cfg.d_model, dtype),
        "enc_layers": _stack(enc_layers),
        "enc_norm": init_norm(cfg.d_model, cfg.norm),
        "dec_layers": _stack(dec_layers),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }


def encode(params, cfg: ModelConfig, frame_embeddings, remat: bool = False):
    """frame_embeddings: (B, T_src, d) — the stubbed conv frontend output."""
    B, T, d = frame_embeddings.shape
    x = frame_embeddings + sinusoid_positions(T, d).astype(
        frame_embeddings.dtype)

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        attn = apply_attention(lp["attn"], cfg, h,
                               jnp.broadcast_to(jnp.arange(T), (B, T)),
                               causal=False)  # bidirectional encoder
        x = x + attn
        h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        return x + apply_mlp(lp["mlp"], h, cfg.act), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg.norm, cfg.norm_eps)


def _cross_attention(cp, cfg, x, enc_kv):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    k, v = enc_kv
    q = (x @ cp["wq"]).reshape(B, S, H, hd)
    out = flash_attention_xla(q, k, v, causal=False)
    return out.reshape(B, S, H * hd) @ cp["wo"]


def cross_kv(params, cfg: ModelConfig, enc_out):
    """Precompute ("pin") cross-attention K/V for all decoder layers."""
    B, T, _ = enc_out.shape
    H, hd = cfg.n_heads, cfg.head_dim

    def body(_, lp):
        k = (enc_out @ lp["cross"]["wk"]).reshape(B, T, H, hd)
        v = (enc_out @ lp["cross"]["wv"]).reshape(B, T, H, hd)
        return None, (k, v)

    _, kv = jax.lax.scan(body, None, params["dec_layers"])
    return kv     # (L, B, T, H, hd) × 2


def forward(params, cfg: ModelConfig, tokens, frame_embeddings=None,
            embeddings=None, remat: bool = False, **_):
    """Teacher-forced decoder pass. tokens: (B, S_dec)."""
    B, S = tokens.shape
    if frame_embeddings is None:
        frame_embeddings = embeddings
    if frame_embeddings is None:
        d = cfg.d_model
        frame_embeddings = jnp.zeros(
            (B, cfg.max_source_positions, d),
            dtype_of(cfg.dtype))
    enc_out = encode(params, cfg, frame_embeddings, remat=remat)
    pos = jnp.arange(S) % cfg.max_target_positions
    x = params["embed"][tokens] + params["pos_dec"][pos][None]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    T = enc_out.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        x = x + apply_attention(lp["self_attn"], cfg, h, positions)
        h = apply_norm(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
        k = (enc_out @ lp["cross"]["wk"]).reshape(B, T, H, hd)
        v = (enc_out @ lp["cross"]["wv"]).reshape(B, T, H, hd)
        x = x + _cross_attention(lp["cross"], cfg, h, (k, v))
        h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        return x + apply_mlp(lp["mlp"], h, cfg.act), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x @ params["embed"].T, 0.0


def loss_fn(params, cfg: ModelConfig, tokens, labels, frame_embeddings=None,
            **kw):
    logits, aux = forward(params, cfg, tokens, frame_embeddings, **kw)
    from repro.models.losses import masked_xent
    return masked_xent(logits, labels, aux)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None, t_src: int = 0) -> dict:
    dtype = dtype or dtype_of(cfg.dtype)
    L = cfg.n_layers
    ps = cfg.kv_page_tokens
    n_pages = batch * (-(-max_len // ps))
    t_src = t_src or cfg.max_source_positions
    return {
        "lengths": jnp.zeros((batch,), jnp.int32),
        "k_pool": jnp.zeros((L, n_pages, ps, cfg.n_kv_heads, cfg.head_dim),
                            dtype),
        "v_pool": jnp.zeros((L, n_pages, ps, cfg.n_kv_heads, cfg.head_dim),
                            dtype),
        "page_table": _identity_page_table(batch, max_len, ps),
        # pinned cross-attention KV (L, B, T_src, H, hd)
        "cross_k": jnp.zeros((L, batch, t_src, cfg.n_heads, cfg.head_dim),
                             dtype),
        "cross_v": jnp.zeros((L, batch, t_src, cfg.n_heads, cfg.head_dim),
                             dtype),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens):
    B = tokens.shape[0]
    lengths = cache["lengths"] + 1
    pos = (lengths - 1) % cfg.max_target_positions
    x = params["embed"][tokens] + params["pos_dec"][pos][:, None]
    new_cache = dict(cache, lengths=lengths)
    H, hd = cfg.n_heads, cfg.head_dim

    def body(x, inp):
        lp, kp, vp, ck, cv = inp
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        attn, kp, vp = apply_attention_decode_paged(
            lp["self_attn"], cfg, h, kp, vp, cache["page_table"], lengths)
        x = x + attn
        h = apply_norm(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
        q = (h[:, 0] @ lp["cross"]["wq"]).reshape(B, 1, H, hd)
        cross = flash_attention_xla(q, ck, cv, causal=False)
        x = x + (cross.reshape(B, 1, H * hd) @ lp["cross"]["wo"])
        h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        return x + apply_mlp(lp["mlp"], h, cfg.act), (kp, vp)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k_pool"], cache["v_pool"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache["k_pool"] = k_new
    new_cache["v_pool"] = v_new
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x @ params["embed"].T, new_cache
