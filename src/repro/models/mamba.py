"""Mamba2 (SSD) block: chunked parallel scan for train/prefill, recurrent
single-step for decode.

State-space recurrence per head (P = head channels, N = state dim):
    S_t = exp(dt_t·A) · S_{t-1} + (dt_t·x_t) ⊗ B_t        S: (P, N)
    y_t = C_t · S_t + D · x_t

Train/prefill uses the SSD chunked algorithm (segment-sum decays: intra-
chunk quadratic + inter-chunk state scan) — O(S·Q) memory instead of the
naive O(S·P·N) scan materialization, and MXU-friendly einsums.

The decode state (S plus the depthwise-conv tail) is tiny and *resident*
("pinned" in thesis terms) — the hybrid archs page only their attention KV
while the SSM state stays pinned, a contrast DESIGN.md §4 calls out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def mamba_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, nh, P, N = mamba_dims(cfg)
    conv_dim = d_in + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], d_in, d, dtype),
    }


def _split_proj(p, cfg: ModelConfig, x):
    d_in, nh, P, N = mamba_dims(cfg)
    z, xBC, dt = jnp.split(x @ p["in_proj"], [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xBC, dt


def _causal_conv(p, xBC, w: int):
    """Depthwise causal conv along the sequence axis."""
    B, S, C = xBC.shape
    pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + S, :] * p["conv_w"][k] for k in range(w))
    return jax.nn.silu(out + p["conv_b"])


def _gated_norm(p, y, z, eps: float):
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return yf * jax.lax.rsqrt(var + eps) * p["norm_scale"]


def apply_mamba(p, cfg: ModelConfig, x, *, chunk: int = 128):
    """Chunked SSD forward.  x: (B, S, d) -> (B, S, d)."""
    Bsz, S, d = x.shape
    d_in, nh, P, N = mamba_dims(cfg)
    z, xBC, dt = _split_proj(p, cfg, x)
    xBC = _causal_conv(p, xBC, cfg.ssm_conv)
    xs = xBC[..., :d_in].reshape(Bsz, S, nh, P)
    Bmat = xBC[..., d_in:d_in + N]                     # (B, S, N), 1 group
    Cmat = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    A = -jnp.exp(p["A_log"])                           # (nh,)
    a = dt * A                                          # log-decay (B,S,nh)
    u = dt[..., None] * xs.astype(jnp.float32)          # (B,S,nh,P)

    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        a_p = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
    else:
        xs_p, Bm, Cm, a_p = xs, Bmat, Cmat, a
    nc = (S + pad) // Q
    u = u.reshape(Bsz, nc, Q, nh, P)
    Bm = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cm = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    a_c = a_p.reshape(Bsz, nc, Q, nh)

    acum = jnp.cumsum(a_c, axis=2)                      # (B,nc,Q,nh)
    # intra-chunk decays L[i,j] = exp(acum_i - acum_j) for i >= j
    diff = acum[:, :, :, None, :] - acum[:, :, None, :, :]   # (B,nc,Q,Q,nh)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cm, Bm)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", CB, L, u)

    # chunk-final states and the inter-chunk scan
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)   # (B,nc,Q,nh)
    S_chunk = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", decay_to_end, u, Bm)
    total_decay = jnp.exp(acum[:, :, -1, :])            # (B,nc,nh)

    def scan_fn(S_prev, inp):
        dec, S_c = inp                                  # (B,nh), (B,nh,P,N)
        S_new = dec[..., None, None] * S_prev + S_c
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, nh, P, N), jnp.float32)
    _, S_prevs = jax.lax.scan(
        scan_fn, S0,
        (total_decay.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)          # (B,nc,nh,P,N)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cm, jnp.exp(acum), S_prevs)

    y = (y_diag + y_off).reshape(Bsz, nc * Q, nh, P)[:, :S]
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    return (y.astype(x.dtype)) @ p["out_proj"]


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, nh, P, N = mamba_dims(cfg)
    conv_dim = d_in + 2 * N
    return {"ssm": jnp.zeros((batch, nh, P, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype)}


def apply_mamba_decode(p, cfg: ModelConfig, x, state):
    """Single-token recurrent step.  x: (B, 1, d) -> (y, state)."""
    Bsz = x.shape[0]
    d_in, nh, P, N = mamba_dims(cfg)
    z, xBC, dt = _split_proj(p, cfg, x)
    z, xBC, dt = z[:, 0], xBC[:, 0], dt[:, 0]
    # conv over the stored tail + current input
    hist = jnp.concatenate([state["conv"], xBC[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist, p["conv_w"].astype(jnp.float32))
    xBC_c = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv = hist[:, 1:]

    xs = xBC_c[:, :d_in].reshape(Bsz, nh, P)
    Bm = xBC_c[:, d_in:d_in + N]
    Cm = xBC_c[:, d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                             # (B, nh)
    u = dt[..., None] * xs                              # (B, nh, P)
    S = state["ssm"] * decay[..., None, None] \
        + jnp.einsum("bhp,bn->bhpn", u, Bm)
    y = jnp.einsum("bhpn,bn->bhp", S, Cm) + p["D"][None, :, None] * xs
    y = y.reshape(Bsz, d_in)
    y = _gated_norm(p, y[:, None, :].reshape(Bsz, 1, d_in)[:, 0],
                    z, cfg.norm_eps)
    out = (y.astype(x.dtype)) @ p["out_proj"]
    return out[:, None, :], {"ssm": S, "conv": new_conv.astype(state["conv"].dtype)}


def mamba_reference(p, cfg: ModelConfig, x):
    """Naive per-token recurrence — oracle for the chunked implementation."""
    Bsz, S, d = x.shape
    d_in, nh, P, N = mamba_dims(cfg)
    state = init_mamba_state(cfg, Bsz, dtype=x.dtype)
    outs = []
    for t in range(S):
        y, state = apply_mamba_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
