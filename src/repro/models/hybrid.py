"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

Layout (attn_every = k): the L Mamba2 blocks are split into groups of k;
after each full group the single shared transformer block (attention + MLP,
one weight set reused at every application) runs.  L = 81, k = 6 gives 13
shared-attention applications plus a 3-block tail.

Decode state = per-layer Mamba2 (ssm, conv) states (tiny, pinned) + one
paged KV pool per shared-attention *application site* (13 sites share
weights but not caches) — the pinned-vs-paged contrast of DESIGN.md §4.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import mamba as mamba_mod
from repro.models.attention import (apply_attention,
                                    apply_attention_decode_paged,
                                    init_attention)
from repro.models.config import ModelConfig
from repro.models.decoder import _identity_page_table, _stack
from repro.models.layers import (apply_mlp, apply_norm, dense_init, dtype_of,
                                 embed_init, init_mlp, init_norm)


def group_layout(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, group_size, tail)."""
    k = max(1, cfg.attn_every)
    n_groups = cfg.n_layers // k
    tail = cfg.n_layers - n_groups * k
    return n_groups, k, tail


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    n_groups, k, tail = group_layout(cfg)
    keys = jax.random.split(key, cfg.n_layers + 4)
    mamba_layers = [
        {"norm": init_norm(cfg.d_model, cfg.norm),
         "mamba": mamba_mod.init_mamba(keys[i], cfg, dtype)}
        for i in range(cfg.n_layers)]
    params: dict[str, Any] = {
        "embed": embed_init(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
        "lm_head": dense_init(keys[-2], cfg.d_model, cfg.vocab_size, dtype),
        "groups": _stack([_stack(mamba_layers[g * k:(g + 1) * k])
                          for g in range(n_groups)]),   # (G, k, ...)
        "shared": {
            "norm1": init_norm(cfg.d_model, cfg.norm),
            "attn": init_attention(keys[-3], cfg, dtype),
            "norm2": init_norm(cfg.d_model, cfg.norm),
            "mlp": init_mlp(keys[-4], cfg.d_model, cfg.d_ff, cfg.act, dtype),
        },
    }
    if tail:
        params["tail"] = _stack(mamba_layers[n_groups * k:])
    return params


def _mamba_layer(lp, cfg, x, chunk):
    h = apply_norm(lp["norm"], x, cfg.norm, cfg.norm_eps)
    return x + mamba_mod.apply_mamba(lp["mamba"], cfg, h, chunk=chunk)


def _shared_attn(sp, cfg, x, positions, q_chunk, kv_chunk):
    h = apply_norm(sp["norm1"], x, cfg.norm, cfg.norm_eps)
    x = x + apply_attention(sp["attn"], cfg, h, positions, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    h = apply_norm(sp["norm2"], x, cfg.norm, cfg.norm_eps)
    return x + apply_mlp(sp["mlp"], h, cfg.act)


def forward(params, cfg: ModelConfig, tokens, *, q_chunk: int = 512,
            kv_chunk: int = 512, ssm_chunk: int = 128,
            embeddings=None, remat: bool = False):
    x = params["embed"][tokens] if embeddings is None else embeddings
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def group_body(x, glp):
        def layer_body(x, lp):
            return _mamba_layer(lp, cfg, x, ssm_chunk), None
        if remat:
            layer_body = jax.checkpoint(layer_body)
        x, _ = jax.lax.scan(layer_body, x, glp)
        x = _shared_attn(params["shared"], cfg, x, positions, q_chunk,
                         kv_chunk)
        return x, None

    if remat:
        group_body = jax.checkpoint(group_body)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "tail" in params:
        def layer_body(x, lp):
            return _mamba_layer(lp, cfg, x, ssm_chunk), None
        if remat:
            layer_body = jax.checkpoint(layer_body)
        x, _ = jax.lax.scan(layer_body, x, params["tail"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x @ params["lm_head"], 0.0


def loss_fn(params, cfg: ModelConfig, tokens, labels, **kw):
    logits, aux = forward(params, cfg, tokens, **kw)
    from repro.models.losses import masked_xent
    return masked_xent(logits, labels, aux)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> dict:
    dtype = dtype or dtype_of(cfg.dtype)
    n_groups, k, tail = group_layout(cfg)
    ps = cfg.kv_page_tokens
    n_pages = batch * (-(-max_len // ps))
    st = mamba_mod.init_mamba_state(cfg, batch, dtype=dtype)
    stacked_state = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), st)
    return {
        "lengths": jnp.zeros((batch,), jnp.int32),
        "ssm": stacked_state,                           # (L, ...) per leaf
        # one KV pool per shared-attention application site
        "k_pool": jnp.zeros((n_groups, n_pages, ps, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
        "v_pool": jnp.zeros((n_groups, n_pages, ps, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
        "page_table": _identity_page_table(batch, max_len, ps),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens):
    x = params["embed"][tokens]
    n_groups, k, tail = group_layout(cfg)
    lengths = cache["lengths"] + 1
    new_cache = dict(cache, lengths=lengths)
    sp = params["shared"]

    group_states = jax.tree_util.tree_map(
        lambda s: s[:n_groups * k].reshape((n_groups, k) + s.shape[1:]),
        cache["ssm"])

    def group_body(x, inp):
        glp, gstate, kp, vp = inp

        def layer_body(x, lp_st):
            lp, st = lp_st
            h = apply_norm(lp["norm"], x, cfg.norm, cfg.norm_eps)
            y, st = mamba_mod.apply_mamba_decode(lp["mamba"], cfg, h, st)
            return x + y, st

        x, new_st = jax.lax.scan(layer_body, x, (glp, gstate))
        h = apply_norm(sp["norm1"], x, cfg.norm, cfg.norm_eps)
        attn, kp, vp = apply_attention_decode_paged(
            sp["attn"], cfg, h, kp, vp, cache["page_table"], lengths)
        x = x + attn
        h = apply_norm(sp["norm2"], x, cfg.norm, cfg.norm_eps)
        x = x + apply_mlp(sp["mlp"], h, cfg.act)
        return x, (new_st, kp, vp)

    x, (new_group_states, k_new, v_new) = jax.lax.scan(
        group_body, x, (params["groups"], group_states,
                        cache["k_pool"], cache["v_pool"]))
    new_cache["k_pool"] = k_new
    new_cache["v_pool"] = v_new

    flat_states = jax.tree_util.tree_map(
        lambda s: s.reshape((n_groups * k,) + s.shape[2:]), new_group_states)
    if tail:
        tail_states = jax.tree_util.tree_map(lambda s: s[n_groups * k:],
                                             cache["ssm"])

        def layer_body(x, lp_st):
            lp, st = lp_st
            h = apply_norm(lp["norm"], x, cfg.norm, cfg.norm_eps)
            y, st = mamba_mod.apply_mamba_decode(lp["mamba"], cfg, h, st)
            return x + y, st

        x, new_tail = jax.lax.scan(layer_body, x, (params["tail"],
                                                   tail_states))
        new_cache["ssm"] = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), flat_states,
            new_tail)
    else:
        new_cache["ssm"] = flat_states

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x @ params["lm_head"], new_cache
