"""Decoder-only LM assembly for the dense / moe / mla_moe families.

Scan-over-layers with stacked parameters throughout: the whole depth
compiles as one while loop (constant compile time in n_layers — essential
for the 512-device dry-run) and the roofline harness multiplies loop-body
costs by the annotated trip count.

Public surface (used by training/, serving/, launch/):
    init_params(cfg, key)                      -> params pytree
    forward(params, cfg, tokens)               -> logits [+ aux]
    prefill(params, cfg, tokens)               -> logits, cache
    init_decode_cache(cfg, batch, max_len)     -> cache pytree
    decode_step(params, cfg, cache, tokens)    -> logits, cache
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.attention import (apply_attention,
                                    apply_attention_decode_paged,
                                    apply_attention_decode_ring,
                                    init_attention, _qkv)
from repro.models.config import ModelConfig
from repro.models.layers import (apply_mlp, apply_norm, dtype_of, embed_init,
                                 init_mlp, init_norm, dense_init)


# ------------------------------------------------------------------- helpers
def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def layer_slice(stacked, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


# ---------------------------------------------------------------------- init
def _init_layer(key, cfg: ModelConfig, dtype, moe: bool):
    k_attn, k_mlp = jax.random.split(key)
    p = {"norm1": init_norm(cfg.d_model, cfg.norm),
         "norm2": init_norm(cfg.d_model, cfg.norm)}
    if cfg.family == "mla_moe":
        p["attn"] = mla_mod.init_mla(k_attn, cfg, dtype)
    else:
        p["attn"] = init_attention(k_attn, cfg, dtype)
    if moe:
        p["moe"] = moe_mod.init_moe(k_mlp, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.act, dtype,
                            bias=cfg.mlp_bias)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    n_dense, n_moe = _layer_split(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                       dtype)
    if n_dense:
        params["dense_layers"] = _stack(
            [_init_layer(keys[2 + i], cfg, dtype, moe=False)
             for i in range(n_dense)])
    if n_moe:
        params["moe_layers"] = _stack(
            [_init_layer(keys[2 + n_dense + i], cfg, dtype, moe=True)
             for i in range(n_moe)])
    if cfg.mtp_depth:
        params["mtp"] = _stack(
            [_init_layer(keys[2 + cfg.n_layers + 0], cfg, dtype,
                         moe=(cfg.n_experts > 0))
             for _ in range(cfg.mtp_depth)])
    return params


def _layer_split(cfg: ModelConfig) -> tuple[int, int]:
    """(#dense-mlp layers, #moe layers) — deepseek has first_k_dense."""
    if cfg.family == "dense":
        return cfg.n_layers, 0
    if cfg.family == "moe":
        return 0, cfg.n_layers
    if cfg.family == "mla_moe":
        return cfg.first_k_dense, cfg.n_layers - cfg.first_k_dense
    raise ValueError(cfg.family)


# ------------------------------------------------------------- layer bodies
def _apply_layer(lp, cfg: ModelConfig, x, positions, moe: bool,
                 q_chunk: int, kv_chunk: int, return_kv: bool):
    h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    if cfg.family == "mla_moe":
        attn_out = mla_mod.apply_mla(lp["attn"], cfg, h, positions,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk)
        kv = None
    else:
        res = apply_attention(lp["attn"], cfg, h, positions, q_chunk=q_chunk,
                              kv_chunk=kv_chunk, return_kv=return_kv)
        attn_out, kv = res if return_kv else (res, None)
    x = constrain(x + attn_out, "batch", "seq", "embed")
    h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    if moe:
        y, aux = moe_mod.apply_moe(lp["moe"], cfg, h)
    else:
        y, aux = apply_mlp(lp["mlp"], h, cfg.act), 0.0
    return constrain(x + y, "batch", "seq", "embed"), aux, kv


# -------------------------------------------------------------------- forward
def forward(params, cfg: ModelConfig, tokens, *, q_chunk: int = 512,
            kv_chunk: int = 512, collect_kv: bool = False,
            embeddings: Optional[jax.Array] = None, remat: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V) [, aux, kv_stack].

    ``embeddings`` overrides the token embedding (modality-frontend stub
    path for the VLM/audio archs — precomputed patch/frame embeddings).
    """
    x = params["embed"][tokens] if embeddings is None else embeddings
    x = constrain(x, "batch", "seq", "embed")
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = 0.0
    kv_stacks = {}

    for name, moe in (("dense_layers", False), ("moe_layers", True)):
        if name not in params:
            continue

        def body(carry, lp, moe=moe):
            x, aux = carry
            x, aux_l, kv = _apply_layer(lp, cfg, x, positions, moe,
                                        q_chunk, kv_chunk, collect_kv)
            return (x, aux + aux_l), kv

        if remat:
            body = jax.checkpoint(body)   # store layer boundaries only
        (x, aux_total), kv = jax.lax.scan(body, (x, aux_total), params[name])
        if collect_kv:
            kv_stacks[name] = kv

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if collect_kv:
        return logits, aux_total, kv_stacks
    return logits, aux_total


def loss_fn(params, cfg: ModelConfig, tokens, labels, *, q_chunk: int = 512,
            kv_chunk: int = 512, remat: bool = False):
    logits, aux = forward(params, cfg, tokens, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, remat=remat)
    from repro.models.losses import masked_xent
    return masked_xent(logits, labels, aux)


# ================================================================== decoding
def uses_ring(cfg: ModelConfig) -> bool:
    return cfg.sliding_window > 0


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None) -> dict:
    """Cache pytree for one-token decode.

    * full-attention archs: paged pools (L, P, page, KVH, hd) + page table;
    * SWA archs: ring buffers (L, B, W, KVH, hd) — the resident window;
    * MLA: paged latent pools (L, P, page, rkv/rope).
    All layouts include ``lengths`` (B,) of tokens seen so far.
    """
    dtype = dtype or dtype_of(cfg.dtype)
    L = cfg.n_layers
    cache: dict[str, Any] = {
        "lengths": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "mla_moe":
        ps = cfg.kv_page_tokens
        n_pages = batch * (-(-max_len // ps))
        cache["ckv_pool"] = jnp.zeros((L, n_pages, ps, cfg.kv_lora_rank), dtype)
        cache["krope_pool"] = jnp.zeros((L, n_pages, ps, cfg.qk_rope_head_dim),
                                        dtype)
        cache["page_table"] = _identity_page_table(batch, max_len, ps)
    elif uses_ring(cfg):
        W = cfg.sliding_window
        cache["k_ring"] = jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                                    dtype)
        cache["v_ring"] = jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.head_dim),
                                    dtype)
    else:
        ps = cfg.kv_page_tokens
        n_pages = batch * (-(-max_len // ps))
        cache["k_pool"] = jnp.zeros((L, n_pages, ps, cfg.n_kv_heads,
                                     cfg.head_dim), dtype)
        cache["v_pool"] = jnp.zeros((L, n_pages, ps, cfg.n_kv_heads,
                                     cfg.head_dim), dtype)
        cache["page_table"] = _identity_page_table(batch, max_len, ps)
    return cache


def _identity_page_table(batch: int, max_len: int, ps: int):
    per_seq = -(-max_len // ps)
    return (jnp.arange(batch * per_seq, dtype=jnp.int32)
            .reshape(batch, per_seq))


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """One decode step.  tokens: (B, 1) int32 -> (logits (B,1,V), cache)."""
    x = params["embed"][tokens]
    lengths = cache["lengths"] + 1
    new_cache = dict(cache, lengths=lengths)
    layer_idx = 0

    for name, moe in (("dense_layers", False), ("moe_layers", True)):
        if name not in params:
            continue
        n = jax.tree_util.tree_leaves(params[name])[0].shape[0]

        if cfg.family == "mla_moe":
            pools = (new_cache["ckv_pool"][layer_idx:layer_idx + n],
                     new_cache["krope_pool"][layer_idx:layer_idx + n])

            def body(x, inp, moe=moe):
                lp, ckv, krope = inp
                h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
                attn, ckv, krope = mla_mod.apply_mla_decode_paged(
                    lp["attn"], cfg, h, ckv, krope, cache["page_table"],
                    lengths)
                x = x + attn
                h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
                if moe:
                    y, _ = moe_mod.apply_moe(lp["moe"], cfg, h, dropless=True)
                else:
                    y = apply_mlp(lp["mlp"], h, cfg.act)
                return x + y, (ckv, krope)

            x, (ckv_new, krope_new) = jax.lax.scan(
                body, x, (params[name],) + pools)
            new_cache["ckv_pool"] = (new_cache["ckv_pool"]
                                     .at[layer_idx:layer_idx + n].set(ckv_new))
            new_cache["krope_pool"] = (new_cache["krope_pool"]
                                       .at[layer_idx:layer_idx + n]
                                       .set(krope_new))
        elif uses_ring(cfg):
            rings = (new_cache["k_ring"][layer_idx:layer_idx + n],
                     new_cache["v_ring"][layer_idx:layer_idx + n])

            def body(x, inp, moe=moe):
                lp, kr, vr = inp
                h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
                attn, kr, vr = apply_attention_decode_ring(
                    lp["attn"], cfg, h, kr, vr, lengths)
                x = x + attn
                h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
                if moe:
                    y, _ = moe_mod.apply_moe(lp["moe"], cfg, h, dropless=True)
                else:
                    y = apply_mlp(lp["mlp"], h, cfg.act)
                return x + y, (kr, vr)

            x, (k_new, v_new) = jax.lax.scan(body, x, (params[name],) + rings)
            new_cache["k_ring"] = (new_cache["k_ring"]
                                   .at[layer_idx:layer_idx + n].set(k_new))
            new_cache["v_ring"] = (new_cache["v_ring"]
                                   .at[layer_idx:layer_idx + n].set(v_new))
        else:
            pools = (new_cache["k_pool"][layer_idx:layer_idx + n],
                     new_cache["v_pool"][layer_idx:layer_idx + n])

            def body(x, inp, moe=moe):
                lp, kp, vp = inp
                h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
                attn, kp, vp = apply_attention_decode_paged(
                    lp["attn"], cfg, h, kp, vp, cache["page_table"], lengths)
                x = x + attn
                h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
                if moe:
                    y, _ = moe_mod.apply_moe(lp["moe"], cfg, h, dropless=True)
                else:
                    y = apply_mlp(lp["mlp"], h, cfg.act)
                return x + y, (kp, vp)

            x, (k_new, v_new) = jax.lax.scan(body, x, (params[name],) + pools)
            new_cache["k_pool"] = (new_cache["k_pool"]
                                   .at[layer_idx:layer_idx + n].set(k_new))
            new_cache["v_pool"] = (new_cache["v_pool"]
                                   .at[layer_idx:layer_idx + n].set(v_new))
        layer_idx += n

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_cache


def prefill(params, cfg: ModelConfig, tokens, *, q_chunk: int = 512,
            kv_chunk: int = 512):
    """Prefill pass: logits + per-layer K/V to be packed into the pools."""
    return forward(params, cfg, tokens, q_chunk=q_chunk, kv_chunk=kv_chunk,
                   collect_kv=(cfg.family != "mla_moe"))
