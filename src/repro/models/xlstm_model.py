"""xLSTM LM assembly: mLSTM blocks with sLSTM blocks at ``slstm_at``.

Stacked-scan over the mLSTM majority; the (few) sLSTM blocks are applied
at their configured positions between scan segments.  Attention-free:
decode carries fixed-size recurrent state only (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import xlstm as cells
from repro.models.config import ModelConfig
from repro.models.decoder import _stack
from repro.models.layers import (apply_norm, dense_init, dtype_of, embed_init,
                                 init_norm)


def segments(cfg: ModelConfig):
    """Split layer indices into alternating (mlstm-run, slstm) segments."""
    sl = sorted(cfg.slstm_at)
    segs = []
    start = 0
    for s in sl:
        segs.append(("m", start, s))      # mlstm layers [start, s)
        segs.append(("s", s, s + 1))
        start = s + 1
    segs.append(("m", start, cfg.n_layers))
    return [x for x in segs if x[2] > x[1]]


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = dtype_of(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 2)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
        "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype),
    }
    for si, (kind, a, b) in enumerate(segments(cfg)):
        if kind == "m":
            layers = [{"norm": init_norm(cfg.d_model, cfg.norm),
                       "cell": cells.init_mlstm(keys[2 + i], cfg, dtype)}
                      for i in range(a, b)]
            params[f"seg{si}"] = _stack(layers)
        else:
            params[f"seg{si}"] = {
                "norm": init_norm(cfg.d_model, cfg.norm),
                "cell": cells.init_slstm(keys[2 + a], cfg, dtype)}
    return params


def forward(params, cfg: ModelConfig, tokens, *, embeddings=None,
            remat: bool = False, **_):
    x = params["embed"][tokens] if embeddings is None else embeddings

    for si, (kind, a, b) in enumerate(segments(cfg)):
        sp = params[f"seg{si}"]
        if kind == "m":
            def body(x, lp):
                h = apply_norm(lp["norm"], x, cfg.norm, cfg.norm_eps)
                return x + cells.apply_mlstm(lp["cell"], cfg, h), None
            if remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, sp)
        else:
            h = apply_norm(sp["norm"], x, cfg.norm, cfg.norm_eps)
            x = x + cells.apply_slstm(sp["cell"], cfg, h)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x @ params["lm_head"], 0.0


def loss_fn(params, cfg: ModelConfig, tokens, labels, **kw):
    logits, aux = forward(params, cfg, tokens, **kw)
    from repro.models.losses import masked_xent
    return masked_xent(logits, labels, aux)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int = 0,
                      dtype=None) -> dict:
    cache: dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
    for si, (kind, a, b) in enumerate(segments(cfg)):
        if kind == "m":
            st = cells.init_mlstm_state(cfg, batch)
            cache[f"seg{si}"] = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (b - a,) + x.shape).copy(), st)
        else:
            cache[f"seg{si}"] = cells.init_slstm_state(cfg, batch)
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens):
    x = params["embed"][tokens]
    new_cache = dict(cache, lengths=cache["lengths"] + 1)

    for si, (kind, a, b) in enumerate(segments(cfg)):
        sp = params[f"seg{si}"]
        if kind == "m":
            def body(x, inp):
                lp, st = inp
                h = apply_norm(lp["norm"], x, cfg.norm, cfg.norm_eps)
                y, st = cells.apply_mlstm_decode(lp["cell"], cfg, h, st)
                return x + y, st
            x, new_st = jax.lax.scan(body, x, (sp, cache[f"seg{si}"]))
            new_cache[f"seg{si}"] = new_st
        else:
            h = apply_norm(sp["norm"], x, cfg.norm, cfg.norm_eps)
            y, st = cells.apply_slstm_decode(sp["cell"], cfg, h,
                                             cache[f"seg{si}"])
            x = x + y
            new_cache[f"seg{si}"] = st
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return x @ params["lm_head"], new_cache
