"""xLSTM blocks: mLSTM (matrix memory, pre-up-projection) and sLSTM
(scalar memory with recurrent gate connections, post-up-projection).

Attention-free: decode carries a per-layer fixed-size state instead of a
KV cache.  In thesis terms the whole state is the *resident set* — there
are no pages to fault on during decode, making xLSTM the degenerate case
for the paging technique (DESIGN.md §4): only the optimizer-state/weight
paging applies.  The mLSTM matrix state (H heads × d_k × d_v) is still
large enough that the serving engine block-pages *it* host↔HBM between
requests.

Recurrences (stabilized, per head):
    mLSTM:  m_t = max(f̃ + m_{t-1}, ĩ);   C_t = e^{f̃+m_{t-1}-m_t} C_{t-1}
            + e^{ĩ-m_t} k_t v_tᵀ;  n_t likewise;  h = Cᵀq / max(|nᵀq|, 1)
    sLSTM:  c_t = σ(f) c_{t-1} + e^{ĩ-m_t} z_t;  gates see h_{t-1} through
            block-diagonal recurrent weights R.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_norm, apply_norm


# ================================================================== mLSTM
def mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.d_model * cfg.mlstm_proj_factor)
    nh = cfg.n_heads
    dk = d_in // nh
    return d_in, nh, dk


def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, nh, dk = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], d, 2 * d_in, dtype),
        "wq": dense_init(ks[1], d_in, d_in, dtype),
        "wk": dense_init(ks[2], d_in, d_in, dtype),
        "wv": dense_init(ks[3], d_in, d_in, dtype),
        "w_if": dense_init(ks[4], d_in, 2 * nh, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "wo_gate": dense_init(ks[5], d_in, d_in, dtype),
        "skip": dense_init(ks[6], d_in, d_in, dtype),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "down": dense_init(ks[7], d_in, d, dtype),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_in, nh, dk = mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, nh, dk, dk), jnp.float32),
            "n": jnp.zeros((batch, nh, dk), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def _mlstm_cell(carry, inp):
    C, n, m = carry
    q, k, v, i_pre, f_pre = inp            # (B,nh,dk) ×3, (B,nh) ×2
    f_log = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_log + m, i_pre)
    f_eff = jnp.exp(f_log + m - m_new)
    i_eff = jnp.exp(i_pre - m_new)
    C_new = f_eff[..., None, None] * C \
        + i_eff[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = f_eff[..., None] * n + i_eff[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C_new, q)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q))
    h = num / jnp.maximum(den, 1.0)[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_sequence(p, cfg, x_in, chunk: int = 64):
    """x_in: (B, S, d_in) -> h: (B, S, d_in).

    Per-token recurrence organized as scan-over-chunks with a checkpointed
    chunk body: the backward pass stores only chunk-boundary (C, n, m)
    states (S/chunk of them) and recomputes inside — without this, AD of
    the token scan would save the matrix memory at every step
    (S × nh × dk² floats — infeasible at 4k/32k training lengths).
    """
    B, S, d_in = x_in.shape
    _, nh, dk = mlstm_dims(cfg)
    q = (x_in @ p["wq"]).reshape(B, S, nh, dk).astype(jnp.float32)
    k = ((x_in @ p["wk"]) / jnp.sqrt(dk)).reshape(B, S, nh, dk).astype(jnp.float32)
    v = (x_in @ p["wv"]).reshape(B, S, nh, dk).astype(jnp.float32)
    gates = x_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)        # (B,S,nh)

    Q = min(chunk, S)
    pad = (-S) % Q
    def padt(a):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        return a
    q, k, v = padt(q), padt(k), padt(v)
    i_pre, f_pre = padt(i_pre), padt(f_pre)
    nc = (S + pad) // Q

    def to_chunks(a):   # (B, nc*Q, ...) -> (nc, Q, B, ...)
        return a.reshape((B, nc, Q) + a.shape[2:]).transpose(
            (1, 2, 0) + tuple(range(3, a.ndim + 1)))

    xs = tuple(to_chunks(a) for a in (q, k, v, i_pre, f_pre))

    @jax.checkpoint
    def chunk_body(carry, inp):
        def step(c, token):
            return _mlstm_cell(c, token)
        carry, hs = jax.lax.scan(step, carry, inp)
        return carry, hs

    st = init_mlstm_state(cfg, B)
    _, hs = jax.lax.scan(chunk_body, (st["C"], st["n"], st["m"]), xs)
    # (nc, Q, B, nh, dk) -> (B, S, d_in)
    hs = hs.transpose(2, 0, 1, 3, 4).reshape(B, nc * Q, d_in)
    return hs[:, :S]


def apply_mlstm(p, cfg: ModelConfig, x):
    """Pre-up-projection mLSTM block body (x already normed): (B,S,d)->..."""
    up = x @ p["up"]
    d_in = up.shape[-1] // 2
    x_in, z = up[..., :d_in], up[..., d_in:]
    h = _mlstm_sequence(p, cfg, x_in).astype(x.dtype)
    o = jax.nn.sigmoid(x_in @ p["wo_gate"])
    h = apply_norm({"scale": p["norm_scale"]}, h + x_in @ p["skip"], "rms",
                   cfg.norm_eps)
    h = h * o * jax.nn.silu(z)
    return h @ p["down"]


def apply_mlstm_decode(p, cfg: ModelConfig, x, state):
    """x: (B,1,d) -> (y, state)."""
    B = x.shape[0]
    d_in, nh, dk = mlstm_dims(cfg)
    up = x[:, 0] @ p["up"]
    x_in, z = up[..., :d_in], up[..., d_in:]
    q = (x_in @ p["wq"]).reshape(B, nh, dk).astype(jnp.float32)
    k = ((x_in @ p["wk"]) / jnp.sqrt(dk)).reshape(B, nh, dk).astype(jnp.float32)
    v = (x_in @ p["wv"]).reshape(B, nh, dk).astype(jnp.float32)
    gates = x_in.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)
    (C, n, m), h = _mlstm_cell((state["C"], state["n"], state["m"]),
                               (q, k, v, i_pre, f_pre))
    h = h.reshape(B, d_in).astype(x.dtype)
    o = jax.nn.sigmoid(x_in @ p["wo_gate"])
    h = apply_norm({"scale": p["norm_scale"]}, h + x_in @ p["skip"], "rms",
                   cfg.norm_eps)
    h = h * o * jax.nn.silu(z)
    return (h @ p["down"])[:, None, :], {"C": C, "n": n, "m": m}


# ================================================================== sLSTM
def slstm_dims(cfg: ModelConfig):
    nh = cfg.n_heads
    ph = cfg.d_model // nh
    return nh, ph


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    nh, ph = slstm_dims(cfg)
    f_up = int(d * cfg.slstm_proj_factor)
    ks = jax.random.split(key, 4)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, jnp.float32),
        "r_gates": (jax.random.normal(ks[1], (4, nh, ph, ph), jnp.float32)
                    / jnp.sqrt(ph)),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "norm_scale": jnp.ones((d,), jnp.float32),
        "ffn_wi": dense_init(ks[2], d, f_up, dtype),
        "ffn_wo": dense_init(ks[3], f_up, d, dtype),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def _slstm_cell(p, cfg, carry, pre_t):
    c, n, h, m = carry
    B = c.shape[0]
    nh, ph = slstm_dims(cfg)
    d = c.shape[-1]
    rec = jnp.einsum("bhp,ghpq->bghq", h.reshape(B, nh, ph),
                     p["r_gates"]).reshape(B, 4 * d)
    g = pre_t + rec
    zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    f_log = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(f_log + m, ii)
    c_new = jnp.exp(f_log + m - m_new) * c + jnp.exp(ii - m_new) * z
    n_new = jnp.exp(f_log + m - m_new) * n + jnp.exp(ii - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def apply_slstm(p, cfg: ModelConfig, x, chunk: int = 64):
    """(B, S, d) -> (B, S, d): recurrent scan + post-up FFN.

    Chunk-checkpointed like the mLSTM: backward stores only chunk-boundary
    states.
    """
    B, S, d = x.shape
    pre = x.astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    st = init_slstm_state(cfg, B)
    Q = min(chunk, S)
    pad = (-S) % Q
    pre_p = jnp.pad(pre, ((0, 0), (0, pad), (0, 0))) if pad else pre
    nc = (S + pad) // Q
    xs = pre_p.reshape(B, nc, Q, -1).transpose(1, 2, 0, 3)

    @jax.checkpoint
    def chunk_body(carry, inp):
        def step(c, pre_t):
            return _slstm_cell(p, cfg, c, pre_t)
        return jax.lax.scan(step, carry, inp)

    (_, _, _, _), hs = jax.lax.scan(chunk_body,
                                    (st["c"], st["n"], st["h"], st["m"]), xs)
    h = hs.transpose(2, 0, 1, 3).reshape(B, nc * Q, d)[:, :S]
    h = apply_norm({"scale": p["norm_scale"]}, h, "rms", cfg.norm_eps)
    h = h.astype(x.dtype)
    return jax.nn.gelu(h @ p["ffn_wi"]) @ p["ffn_wo"]


def apply_slstm_decode(p, cfg: ModelConfig, x, state):
    pre = x[:, 0].astype(jnp.float32) @ p["w_gates"] + p["b_gates"]
    (c, n, h, m), h_out = _slstm_cell(
        p, cfg, (state["c"], state["n"], state["h"], state["m"]), pre)
    y = apply_norm({"scale": p["norm_scale"]}, h_out, "rms", cfg.norm_eps)
    y = y.astype(x.dtype)
    y = jax.nn.gelu(y @ p["ffn_wi"]) @ p["ffn_wo"]
    return y[:, None, :], {"c": c, "n": n, "h": h, "m": m}
