"""Mixture-of-Experts layer: capacity-based dispatch (Mesh-TF style).

Shardable either as EP (experts over the 'model' axis) or TP (expert FFN
hidden over 'model'); the partition rules in distributed/sharding.py pick
per architecture.  Top-k softmax routing + load-balancing auxiliary loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, f), jnp.float32)
               / jnp.sqrt(d)).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, d, f), jnp.float32)
               / jnp.sqrt(d)).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
               / jnp.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {"wi": dense_init(kss[0], d, fs, dtype),
                       "wg": dense_init(kss[1], d, fs, dtype),
                       "wo": dense_init(kss[2], fs, d, dtype)}
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.n_experts)
    return max(4, -(-c // 4) * 4)


GROUP_TOKENS = 4096     # dispatch group size (bounds the one-hot tensors)


def apply_moe(p, cfg: ModelConfig, x, *, dropless: bool = False):
    """x: (B, S, d) -> (y, aux_loss).

    ``dropless=True`` sizes expert capacity to the worst case (every token
    to one expert) — the serving/decode configuration, where dropping a
    token corrupts generation.  Training uses the capacity factor (Switch
    convention); overflowing tokens fall through the residual.

    Long sequences are dispatched in groups of ``GROUP_TOKENS`` (Mesh-TF
    convention): the (tokens × experts × capacity) one-hots stay bounded
    regardless of sequence length — prefill_32k would otherwise build a
    multi-TB dispatch tensor.
    """
    B, S, d = x.shape
    T_all = B * S
    if not dropless and T_all > GROUP_TOKENS:
        g = GROUP_TOKENS
        pad = (-T_all) % g
        xf = x.reshape(T_all, d)
        if pad:
            xf = jnp.pad(xf, ((0, pad), (0, 0)))
        groups = xf.reshape(-1, g, d)

        @jax.checkpoint
        def one(xg):
            y, aux = _moe_group(p, cfg, xg, dropless=False)
            return y, aux

        ys, auxs = jax.lax.map(one, groups)
        y = ys.reshape(-1, d)[:T_all].reshape(B, S, d)
        return y, jnp.mean(auxs)
    y, aux = _moe_group(p, cfg, x.reshape(T_all, d), dropless=dropless)
    return y.reshape(B, S, d), aux


def _moe_group(p, cfg: ModelConfig, xf, *, dropless: bool):
    """xf: (T, d) -> (y (T, d), aux)."""
    d = xf.shape[-1]
    E, k = cfg.n_experts, cfg.experts_per_token
    T = xf.shape[0]
    C = T if dropless else _capacity(T, cfg)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, k)                  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # position-in-expert via sequential cumsum over the k routing choices
    dispatch = jnp.zeros((T, E, C), xf.dtype)
    combine = jnp.zeros((T, E, C), jnp.float32)
    fill = jnp.zeros((E,), jnp.int32)
    for choice in range(k):
        onehot = jax.nn.one_hot(sel[:, choice], E, dtype=jnp.int32)  # (T, E)
        pos = jnp.cumsum(onehot, axis=0) - 1 + fill[None, :]
        fill = fill + onehot.sum(axis=0)
        within = (pos < C) & (onehot > 0)
        pos_c = jnp.clip(pos, 0, C - 1)
        slot = jax.nn.one_hot(pos_c, C, dtype=xf.dtype) * within[..., None]
        dispatch = dispatch + slot.astype(xf.dtype)
        combine = combine + slot.astype(jnp.float32) \
            * gate_vals[:, choice][:, None, None]

    expert_in = jnp.einsum("tec,td->ecd", dispatch, xf)
    expert_in = constrain(expert_in, "experts", "capacity", "embed")
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["wi"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])
    h = constrain(h, "experts", "capacity", "moe_ff")
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    expert_out = constrain(expert_out, "experts", "capacity", "embed")
    y = jnp.einsum("tec,ecd->td", combine.astype(xf.dtype), expert_out)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * router_prob) * E * cfg.router_aux_coef

    if "shared" in p:
        sp = p["shared"]
        y = y + (jax.nn.silu(xf @ sp["wi"]) * (xf @ sp["wg"])) @ sp["wo"]
    return y, aux
