"""Multi-head Latent Attention (DeepSeek-V3) with a paged *latent* cache.

The KV cache stores only the compressed latent ``c_kv`` (kv_lora_rank) and
the shared RoPE key (qk_rope_head_dim) per token — 576 dims/token for the
assigned config instead of n_heads × (d_k + d_v).  This makes MLA the
best-case architecture for the thesis' paged-memory technique: the latent
pages are small, uniform, and read through the page table exactly like the
GQA pool (DESIGN.md §4).

Decode uses the *absorbed* form: W_UK is folded into the query and W_UV
into the output so attention runs entirely in latent space and never
expands per-head keys/values for the context.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.attention_ops import NEG_INF, flash_attention_xla
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, init_norm, apply_norm


def init_mla(key, cfg: ModelConfig, dtype):
    d, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, rq, dtype),
        "q_norm": init_norm(rq),
        "wq_b": dense_init(ks[1], rq, H * (nope + rope), dtype),
        "wkv_a": dense_init(ks[2], d, rkv + rope, dtype),
        "kv_norm": init_norm(rkv),
        "wk_b": dense_init(ks[3], rkv, H * nope, dtype),
        "wv_b": dense_init(ks[4], rkv, H * vh, dtype),
        "wo": dense_init(ks[5], H * vh, d, dtype),
    }


def _latents(p, cfg: ModelConfig, x, positions):
    """Shared projection path: q heads + (c_kv, k_rope) latents."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = apply_norm(p["q_norm"], x @ p["wq_a"], "rms", cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = x @ p["wkv_a"]
    c_kv = apply_norm(p["kv_norm"], kv[..., :cfg.kv_lora_rank], "rms",
                      cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]       # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def apply_mla(p, cfg: ModelConfig, x, positions, *, q_chunk=512, kv_chunk=512):
    """Training / prefill: expand per-head K/V and run flash attention."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, nope)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, vh)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, H, rope))], axis=-1)
    # pad v to the qk head_dim so flash kernels see one head size; strip after
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rope - vh)))
    out = flash_attention_xla(q, k, v_p, causal=True, q_chunk=q_chunk,
                              kv_chunk=kv_chunk)[..., :vh]
    return out.reshape(B, S, H * vh) @ p["wo"]


def _mla_update_and_attend(q_abs, q_rope, c_new, kr_new, ckv_pool,
                           krope_pool, page_table, lengths, *, scale: float):
    """Pool write + absorbed-latent page scan (shard_map-able body)."""
    B, H, rkv = q_abs.shape
    ps = ckv_pool.shape[1]
    pos = lengths - 1
    page_slot = pos // ps
    offset = pos % ps
    frame = jnp.take_along_axis(page_table, page_slot[:, None], axis=1)[:, 0]
    frame = jnp.maximum(frame, 0)
    ckv_pool = ckv_pool.at[frame, offset[0]].set(c_new)
    krope_pool = krope_pool.at[frame, offset[0]].set(kr_new)
    max_pages = page_table.shape[1]

    def page_step(carry, j):
        m, l, acc = carry
        idx = page_table[:, j]
        safe = jnp.maximum(idx, 0)
        c_pg = ckv_pool[safe].astype(jnp.float32)             # (B, ps, rkv)
        r_pg = krope_pool[safe].astype(jnp.float32)           # (B, ps, rope)
        s = (jnp.einsum("bhr,bkr->bhk", q_abs, c_pg)
             + jnp.einsum("bhr,bkr->bhk", q_rope.astype(jnp.float32), r_pg))
        s = s * scale
        posk = j * ps + jnp.arange(ps)
        valid = (posk[None, :] < lengths[:, None]) & (idx >= 0)[:, None]
        s = jnp.where(valid[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        pw = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pw.sum(axis=-1)
        ctx = jnp.einsum("bhk,bkr->bhr", pw, c_pg)
        acc_new = acc * corr[..., None] + ctx
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    a0 = jnp.zeros((B, H, rkv), jnp.float32)
    (m, l, ctx), _ = jax.lax.scan(page_step, (m0, l0, a0),
                                  jnp.arange(max_pages))
    ctx = ctx / jnp.maximum(l[..., None], 1e-30)              # (B, H, rkv)
    return ctx, ckv_pool, krope_pool


def _mla_update_and_attend_dist(q_abs, q_rope, c_new, kr_new, ckv_pool,
                                krope_pool, page_table, lengths, *,
                                scale: float):
    """shard_map variant: batch+pages co-sharded over the data axes, query
    heads split over 'model' (the latent pools have no head dim — they
    transit the region replicated over 'model', one layer slice at a time).
    Same locality argument as the GQA path (EXPERIMENTS.md §Perf iter. 5).
    """
    from repro.compat import import_shard_map
    shard_map = import_shard_map()
    from jax.sharding import PartitionSpec as P
    import numpy as _np
    from repro.distributed import logical

    mesh = logical.current_mesh()
    daxes = logical.rule("batch")
    B, H, _ = q_abs.shape
    P_pages = ckv_pool.shape[0]
    if mesh is None or daxes is None:
        return _mla_update_and_attend(q_abs, q_rope, c_new, kr_new, ckv_pool,
                                      krope_pool, page_table, lengths,
                                      scale=scale)
    axes = daxes if isinstance(daxes, tuple) else (daxes,)
    dsize = int(_np.prod([mesh.shape[a] for a in axes]))
    if dsize <= 1 or B % dsize or P_pages % dsize:
        return _mla_update_and_attend(q_abs, q_rope, c_new, kr_new, ckv_pool,
                                      krope_pool, page_table, lengths,
                                      scale=scale)
    p_local = P_pages // dsize
    msize = mesh.shape.get("model", 1)
    h = "model" if ("model" in mesh.shape and H % msize == 0) else None

    def local_fn(qa, qr, cn, kn, cp, kp, pt, ln):
        rank = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        pt_local = jnp.where(pt >= 0, pt - rank * p_local, pt)
        return _mla_update_and_attend(qa, qr, cn, kn, cp, kp, pt_local, ln,
                                      scale=scale)

    d = daxes
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(d, h), P(d, h), P(d), P(d), P(d), P(d), P(d), P(d)),
        out_specs=(P(d, h), P(d), P(d)),
        check_vma=False)
    return fn(q_abs, q_rope, c_new, kr_new, ckv_pool, krope_pool,
              page_table, lengths)


def apply_mla_decode_paged(p, cfg: ModelConfig, x, ckv_pool, krope_pool,
                           page_table, lengths):
    """Absorbed-form decode through the paged latent cache.

    ckv_pool:   (P, page_tokens, kv_lora_rank)
    krope_pool: (P, page_tokens, qk_rope_head_dim)
    Returns (out, ckv_pool, krope_pool).
    """
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    pos = lengths - 1
    q_nope, q_rope, c_kv, k_rope = _latents(p, cfg, x, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]               # (B,H,*)
    c_new, kr_new = c_kv[:, 0], k_rope[:, 0]

    # absorb W_UK into q:  q_abs (B,H,rkv)
    wk_b = p["wk_b"].reshape(rkv, H, nope)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = 1.0 / math.sqrt(nope + rope)
    ctx, ckv_pool, krope_pool = _mla_update_and_attend_dist(
        q_abs, q_rope.astype(jnp.float32), c_new, kr_new, ckv_pool,
        krope_pool, page_table, lengths, scale=scale)
    wv_b = p["wv_b"].reshape(rkv, H, vh)
    out = jnp.einsum("bhr,rhv->bhv", ctx, wv_b.astype(jnp.float32))
    out = out.reshape(B, H * vh).astype(x.dtype) @ p["wo"]
    return out[:, None, :], ckv_pool, krope_pool
