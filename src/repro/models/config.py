"""Architecture configuration dataclass shared by all model families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | mla_moe | hybrid | xlstm | encdec
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 256
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qk_norm: bool = False        # qwen3 / chameleon style per-head norm
    sliding_window: int = 0      # 0 = full attention (SWA otherwise)
    norm: str = "rms"            # rms | ln
    act: str = "silu"            # silu (SwiGLU) | gelu (plain MLP)
    attn_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ---- MoE ----
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0       # deepseek: first k layers are dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # ---- MLA (DeepSeek-V3) ----
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0           # multi-token prediction modules

    # ---- SSM (Mamba2) / hybrid (Zamba2) ----
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0          # hybrid: shared attn block after every k SSM blocks

    # ---- xLSTM ----
    slstm_at: Tuple[int, ...] = ()
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333333

    # ---- encoder-decoder (Whisper) ----
    n_enc_layers: int = 0
    max_source_positions: int = 1500
    max_target_positions: int = 448

    # ---- serving ----
    kv_page_tokens: int = 256    # tokens per KV page (VMEM-friendly tile)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))

    # ------------------------------------------------------------- helpers
    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "xlstm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        return self.family in ("hybrid", "xlstm") or self.sliding_window > 0

    @property
    def group_size(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline cross-checks)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense",):
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            mlp = 3 * d * f if self.act == "silu" else 2 * d * f
            return emb + L * (attn + mlp + 2 * d) + d
        if self.family == "moe":
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            expert = 3 * d * self.moe_d_ff
            return emb + L * (attn + self.n_experts * expert
                              + d * self.n_experts + 2 * d) + d
        if self.family == "mla_moe":
            r_q, r_kv = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vh = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
            H = self.n_heads
            attn = (d * r_q + r_q * H * (nope + rope)
                    + d * (r_kv + rope) + r_kv * H * (nope + vh)
                    + H * vh * d)
            expert = 3 * d * self.moe_d_ff
            dense_mlp = 3 * d * f
            moe_layers = L - self.first_k_dense
            return emb + L * (attn + 2 * d) \
                + self.first_k_dense * dense_mlp \
                + moe_layers * ((self.n_experts + self.n_shared_experts)
                                * expert + d * self.n_experts)
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            mamba = (d * (2 * d_in + 2 * self.ssm_state + nh)   # in_proj
                     + self.ssm_conv * (d_in + 2 * self.ssm_state)
                     + nh + nh                                   # A_log, D
                     + d_in * d)                                 # out_proj
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            shared = attn + 3 * d * self.d_ff + 2 * d
            return emb + L * (mamba + d) + shared + d
        if self.family == "xlstm":
            pf = self.mlstm_proj_factor
            d_in = int(d * pf)
            n_m = L - len(self.slstm_at)
            n_s = len(self.slstm_at)
            mlstm = d * 2 * d_in + 3 * d_in * d_in // 4 + d_in * d  # approx
            slstm = 4 * d * d + d * int(d * self.slstm_proj_factor) * 2
            return emb + n_m * mlstm + n_s * slstm + L * 2 * d + d
        if self.family == "encdec":
            attn = 4 * d * d
            mlp = 2 * d * f
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec = L * (2 * attn + mlp + 3 * d)
            return emb + enc + dec + d
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE activates top-k experts."""
        if self.family == "moe":
            total = self.param_count()
            inactive = (self.n_experts - self.experts_per_token) \
                * 3 * self.d_model * self.moe_d_ff * self.n_layers
            return total - inactive
        if self.family == "mla_moe":
            total = self.param_count()
            moe_layers = self.n_layers - self.first_k_dense
            inactive = (self.n_experts - self.experts_per_token) \
                * 3 * self.d_model * self.moe_d_ff * moe_layers
            return total - inactive
        return self.param_count()


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        first_k_dense=min(cfg.first_k_dense, 1),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_head_dim=16 if cfg.qk_nope_head_dim else 0,
        qk_rope_head_dim=8 if cfg.qk_rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        slstm_at=(1,) if cfg.slstm_at else (),
        n_enc_layers=min(cfg.n_enc_layers, 2),
        max_source_positions=16 if cfg.is_encdec else cfg.max_source_positions,
        mtp_depth=0,
        kv_page_tokens=16,
        capacity_factor=2.0,   # dropless at smoke-test sizes (decode parity)
        dtype="float32",
    )
    if cfg.family == "hybrid":
        base["n_layers"] = 5   # 2 groups of 2 + tail, exercises shared attn
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
