"""Memory-efficient attention in pure JAX (the XLA fallback data plane).

Three implementations, all GQA-aware, fp32 accumulation:

* :func:`mha_reference` — materializes the score matrix; the oracle for
  tests and the Pallas kernels' ``ref.py``.
* :func:`flash_attention_xla` — double-chunked online-softmax attention
  (scan over q chunks × scan over kv chunks).  This is what prefill_32k
  lowers to when the Pallas kernel is disabled: peak memory is
  O(q_chunk × kv_chunk) instead of O(S²).
* :func:`paged_attention_xla` — decode attention that reads K/V through a
  **page table** (the virtual-address access of the thesis, on the KV
  cache): scan over page slots, gathering one page per step from the frame
  pool.  Never materializes the (B, S) context.

Sliding-window (SWA) masking supported everywhere — the window is what
bounds the *resident* page set for long_500k (DESIGN.md §4).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_split(q, n_kv: int):
    """(B, S, H, D) -> (B, S, KVH, G, D)."""
    B, S, H, D = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, D)


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0, lengths=None):
    """Materializing attention oracle.

    q: (B, Sq, H, D); k, v: (B, Sk, KVH, D); q_offset: absolute position of
    q[0] (for decode, q_offset = context_len - Sq).  lengths: (B,) valid
    prefix of k/v.
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    qh = _gqa_split(q, KVH).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qh, kf) / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    mask = mask[None, None, None]
    if lengths is not None:
        mask = mask & (k_pos[None, :] < lengths[:, None])[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def _pad_to(x, axis: int, multiple: int):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def flash_attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        q_offset: int = 0):
    """Chunked flash attention (pure lax.scan over KV, no Pallas).

    q: (B, Sq, H, D); k, v: (B, Sk, KVH, D).

    Structure chosen for GSPMD friendliness (see DESIGN.md §3):
    * all q chunks are processed **in parallel** (the chunk axis folds into
      the batch of the einsum) so sequence-sharded q — context parallelism
      for archs whose head count does not divide the TP axis — actually
      runs data-parallel instead of serializing through a scan;
    * GQA expands K/V to the full head count **per KV chunk** (a (B, Ck,
      H, D) transient), keeping every einsum a plain 4-D MHA contraction:
      no 5-D grouped reshapes for GSPMD to re-layout, no contractions over
      a sharded head_dim (those all-reduce a score tile per chunk pair —
      the failure mode the first dry-run exposed).

    Peak live memory per kv step: one (B × Sq_local × H × kv_chunk) f32
    score tile.
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    kv_chunk = min(kv_chunk, k.shape[1])

    kp, Sk0 = _pad_to(k, 1, kv_chunk)
    vp, _ = _pad_to(v, 1, kv_chunk)
    nk = kp.shape[1] // kv_chunk

    kb = kp.reshape(B, nk, kv_chunk, KVH, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, kv_chunk, KVH, D).transpose(1, 0, 2, 3, 4)
    kv_pos = jnp.arange(kp.shape[1]).reshape(nk, kv_chunk)
    k_valid = (jnp.arange(kp.shape[1]) < Sk0).reshape(nk, kv_chunk)

    q_pos = q_offset + jnp.arange(Sq)
    qf = q.astype(jnp.float32) * scale

    def kv_step(carry, kv_inp):
        m, l, acc = carry
        kj, k_blk, v_blk = kv_inp                  # (B, Ck, KVH, D)
        if G > 1:   # GQA: expand to full heads for this chunk only
            k_blk = jnp.repeat(k_blk, G, axis=2)
            v_blk = jnp.repeat(v_blk, G, axis=2)
        kf = k_blk.astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)   # (B, H, Sq, Ck)
        k_p = kv_pos[kj]
        mask = k_valid[kj][None, :]
        if causal:
            mask = mask & (q_pos[:, None] >= k_p[None, :])
        if window > 0:
            mask = mask & ((q_pos[:, None] - k_p[None, :]) < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                  (jnp.arange(nk), kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)    # (B, H, Sq, D)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def paged_attention_xla(q, k_pool, v_pool, page_table, lengths, *,
                        window: int = 0):
    """Decode attention through a KV page table (one token per sequence).

    q:          (B, H, D)
    k/v_pool:   (P, page_tokens, KVH, D) — the shared frame pool
    page_table: (B, max_pages) int32, -1 = unmapped (a "page fault" at the
                runtime layer; the compiled step only ever sees resident
                frames — the serving engine guarantees it, thesis-style)
    lengths:    (B,) context length per sequence
    """
    B, H, D = q.shape
    P, ps, KVH, _ = k_pool.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    max_pages = page_table.shape[1]
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32) * scale

    def page_step(carry, j):
        m, l, acc = carry
        idx = page_table[:, j]                       # (B,)
        safe = jnp.maximum(idx, 0)
        k_pg = k_pool[safe].astype(jnp.float32)       # (B, ps, KVH, D)
        v_pg = v_pool[safe].astype(jnp.float32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_pg)   # (B, KVH, G, ps)
        pos = j * ps + jnp.arange(ps)                 # absolute positions
        valid = (pos[None, :] < lengths[:, None]) & (idx >= 0)[:, None]
        if window > 0:
            valid = valid & ((lengths[:, None] - 1 - pos[None, :]) < window)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p, v_pg)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(page_step, (m0, l0, a0),
                                  jnp.arange(max_pages))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, H, D).astype(q.dtype)


def ring_buffer_attention(q, k_ring, v_ring, cur_len, window: int):
    """Decode attention over a sliding-window ring buffer.

    q: (B, H, D); k/v_ring: (B, W, KVH, D); cur_len: (B,) tokens seen so
    far (ring holds the last min(cur_len, W) of them, written mod W).
    """
    B, H, D = q.shape
    W = k_ring.shape[1]
    KVH = k_ring.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_ring.astype(jnp.float32))
    slot = jnp.arange(W)[None, :]
    n_valid = jnp.minimum(cur_len, W)[:, None]
    # slot w holds position p where p % W == w and p >= cur_len - n_valid
    valid = slot < n_valid * 0 + n_valid  # (B, W): slots 0..n_valid-1 used
    # when cur_len > W the ring wraps, but all W slots are valid
    valid = jnp.where(cur_len[:, None] >= W, jnp.ones_like(valid, bool), valid)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_ring.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
