"""Sharded-safe masked cross-entropy.

``take_along_axis(logits, labels)`` gathers on the vocab dim; under a
vocab-sharded lm_head GSPMD resolves that by **all-gathering the logits**
— (B, S, V) in f32, tens of GB per device at train_4k (measured in the
§Perf log).  The iota-mask formulation keeps every op elementwise or a
reduction over the sharded dim, which partitions cleanly:

    sel = Σ_v [v == label] · logit_v          (masked reduce, psum'd)
    lse = logsumexp_v(logits)                 (sharded reduce, psum'd)
    nll = lse - sel

Everything stays in the logits dtype until the per-token scalars.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_xent(logits, labels, aux=0.0):
    """logits: (B, S, V); labels: (B, S) int32 (-1 = masked)."""
    V = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot_mask = vocab_iota == jnp.maximum(labels, 0)[..., None]
    sel = jnp.sum(jnp.where(onehot_mask, lf, 0.0), axis=-1)
    nll = lse - sel
    mask = labels >= 0
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux
