"""Family → module dispatch.  Every family exposes the same surface:

    init_params(cfg, key)
    forward(params, cfg, tokens, **kw)        -> (logits, aux)
    loss_fn(params, cfg, tokens, labels)      -> scalar
    init_decode_cache(cfg, batch, max_len)    -> cache pytree
    decode_step(params, cfg, cache, tokens)   -> (logits, cache)
"""

from __future__ import annotations

from types import SimpleNamespace

import jax.numpy as jnp

from repro.models import decoder, encdec, hybrid, xlstm_model
from repro.models.config import ModelConfig


def _decoder_api():
    return SimpleNamespace(
        init_params=decoder.init_params,
        forward=decoder.forward,
        loss_fn=decoder.loss_fn,
        init_decode_cache=decoder.init_decode_cache,
        decode_step=decoder.decode_step,
    )


_FAMILIES = {
    "dense": _decoder_api(),
    "moe": _decoder_api(),
    "mla_moe": _decoder_api(),
    "hybrid": SimpleNamespace(
        init_params=hybrid.init_params,
        forward=hybrid.forward,
        loss_fn=hybrid.loss_fn,
        init_decode_cache=hybrid.init_decode_cache,
        decode_step=hybrid.decode_step,
    ),
    "xlstm": SimpleNamespace(
        init_params=xlstm_model.init_params,
        forward=xlstm_model.forward,
        loss_fn=xlstm_model.loss_fn,
        init_decode_cache=xlstm_model.init_decode_cache,
        decode_step=xlstm_model.decode_step,
    ),
    "encdec": SimpleNamespace(
        init_params=encdec.init_params,
        forward=encdec.forward,
        loss_fn=encdec.loss_fn,
        init_decode_cache=encdec.init_decode_cache,
        decode_step=encdec.decode_step,
    ),
}


def model_for(cfg: ModelConfig):
    return _FAMILIES[cfg.family]
