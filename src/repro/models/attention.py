"""GQA attention layer: projections + RoPE + qk-norm + SWA + paged decode."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models.attention_ops import (flash_attention_xla,
                                        paged_attention_xla,
                                        ring_buffer_attention)
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_head_norm


def init_attention(key, cfg: ModelConfig, dtype):
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KVH * hd, dtype),
        "wv": dense_init(ks[2], d, KVH * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KVH * hd,), dtype)
        p["bv"] = jnp.zeros((KVH * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KVH, hd)
    v = v.reshape(B, S, KVH, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "q_seq", "heads", "head_dim")
    k = constrain(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "kv_seq", "kv_heads", "head_dim")
    return q, k, v


def apply_attention(p, cfg: ModelConfig, x, positions, *,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    return_kv: bool = False, causal: bool = True):
    """Training / prefill attention (causal, optionally sliding-window)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    out = flash_attention_xla(q, k, v, causal=causal,
                              window=cfg.sliding_window if causal else 0,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = constrain(out, "batch", "q_seq", "heads", "head_dim")
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim) @ p["wo"]
    out = constrain(out, "batch", "seq", "embed")
    if return_kv:
        return out, (k, v)
    return out


def _paged_update_and_attend(q1, k1, v1, k_pool, v_pool, page_table,
                             lengths, window: int):
    """Write the new token's K/V into its page, then attend."""
    ps = k_pool.shape[1]
    pos = lengths - 1
    page_slot = pos // ps
    offset = pos % ps
    frame = jnp.take_along_axis(page_table, page_slot[:, None], axis=1)[:, 0]
    frame = jnp.maximum(frame, 0)
    k_pool = k_pool.at[frame, offset[0]].set(k1)
    v_pool = v_pool.at[frame, offset[0]].set(v1)
    out = paged_attention_xla(q1, k_pool, v_pool, page_table, lengths,
                              window=window)
    return out, k_pool, v_pool


def _paged_update_and_attend_dist(q1, k1, v1, k_pool, v_pool, page_table,
                                  lengths, window: int):
    """Locality-explicit variant (the §Perf decode iteration).

    Pool pages and batch rows are co-sharded over the data axes (the
    engine's identity page layout guarantees sequence b's pages live on
    b's shard).  GSPMD cannot prove that, so the plain gather becomes a
    full-pool masked reduce per page step — TB-scale HBM traffic and ~half
    the step in collectives (measured; see EXPERIMENTS.md §Perf).  Under
    shard_map the gather is local: page-table frames are rebased to the
    shard-local pool slice and no collective is emitted at all.
    """
    from repro.compat import import_shard_map
    shard_map = import_shard_map()
    from jax.sharding import PartitionSpec as P
    import numpy as _np
    from repro.distributed import logical

    mesh = logical.current_mesh()
    daxes = logical.rule("batch")
    B = q1.shape[0]
    P_pages = k_pool.shape[0]
    if mesh is None or daxes is None:
        return _paged_update_and_attend(q1, k1, v1, k_pool, v_pool,
                                        page_table, lengths, window)
    axes = daxes if isinstance(daxes, tuple) else (daxes,)
    dsize = int(_np.prod([mesh.shape[a] for a in axes]))
    if dsize <= 1 or B % dsize or P_pages % dsize:
        return _paged_update_and_attend(q1, k1, v1, k_pool, v_pool,
                                        page_table, lengths, window)
    p_local = P_pages // dsize
    # also split heads over 'model' inside the region when both the query
    # and KV head counts divide it (keeps GQA grouping shard-local and the
    # pool tensor-parallel — without this the pool replicates over model
    # inside the region, a measured 16× per-layer transient for MHA archs)
    msize = mesh.shape.get("model", 1)
    H, KVH = q1.shape[1], k1.shape[1]
    head_tp = "model" in mesh.shape and H % msize == 0 and KVH % msize == 0

    def local_fn(q_l, k1_l, v1_l, kp_l, vp_l, pt_l, len_l):
        rank = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        pt_local = jnp.where(pt_l >= 0, pt_l - rank * p_local, pt_l)
        return _paged_update_and_attend(q_l, k1_l, v1_l, kp_l, vp_l,
                                        pt_local, len_l, window)

    d = daxes
    h = "model" if head_tp else None
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(d, h), P(d, h), P(d, h),
                  P(d, None, h), P(d, None, h), P(d), P(d)),
        out_specs=(P(d, h), P(d, None, h), P(d, None, h)),
        check_vma=False)
    return fn(q1, k1, v1, k_pool, v_pool, page_table, lengths)


def apply_attention_decode_paged(p, cfg: ModelConfig, x, k_pool, v_pool,
                                 page_table, lengths):
    """One-token decode through the paged KV pool.

    x: (B, 1, d).  ``lengths`` counts tokens *including* the current one.
    The new token's K/V is written into its page (uniform offset across the
    batch — the shapes' decode steps are in lockstep), then attention reads
    the whole context through the page table.
    Returns (out, k_pool, v_pool).
    """
    B = x.shape[0]
    pos = lengths - 1                                     # (B,) current index
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]
    out, k_pool, v_pool = _paged_update_and_attend_dist(
        q1, k1, v1, k_pool, v_pool, page_table, lengths, cfg.sliding_window)
    out = out.reshape(B, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out[:, None, :], k_pool, v_pool


def apply_attention_decode_ring(p, cfg: ModelConfig, x, k_ring, v_ring,
                                lengths):
    """One-token decode over a sliding-window ring buffer (SWA archs).

    The ring IS the resident set: everything older than the window has
    been "swapped out" — re-touching it is impossible by construction,
    which is why SWA archs run long_500k with a bounded pool.
    Returns (out, k_ring, v_ring).
    """
    B = x.shape[0]
    W = k_ring.shape[1]
    pos = lengths - 1
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]
    slot = pos[0] % W
    k_ring = jax.lax.dynamic_update_slice_in_dim(k_ring, k1[:, None], slot, 1)
    v_ring = jax.lax.dynamic_update_slice_in_dim(v_ring, v1[:, None], slot, 1)
    out = ring_buffer_attention(q1, k_ring, v_ring, lengths, cfg.sliding_window)
    out = out.reshape(B, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return out[:, None, :], k_ring, v_ring
