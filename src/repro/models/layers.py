"""Shared neural-net primitives: norms, RoPE, initializers, MLPs.

Pure-function style: ``init_*`` returns a params pytree (nested dicts of
jnp arrays), ``apply``-style functions take (params, inputs).  All matmul
weights are stored ``(in, out)``; layers are stacked on a leading axis by
the decoders for scan-over-layers.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.logical import constrain


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- initializers
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------- norms
def init_norm(d: int, kind: str = "rms"):
    if kind == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(params, x, kind: str = "rms", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-5):
    """Per-head RMS norm for qk_norm (Qwen3 / Chameleon)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


# ------------------------------------------------------------------------ RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------- MLP
def init_mlp(key, d: int, f: int, act: str, dtype, bias: bool = False):
    ks = jax.random.split(key, 3)
    if act == "silu":   # SwiGLU
        p = {"wi": dense_init(ks[0], d, f, dtype),
             "wg": dense_init(ks[1], d, f, dtype),
             "wo": dense_init(ks[2], f, d, dtype)}
    else:               # plain GELU MLP
        p = {"wi": dense_init(ks[0], d, f, dtype),
             "wo": dense_init(ks[1], f, d, dtype)}
    if bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(p, x, act: str):
    if act == "silu":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    else:
        h = x @ p["wi"]
        if "bi" in p:
            h = h + p["bi"]
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "ff")
    y = h @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y


# ------------------------------------------------------- sinusoidal positions
def sinusoid_positions(n: int, d: int):
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000, 2 * dim / d)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)
