"""The four assigned input-shape sets (seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
KV cache of seq_len), NOT ``train_step``.  ``long_500k`` requires
sub-quadratic attention: run only for SSM / hybrid / SWA archs
(DESIGN.md §4 documents the per-arch skips).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


TRAIN_4K = Shape("train_4k", 4096, 256, "train")
PREFILL_32K = Shape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = Shape("decode_32k", 32768, 128, "decode")
LONG_500K = Shape("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(cfg) -> list:
    """Applicable shapes for an arch (documented skips in DESIGN.md §4)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out


def skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full attention: 500k-token decode has no bounded "
                "resident set; skipped per assignment note")
    return ""
