"""Whisper-medium [audio]: encoder-decoder with a STUBBED conv frontend
[arXiv:2212.04356; unverified].

``input_specs()`` provides precomputed log-mel frame embeddings
(B, 1500, d_model); the decoder is the transformer backbone under test.
Cross-attention KV is computed once and pinned; decoder self-attention KV
is paged (DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,            # decoder layers
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    max_source_positions=1500,
    max_target_positions=448,
    act="gelu",
    norm="ln",
    tie_embeddings=True,
)
