"""Assigned architecture configs (--arch <id>).  One module per arch."""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "chameleon_34b", "codeqwen15_7b", "qwen3_14b", "starcoder2_3b",
    "h2o_danube_1_8b", "mixtral_8x7b", "deepseek_v3_671b", "zamba2_7b",
    "xlstm_125m", "whisper_medium",
)

# CLI aliases (--arch accepts either form)
ALIASES = {
    "chameleon-34b": "chameleon_34b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen3-14b": "qwen3_14b",
    "starcoder2-3b": "starcoder2_3b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "zamba2-7b": "zamba2_7b",
    "xlstm-125m": "xlstm_125m",
    "whisper-medium": "whisper_medium",
}


def get_config(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; one of {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
