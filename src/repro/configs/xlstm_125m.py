"""xLSTM-125M [ssm]: sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

12 blocks, sLSTM at positions 5 and 11 (a 5:1 mLSTM:sLSTM mix), d_ff=0 —
the mLSTM blocks are pre-up-projection (internal 2x expansion), the sLSTM
block carries its own 4/3 post-up FFN.  Attention-free: long_500k RUNS
(fixed-size recurrent state; no KV pages at all — DESIGN.md §4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_at=(5, 11),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=1.3333333,
    norm="ln",
)
