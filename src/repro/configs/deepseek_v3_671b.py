"""DeepSeek-V3-671B [moe]: MLA, 1 shared + 256 routed experts top-8, MTP
[arXiv:2412.19437; hf].

MLA's latent KV cache (kv_lora_rank 512 + 64 RoPE dims per token) is the
best case for the paged-memory technique (DESIGN.md §4).  Routing here is
softmax top-8 (DeepSeek's sigmoid+bias noted as a deviation in DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="mla_moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,             # dense-layer FFN (first_k_dense)
    moe_d_ff=2048,          # per-routed-expert FFN
    vocab_size=129280,
    n_experts=256,
    experts_per_token=8,
    n_shared_experts=1,
    first_k_dense=3,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=0,            # MTP module available; off for assigned shapes
    rope_theta=10000.0,
    act="silu",
    norm="rms",
)
