"""Chameleon-34B [vlm]: early-fusion decoder over a unified text+VQ-image
token vocabulary (65 536) [arXiv:2405.09818; unverified].

The VQ image tokenizer is a STUB: image tokens arrive as ordinary token ids
in the merged vocab (early fusion means the backbone is a plain decoder);
``input_specs()`` supplies token ids directly.  Chameleon's qk-norm is on.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,           # chameleon stabilizes with qk layernorm
    rope_theta=10000.0,
    act="silu",
    norm="rms",
)
