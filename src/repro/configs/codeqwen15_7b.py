"""CodeQwen1.5-7B [dense]: qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B; hf]."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,          # MHA (GQA kv=32)
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1000000.0,   # qwen1.5 long-context base
    act="silu",
    norm="rms",
    attn_bias=True,         # qwen1.5 uses qkv bias
)
