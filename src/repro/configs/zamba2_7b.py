"""Zamba2-7B [hybrid]: Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified].

81 Mamba2 blocks; the single shared transformer block runs after every 6th
block (13 applications + 3-block tail).  Zamba2's per-application LoRA
deltas on the shared block are omitted (noted in DESIGN.md §2).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10000.0,
    act="silu",
    norm="rms",
)
