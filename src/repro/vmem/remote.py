"""Fabric-backed frame pool: page-ins travel over the verbs API.

:class:`RemoteFramePool` decorates a local :class:`FramePool` so that
every pager page-in posts an asynchronous ``ProtectionDomain.post_read``
against a remote node's memory and waits for its completion on a real
:class:`~repro.api.completion.CompletionQueue` — the first time the
fabric simulation and the JAX data plane meet.  The local landing region
is registered ``FAULTING`` (the thesis' whole point: no pinning
ceremony), so cold page-ins take destination faults whose RAPF
retransmits surface in :class:`~repro.vmem.stats.PagingStats`
(``rapf_retransmits``, ``remote_dst_faults``) and whose
:class:`WorkCompletion`s stay observable on the CQ.

This is the building block for multi-node paged serving: a KV pager
whose backing tier is another node's memory instead of local host RAM.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.api.completion import CompletionQueue, WorkCompletion
from repro.api.config import FabricConfig
from repro.api.fabric import Fabric, ProtectionDomain
from repro.api.memory import BufferPrep, MemoryRegion
from repro.api.policy import FaultPolicy
from repro.core import addresses as A
from repro.core.arbiter import ServiceClass
from repro.vmem.frames import DeviceFramePool, FramePool, PageInReceipt


class RemoteFramePool(FramePool):
    """Decorator: a local pool whose page-ins are remote verbs reads."""

    def __init__(self, local: FramePool, domain: ProtectionDomain,
                 remote_mr: MemoryRegion, local_mr: MemoryRegion,
                 cq: CompletionQueue, page_bytes: int = A.PAGE_SIZE):
        super().__init__(local.n_frames, local.page_elems)
        self.local = local
        self.free = local.free              # share allocation state
        self.domain = domain
        self.remote_mr = remote_mr
        self.local_mr = local_mr
        self.cq = cq
        self.page_bytes = page_bytes
        self.completions: list[WorkCompletion] = []
        n_pages = min(remote_mr.length, local_mr.length) // page_bytes
        if n_pages < 1:
            raise ValueError("memory regions smaller than one page")
        self.n_backing_pages = n_pages

    # payload delegates to the local pool -------------------------------
    @property
    def dtype(self):
        return getattr(self.local, "dtype", None)

    @property
    def data(self):
        return getattr(self.local, "data", None)

    @data.setter
    def data(self, value):
        self.local.data = value

    def load(self, frame, data):
        self.local.load(frame, data)

    def store(self, frame):
        return self.local.store(frame)

    def gather(self, frames) -> jnp.ndarray:
        return self.local.gather(frames)

    # transport ----------------------------------------------------------
    def page_in(self, space, vpage: int, n_pages: int,
                prefetch: bool = False) -> PageInReceipt:
        if vpage + n_pages > self.n_backing_pages:
            raise ValueError(
                f"page-in [{vpage}, {vpage + n_pages}) beyond the remote "
                f"region ({self.n_backing_pages} pages)")
        off = vpage * self.page_bytes
        nbytes = n_pages * self.page_bytes
        if self.cq.outstanding >= self.cq.max_outstanding:
            # keep the posting verbs unblocked; history stays in
            # ``completions`` for callers that drained nothing themselves
            self.completions.extend(self.cq.poll(self.cq.max_outstanding))
        # a demand page-in is on some tenant's critical path -> LATENCY;
        # predictive stream warm-ups share bandwidth as BULK traffic
        wr = self.domain.post_read(self.remote_mr, self.local_mr,
                                   cq=self.cq, nbytes=nbytes,
                                   target_offset=off, local_offset=off,
                                   service_class=(ServiceClass.BULK
                                                  if prefetch else
                                                  ServiceClass.LATENCY))
        wc = wr.result()
        return PageInReceipt(us=wc.latency_us, remote_reads=1,
                             rapf_retransmits=wc.stats.rapf_retransmits,
                             dst_faults=wc.stats.dst_faults,
                             bytes_in=nbytes,
                             mtt_hits=wc.stats.mtt_hits,
                             mtt_misses=wc.stats.mtt_misses,
                             mtt_stale=wc.stats.mtt_stale,
                             pool_redirects=wc.stats.pool_redirect_pages)

    # telemetry ----------------------------------------------------------
    @property
    def fabric(self) -> Fabric:
        return self.domain.fabric

    def net_stats(self):
        """Interconnect telemetry of the backing fabric — on routed
        topologies a page-in's route shares links with other tenants, so
        remote paging latency reflects real path contention."""
        return self.fabric.net_stats()

    # convenience builder ------------------------------------------------
    @classmethod
    def build(cls, *, n_frames: int, page_elems: int, n_pages: int,
              fabric: Optional[Fabric] = None,
              config: Optional[FabricConfig] = None, pd: int = 1,
              policy: Optional[FaultPolicy] = None,
              local: Optional[FramePool] = None,
              page_bytes: int = A.PAGE_SIZE,
              local_node: int = 0, remote_node: int = 1,
              local_base: int = 0x10_0000_0000,
              remote_base: int = 0x20_0000_0000,
              cq_depth: int = 256, dtype=jnp.float32) -> "RemoteFramePool":
        """Wire a fabric scenario: remote backing (pre-touched), faulting
        local landing buffer, one CQ, one protection domain.

        ``config`` selects the fabric when none is passed — e.g. a
        routed ``FabricConfig(n_nodes=8, topology="torus_2d")`` whose
        multi-hop paths make page-ins contend with other traffic; the
        default is the seed's two-node ALL_TO_ALL.
        """
        if fabric is not None and config is not None:
            raise ValueError("pass either fabric= or config=, not both")
        fabric = fabric or Fabric.build(config or FabricConfig(n_nodes=2))
        n_nodes = len(fabric.nodes)
        if not (0 <= local_node < n_nodes and 0 <= remote_node < n_nodes):
            raise ValueError(
                f"local_node={local_node} / remote_node={remote_node} "
                f"outside the fabric's {n_nodes} nodes")
        domain = fabric.domain(pd) or fabric.open_domain(pd, policy=policy)
        size = n_pages * page_bytes
        remote_mr = domain.register_memory(remote_node, remote_base, size,
                                           prep=BufferPrep.TOUCHED)
        local_mr = domain.register_memory(local_node, local_base, size,
                                          prep=BufferPrep.FAULTING)
        cq = fabric.create_cq(depth=cq_depth)
        local = local or DeviceFramePool(n_frames, page_elems, dtype)
        return cls(local, domain, remote_mr, local_mr, cq,
                   page_bytes=page_bytes)
