"""Fabric-backed frame pool: page-ins travel over the verbs API.

:class:`RemoteFramePool` decorates a local :class:`FramePool` so that
every pager page-in posts an asynchronous ``ProtectionDomain.post_read``
against a remote node's memory and waits for its completion on a real
:class:`~repro.api.completion.CompletionQueue` — the first time the
fabric simulation and the JAX data plane meet.  The local landing region
is registered ``FAULTING`` (the thesis' whole point: no pinning
ceremony), so cold page-ins take destination faults whose RAPF
retransmits surface in :class:`~repro.vmem.stats.PagingStats`
(``rapf_retransmits``, ``remote_dst_faults``) and whose
:class:`WorkCompletion`s stay observable on the CQ.

This is the building block for multi-node paged serving: a KV pager
whose backing tier is another node's memory instead of local host RAM.

**Crash-fault failover.**  A pool built with a ``replica_mr`` (a second
backing region on a different node, ``build(replica_node=...)``) mirrors
every write-back (:meth:`RemoteFramePool.page_out`) to both backing
nodes and keeps per-page version counters.  When a page-in against the
primary completes with an error status (the primary backing node
crashed or partitioned away), the pool fails over: the read is re-posted
against the replica, and each page served is checked for
*read-your-writes* — the replica must hold the newest version this pool
ever wrote back (``ryw_verified`` / ``ryw_violations``).  All later
page-ins go straight to the replica.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.api.completion import CompletionQueue, WorkCompletion
from repro.api.config import FabricConfig
from repro.api.fabric import Fabric, ProtectionDomain
from repro.api.memory import BufferPrep, MemoryRegion
from repro.api.policy import FaultPolicy
from repro.core import addresses as A
from repro.core.arbiter import ServiceClass
from repro.vmem.frames import DeviceFramePool, FramePool, PageInReceipt


class RemoteFramePool(FramePool):
    """Decorator: a local pool whose page-ins are remote verbs reads."""

    def __init__(self, local: FramePool, domain: ProtectionDomain,
                 remote_mr: MemoryRegion, local_mr: MemoryRegion,
                 cq: CompletionQueue, page_bytes: int = A.PAGE_SIZE,
                 replica_mr: Optional[MemoryRegion] = None):
        super().__init__(local.n_frames, local.page_elems)
        self.local = local
        self.free = local.free              # share allocation state
        self.domain = domain
        self.remote_mr = remote_mr
        self.local_mr = local_mr
        self.cq = cq
        self.page_bytes = page_bytes
        self.completions: list[WorkCompletion] = []
        n_pages = min(remote_mr.length, local_mr.length) // page_bytes
        if n_pages < 1:
            raise ValueError("memory regions smaller than one page")
        self.n_backing_pages = n_pages
        # ---- crash-fault failover state --------------------------------
        if replica_mr is not None:
            if replica_mr.node_id == remote_mr.node_id:
                raise ValueError(
                    "replica_mr must live on a different node than the "
                    "primary backing region (same-node replication "
                    "survives nothing)")
            if replica_mr.length // page_bytes < n_pages:
                raise ValueError("replica region smaller than the primary")
        self.replica_mr = replica_mr
        self.failed_over = False
        self.failovers = 0                  # page-ins re-served by replica
        self.ryw_verified = 0               # failover pages at newest version
        self.ryw_violations = 0             # replica missed a write-back
        # read-your-writes bookkeeping: version this pool last wrote back
        # per backing page, and the version the REPLICA is known to hold
        self._versions: dict[int, int] = {}
        self._replica_versions: dict[int, int] = {}

    # payload delegates to the local pool -------------------------------
    @property
    def dtype(self):
        return getattr(self.local, "dtype", None)

    @property
    def data(self):
        return getattr(self.local, "data", None)

    @data.setter
    def data(self, value):
        self.local.data = value

    def load(self, frame, data):
        self.local.load(frame, data)

    def store(self, frame):
        return self.local.store(frame)

    def gather(self, frames) -> jnp.ndarray:
        return self.local.gather(frames)

    # transport ----------------------------------------------------------
    @property
    def active_mr(self) -> MemoryRegion:
        """The backing region page-ins currently read from."""
        return (self.replica_mr if self.failed_over and self.replica_mr
                is not None else self.remote_mr)

    def _post_read(self, mr: MemoryRegion, off: int, nbytes: int,
                   prefetch: bool) -> WorkCompletion:
        if self.cq.outstanding >= self.cq.max_outstanding:
            # keep the posting verbs unblocked; history stays in
            # ``completions`` for callers that drained nothing themselves
            self.completions.extend(self.cq.poll(self.cq.max_outstanding))
        # a demand page-in is on some tenant's critical path -> LATENCY;
        # predictive stream warm-ups share bandwidth as BULK traffic
        wr = self.domain.post_read(mr, self.local_mr,
                                   cq=self.cq, nbytes=nbytes,
                                   target_offset=off, local_offset=off,
                                   service_class=(ServiceClass.BULK
                                                  if prefetch else
                                                  ServiceClass.LATENCY))
        return wr.result()

    def page_in(self, space, vpage: int, n_pages: int,
                prefetch: bool = False) -> PageInReceipt:
        if vpage + n_pages > self.n_backing_pages:
            raise ValueError(
                f"page-in [{vpage}, {vpage + n_pages}) beyond the remote "
                f"region ({self.n_backing_pages} pages)")
        off = vpage * self.page_bytes
        nbytes = n_pages * self.page_bytes
        t0 = self.fabric.now
        wc = self._post_read(self.active_mr, off, nbytes, prefetch)
        failovers = 0
        if not wc.ok and not self.failed_over and self.replica_mr is not None:
            # primary backing node crashed/partitioned: fail over to the
            # replica pager and re-serve this read from it.  latency_us
            # below spans BOTH attempts — detection time is part of the
            # recovery latency the chaos benchmark claims.
            self.failed_over = True
            wc = self._post_read(self.replica_mr, off, nbytes, prefetch)
        if self.failed_over and wc.ok:
            failovers = 1
            self.failovers += 1
            self._verify_ryw(vpage, n_pages)
        return PageInReceipt(us=self.fabric.now - t0 if failovers
                             else wc.latency_us,
                             remote_reads=1,
                             rapf_retransmits=wc.stats.rapf_retransmits,
                             dst_faults=wc.stats.dst_faults,
                             bytes_in=nbytes if wc.ok else 0,
                             failovers=failovers,
                             mtt_hits=wc.stats.mtt_hits,
                             mtt_misses=wc.stats.mtt_misses,
                             mtt_stale=wc.stats.mtt_stale,
                             pool_redirects=wc.stats.pool_redirect_pages)

    def _verify_ryw(self, vpage: int, n_pages: int) -> None:
        """Read-your-writes check: every page served by the replica must
        carry the newest version this pool ever wrote back."""
        for p in range(vpage, vpage + n_pages):
            want = self._versions.get(p, 0)
            if self._replica_versions.get(p, 0) == want:
                self.ryw_verified += 1
            else:
                self.ryw_violations += 1

    def page_out(self, space, vpage: int, n_pages: int = 1) -> float:
        """Write back ``n_pages`` starting at ``vpage`` to the backing
        store — mirrored to the replica when one is configured, so a
        later failover read observes the write (read-your-writes).

        Returns the simulated microseconds the write-back(s) took.
        """
        if vpage + n_pages > self.n_backing_pages:
            raise ValueError(
                f"page-out [{vpage}, {vpage + n_pages}) beyond the remote "
                f"region ({self.n_backing_pages} pages)")
        off = vpage * self.page_bytes
        nbytes = n_pages * self.page_bytes
        for p in range(vpage, vpage + n_pages):
            self._versions[p] = self._versions.get(p, 0) + 1
        targets = []
        if not self.failed_over:
            targets.append((self.remote_mr, False))
        if self.replica_mr is not None:
            targets.append((self.replica_mr, True))
        t0 = self.fabric.now
        for mr, is_replica in targets:
            if self.cq.outstanding >= self.cq.max_outstanding:
                self.completions.extend(
                    self.cq.poll(self.cq.max_outstanding))
            wr = self.domain.post_write(self.local_mr, mr, cq=self.cq,
                                        nbytes=nbytes, src_offset=off,
                                        dst_offset=off,
                                        service_class=ServiceClass.BULK)
            wc = wr.result()
            if is_replica and wc.ok:
                # only a COMPLETED replica write is read-your-writes
                # visible; a failed one must surface as a violation
                for p in range(vpage, vpage + n_pages):
                    self._replica_versions[p] = self._versions[p]
            elif not is_replica and not wc.ok and self.replica_mr is not None:
                # the primary died under a write-back: stop sending it
                # traffic — subsequent reads and writes go replica-only
                self.failed_over = True
        return self.fabric.now - t0

    # telemetry ----------------------------------------------------------
    @property
    def fabric(self) -> Fabric:
        return self.domain.fabric

    def net_stats(self):
        """Interconnect telemetry of the backing fabric — on routed
        topologies a page-in's route shares links with other tenants, so
        remote paging latency reflects real path contention."""
        return self.fabric.net_stats()

    # convenience builder ------------------------------------------------
    @classmethod
    def build(cls, *, n_frames: int, page_elems: int, n_pages: int,
              fabric: Optional[Fabric] = None,
              config: Optional[FabricConfig] = None, pd: int = 1,
              policy: Optional[FaultPolicy] = None,
              local: Optional[FramePool] = None,
              page_bytes: int = A.PAGE_SIZE,
              local_node: int = 0, remote_node: int = 1,
              local_base: int = 0x10_0000_0000,
              remote_base: int = 0x20_0000_0000,
              replica_node: Optional[int] = None,
              replica_base: int = 0x30_0000_0000,
              cq_depth: int = 256, dtype=jnp.float32) -> "RemoteFramePool":
        """Wire a fabric scenario: remote backing (pre-touched), faulting
        local landing buffer, one CQ, one protection domain.

        ``config`` selects the fabric when none is passed — e.g. a
        routed ``FabricConfig(n_nodes=8, topology="torus_2d")`` whose
        multi-hop paths make page-ins contend with other traffic; the
        default is the seed's two-node ALL_TO_ALL.

        ``replica_node`` registers a second (pre-touched) backing region
        there and arms crash-fault failover: if the primary backing node
        dies, page-ins transparently re-serve from the replica (with
        read-your-writes verification against mirrored write-backs).
        """
        if fabric is not None and config is not None:
            raise ValueError("pass either fabric= or config=, not both")
        fabric = fabric or Fabric.build(config or FabricConfig(n_nodes=2))
        n_nodes = len(fabric.nodes)
        if not (0 <= local_node < n_nodes and 0 <= remote_node < n_nodes):
            raise ValueError(
                f"local_node={local_node} / remote_node={remote_node} "
                f"outside the fabric's {n_nodes} nodes")
        if replica_node is not None:
            if not 0 <= replica_node < n_nodes:
                raise ValueError(
                    f"replica_node={replica_node} outside the fabric's "
                    f"{n_nodes} nodes")
            if replica_node == remote_node:
                raise ValueError(
                    "replica_node must differ from remote_node")
        domain = fabric.domain(pd) or fabric.open_domain(pd, policy=policy)
        size = n_pages * page_bytes
        remote_mr = domain.register_memory(remote_node, remote_base, size,
                                           prep=BufferPrep.TOUCHED)
        local_mr = domain.register_memory(local_node, local_base, size,
                                          prep=BufferPrep.FAULTING)
        replica_mr = None
        if replica_node is not None:
            replica_mr = domain.register_memory(
                replica_node, replica_base, size, prep=BufferPrep.TOUCHED)
        cq = fabric.create_cq(depth=cq_depth)
        local = local or DeviceFramePool(n_frames, page_elems, dtype)
        return cls(local, domain, remote_mr, local_mr, cq,
                   page_bytes=page_bytes, replica_mr=replica_mr)
