"""Legacy-kwarg handling: ONE place that deprecates ``strategy=`` /
``lookahead=`` in favour of :class:`~repro.api.policy.FaultPolicy`.

Every memory consumer (``PagedTensorStore``, ``PagedKVManager``,
``PagedAdamW``, ``ServingEngine``) funnels its constructor knobs through
:func:`coerce_policy`, so the per-tenant policy vocabulary stays
consistent with ``repro.api`` and the deprecation story lives here
instead of being re-implemented four times.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.api.policy import DEFAULT_POLICY, FaultPolicy
from repro.core.resolver import Strategy


def coerce_policy(owner: str, policy: Optional[FaultPolicy],
                  strategy: Optional[Strategy] = None,
                  lookahead: Optional[int] = None,
                  default: FaultPolicy = DEFAULT_POLICY) -> FaultPolicy:
    """Resolve (policy, legacy strategy/lookahead) into one FaultPolicy.

    ``policy`` wins; passing both is an error.  Legacy kwargs emit a
    DeprecationWarning naming ``owner`` and are folded into a policy.
    """
    if policy is not None:
        if strategy is not None or lookahead is not None:
            raise TypeError(
                f"{owner}: pass either policy=FaultPolicy(...) or the "
                f"legacy strategy=/lookahead= kwargs, not both")
        return policy
    if strategy is None and lookahead is None:
        return default
    warnings.warn(
        f"{owner}(strategy=..., lookahead=...) is deprecated; pass "
        f"policy=FaultPolicy(strategy, lookahead) instead",
        DeprecationWarning, stacklevel=3)
    return dataclasses.replace(
        default,
        strategy=strategy if strategy is not None else default.strategy,
        lookahead=lookahead if lookahead is not None else default.lookahead)
