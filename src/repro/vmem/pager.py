"""The unified demand pager: fault → resolve → map.

One :class:`Pager` owns one :class:`~repro.vmem.frames.FramePool` and
serves any number of :class:`AddressSpace` tenants over it — the thesis'
"handle the fault instead of pinning" mechanism as a reusable subsystem.
``PagedTensorStore``, ``PagedKVManager``, ``PagedAdamW`` and the serving
engine's KV spill path are all thin wrappers over this one fault loop:

* an access (or pre-dispatch residency check) hits a non-resident page;
* the tenant's :class:`~repro.api.policy.FaultPolicy` picks the
  resolution strategy — Touch-A-Page pays one event per page, the
  block strategies resolve a ``get_user_pages`` block per event, STREAM
  additionally warms the next block (``repro.vmem.prefetch``);
* frames come from the shared pool, evicting per the pluggable policy
  (``repro.vmem.eviction``) when exhausted — never a pinned page;
* the pool backend moves the payload: device/host copies locally, or a
  verbs ``post_read`` over the fabric for
  :class:`~repro.vmem.remote.RemoteFramePool`, whose completions land on
  a real :class:`~repro.api.completion.CompletionQueue`.

Timing is accounted with the calibrated :class:`CostModel` in
``PagingStats.simulated_us`` while the data movement itself is real,
exactly as in the seed pagers.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policy import DEFAULT_POLICY, FaultPolicy
from repro.core import addresses as A
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy
from repro.vmem.eviction import EvictionPolicy, LRUEviction
from repro.vmem.frames import FramePool
from repro.vmem.prefetch import predictor_for
from repro.vmem.stats import PagingStats

NON_RESIDENT = -1


class AddressSpace:
    """One tenant's virtual page range over a (possibly shared) pool.

    Owns the page table, pin/prefetch/reference bits, the host backing
    image (where non-resident pages live, absent for id-only pools) and a
    per-tenant :class:`PagingStats`.  An optional per-space
    :class:`FaultPolicy` overrides the pager default — two tenants of one
    pool can resolve faults with different strategies, mirroring the
    per-domain policies of ``repro.api``.
    """

    def __init__(self, pager: "Pager", n_pages: int, name: str = "",
                 policy: Optional[FaultPolicy] = None):
        self.pager = pager
        self.n_pages = n_pages
        self.name = name
        self.policy = policy
        self.page_table = np.full((n_pages,), NON_RESIDENT, np.int64)
        self.pinned = np.zeros((n_pages,), bool)
        self.prefetched = np.zeros((n_pages,), bool)
        self.referenced = np.zeros((n_pages,), bool)
        self.swapped = np.zeros((n_pages,), bool)   # evicted, awaiting fault
        self.last_used = np.zeros((n_pages,), np.int64)
        pool = pager.pool
        if pool.page_elems:
            dtype = jax.dtypes.canonicalize_dtype(
                getattr(pool, "dtype", np.float32))
            self.backing = np.zeros((n_pages, pool.page_elems), dtype)
        else:
            self.backing = None
        self.stats = PagingStats()

    # ------------------------------------------------------------ queries
    def is_resident(self, vpage: int) -> bool:
        return self.page_table[vpage] != NON_RESIDENT

    def resident_pages(self) -> int:
        return int((self.page_table != NON_RESIDENT).sum())

    def frame_ids(self, vpages) -> np.ndarray:
        """Frame ids for compiled-kernel page tables (resolve first)."""
        return self.page_table[np.atleast_1d(vpages)]

    # -------------------------------------------------- delegated verbs
    def access(self, vpages) -> jnp.ndarray:
        return self.pager.access(self, vpages)

    def ensure_resident(self, vpages, victims=None) -> int:
        return self.pager.ensure_resident(self, vpages, victims=victims)

    def pin(self, vpages) -> None:
        self.pager.pin(self, vpages)

    def unpin(self, vpages) -> None:
        self.pager.unpin(self, vpages)

    def write(self, vpage: int, data, allow_partial: bool = False) -> None:
        """Populate a page's backing image (device copy kept coherent).

        ``data`` must fill the page exactly unless ``allow_partial`` —
        streaming consumers whose final page is short (e.g. the last
        optimizer block) opt in; everyone else gets a loud error rather
        than a silently stale page tail.
        """
        flat = np.asarray(data, self.backing.dtype).reshape(-1)
        width = self.backing.shape[1]
        if flat.size != width and not (allow_partial
                                       and flat.size < width):
            raise ValueError(
                f"page payload of {flat.size} elems does not fill a "
                f"{width}-elem page (pass allow_partial=True to write a "
                f"short final page)")
        self.backing[vpage, :flat.size] = flat
        f = self.page_table[vpage]
        if f != NON_RESIDENT:
            self.pager.pool.load(int(f), self.backing[vpage])

    def write_back(self, vpage: int) -> None:
        """Frame -> backing writeback for a resident page."""
        f = self.page_table[vpage]
        if f != NON_RESIDENT and self.backing is not None:
            data = self.pager.pool.store(int(f))
            if data is not None:
                self.backing[vpage] = data


class Pager:
    """Fault resolver + frame allocator over one pool, many spaces."""

    def __init__(self, pool: FramePool, *,
                 policy: FaultPolicy = DEFAULT_POLICY,
                 cost: CostModel = DEFAULT_COST_MODEL,
                 eviction: Optional[EvictionPolicy] = None,
                 page_bytes: int = A.PAGE_SIZE):
        self.pool = pool
        self.policy = policy
        self.cost = cost
        self.eviction = eviction or LRUEviction()
        self.page_bytes = page_bytes
        self.spaces: list[AddressSpace] = []
        self.stats = PagingStats()
        self._clock = 0

    # ------------------------------------------------------------- spaces
    def create_space(self, n_pages: int, name: str = "",
                     policy: Optional[FaultPolicy] = None) -> AddressSpace:
        sp = AddressSpace(self, n_pages, name=name, policy=policy)
        self.spaces.append(sp)
        self.pool.spaces.append(sp)
        self.stats.allocs += 1
        sp.stats.allocs += 1
        return sp

    def destroy_space(self, space: AddressSpace) -> None:
        for v in np.where(space.page_table != NON_RESIDENT)[0]:
            self.pool.release(int(space.page_table[v]))
            self.eviction.note_unmap(space, int(v))
        space.page_table[:] = NON_RESIDENT
        self.spaces.remove(space)
        self.pool.spaces.remove(space)

    def policy_of(self, space: AddressSpace) -> FaultPolicy:
        return space.policy or self.policy

    # ------------------------------------------------------------ plumbing
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _acct(self, space: AddressSpace, **deltas) -> None:
        # lint: allow(det-dict-iter): commutative setattr accumulation
        for name, d in deltas.items():
            setattr(space.stats, name, getattr(space.stats, name) + d)
            setattr(self.stats, name, getattr(self.stats, name) + d)

    @property
    def _os_pages_per_page(self) -> int:
        """4 KB OS pages one pager page represents (cost granularity)."""
        return max(1, self.page_bytes // A.PAGE_SIZE)

    # ----------------------------------------------------------- eviction
    def _evict_page(self, space: AddressSpace, vpage: int) -> None:
        frame = int(space.page_table[vpage])
        space.write_back(vpage)
        space.page_table[vpage] = NON_RESIDENT
        space.swapped[vpage] = True
        space.prefetched[vpage] = False
        self.pool.release(frame)
        self.eviction.note_unmap(space, vpage)
        self._acct(space, evictions=1, pages_out=1)

    def _evict_for(self, requester: AddressSpace,
                   victims: Optional[Sequence[AddressSpace]]) -> int:
        # default candidates: every space over the POOL (not just this
        # pager's), so consumers sharing a pool contend with each other
        cands = list(victims) if victims is not None else self.pool.spaces
        pick = self.eviction.select_victim(cands)
        if pick is None:
            self._acct(requester, pin_violations=1)
            raise MemoryError(
                "frame pool exhausted and every candidate page is pinned "
                "or absent (the thesis' pinning-limit failure mode)")
        vspace, vpage = pick
        self._evict_page(vspace, vpage)
        if vspace is not requester:
            # cross-tenant spill: touching the victim's cold page out is
            # on the requester's critical path (seed KV-spill accounting)
            self._acct(requester, spills=1,
                       simulated_us=self.cost.touch_page_us)
        frame = self.pool.alloc()
        assert frame is not None
        return frame

    def _map_page(self, space: AddressSpace, vpage: int,
                  victims: Optional[Sequence[AddressSpace]],
                  fresh: bool = False) -> int:
        if space.page_table[vpage] != NON_RESIDENT:
            return int(space.page_table[vpage])
        frame = self.pool.alloc()
        if frame is None:
            frame = self._evict_for(space, victims)
        if not fresh and space.backing is not None:
            self.pool.load(frame, space.backing[vpage])
        space.page_table[vpage] = frame
        space.swapped[vpage] = False
        space.last_used[vpage] = self._clock
        self.eviction.note_map(space, vpage)
        if not fresh:
            self._acct(space, pages_in=1)
        return frame

    # -------------------------------------------------------- fault events
    def _fault_event(self, space: AddressSpace, pages: Sequence[int],
                     victims: Optional[Sequence[AddressSpace]],
                     stream: Sequence[int] = ()) -> int:
        """One resolution event: page in ``pages`` (+``stream``), charge
        the strategy's cost and the pool backend's transport cost."""
        pol = self.policy_of(space)
        paged = [v for v in pages
                 if space.page_table[v] == NON_RESIDENT]
        for v in paged:
            self._map_page(space, v, victims)
        streamed = [v for v in stream
                    if space.page_table[v] == NON_RESIDENT]
        for v in streamed:
            self._map_page(space, v, victims)
            space.prefetched[v] = True
        # all block pages beyond the faulted one rode along: prefetched
        for v in paged[1:]:
            space.prefetched[v] = True
        c = self.cost
        osp = self._os_pages_per_page
        if pol.strategy is Strategy.TOUCH_A_PAGE:
            events = osp * max(1, len(paged))
            self._acct(space, faults=events, simulated_us=events * (
                c.netlink_send_us + c.wakeup_us + c.touch_page_us))
        else:
            cap = max(1, pol.lookahead)
            us = c.gup_us(max(1, min(len(paged) * osp, cap)))
            us += min(len(streamed) * osp, cap) * c.gup_per_page_us
            self._acct(space, faults=1, simulated_us=us)
        # transport: contiguous runs, one backend page-in per run.  Demand
        # pages (the faulted block) go first as LATENCY traffic; predictive
        # stream warm-ups ride behind them as BULK (fabric-backed pools
        # thread the class into the DMA arbiter via post_read).
        for pages, is_prefetch in ((paged, False), (streamed, True)):
            for start, n in _runs(sorted(pages)):
                r = self.pool.page_in(space, start, n, prefetch=is_prefetch)
                self._acct(space, simulated_us=r.us,
                           remote_reads=r.remote_reads,
                           rapf_retransmits=r.rapf_retransmits,
                           remote_dst_faults=r.dst_faults,
                           remote_bytes_in=r.bytes_in,
                           failovers=r.failovers,
                           mtt_hits=r.mtt_hits,
                           mtt_misses=r.mtt_misses,
                           mtt_stale=r.mtt_stale,
                           pool_redirects=r.pool_redirects)
        return len(paged) + len(streamed)

    def fault_in(self, space: AddressSpace, vpage: int,
                 victims: Optional[Sequence[AddressSpace]] = None) -> int:
        """Resolve a fault at ``vpage`` with the policy's prefetch."""
        block, stream = predictor_for(self.policy_of(space)).predict(
            space, vpage)
        return self._fault_event(space, [vpage] + block, victims,
                                 stream=stream)

    def resolve_batch(self, space: AddressSpace, vpages,
                      victims: Optional[Sequence[AddressSpace]] = None
                      ) -> int:
        """Resolve a known set of non-resident pages (pre-dispatch
        residency, KV fault-back-in): block strategies take one event per
        ``lookahead`` pages of the sorted set, Touch-A-Page one each."""
        self._tick()
        pol = self.policy_of(space)
        todo = sorted(int(v) for v in np.atleast_1d(vpages)
                      if space.page_table[int(v)] == NON_RESIDENT)
        n = 0
        if pol.strategy is Strategy.TOUCH_A_PAGE:
            for v in todo:
                n += self._fault_event(space, [v], victims)
        else:
            la = max(1, pol.lookahead)
            for i in range(0, len(todo), la):
                n += self._fault_event(space, todo[i:i + la], victims)
        return n

    # ------------------------------------------------------------- verbs
    def map_fresh(self, space: AddressSpace, vpage: int,
                  victims: Optional[Sequence[AddressSpace]] = None) -> int:
        """Allocate+map a brand-new page (no backing page-in): the KV
        append path, where the payload is produced on device."""
        self._tick()
        return self._map_page(space, vpage, victims, fresh=True)

    def access(self, space: AddressSpace, vpages) -> jnp.ndarray:
        """Read pages, faulting in non-resident ones; (n, page_elems)."""
        vpages = np.atleast_1d(np.asarray(vpages, np.int64))
        self._tick()
        for v in map(int, vpages):
            if space.page_table[v] == NON_RESIDENT:
                self.fault_in(space, v)
            elif space.prefetched[v]:
                self._acct(space, prefetch_hits=1)
                space.prefetched[v] = False
            space.last_used[v] = self._clock
            self.eviction.note_access(space, v)
        return self.pool.gather(space.page_table[vpages])

    def ensure_resident(self, space: AddressSpace, vpages,
                        victims: Optional[Sequence[AddressSpace]] = None
                        ) -> int:
        """Fault in any non-resident ``vpages`` (with prefetch), without
        reading them back; returns pages paged in."""
        self._tick()
        n = 0
        for v in map(int, np.atleast_1d(vpages)):
            if space.page_table[v] == NON_RESIDENT:
                n += self.fault_in(space, v, victims)
            space.last_used[v] = self._clock
        return n

    def pin(self, space: AddressSpace, vpages,
            victims: Optional[Sequence[AddressSpace]] = None) -> None:
        """Page in and pin; enforces the FaultPolicy pin budget.

        Duplicate vpages pin (and charge ``pin_us`` for) one page, not
        one per occurrence: the budget check counts *distinct* new pins,
        so ``pin([v, v])`` with one page of headroom succeeds.
        """
        vp = np.atleast_1d(vpages)
        uniq = list(dict.fromkeys(map(int, vp)))   # dedup, order-preserving
        pol = self.policy_of(space)
        if pol.pin_limit_bytes is not None:
            would = (int(space.pinned.sum())
                     + sum(1 for v in uniq if not space.pinned[v]))
            if would * self.page_bytes > pol.pin_limit_bytes:
                self._acct(space, pin_violations=1)
                raise MemoryError(
                    f"pin budget exceeded: {would} pages x "
                    f"{self.page_bytes} B > pin_limit_bytes="
                    f"{pol.pin_limit_bytes} (tenant {space.name!r})")
        self._tick()
        for v in uniq:
            self._map_page(space, v, victims)
            space.pinned[v] = True
        self._acct(space,
                   simulated_us=self.cost.pin_us(len(uniq) * self.page_bytes))

    def unpin(self, space: AddressSpace, vpages) -> None:
        vp = np.atleast_1d(vpages)
        uniq = list(dict.fromkeys(map(int, vp)))
        for v in uniq:
            space.pinned[v] = False
        self._acct(space, simulated_us=self.cost.unpin_us(
            len(uniq) * self.page_bytes))


def _runs(pages: Sequence[int]) -> list[tuple[int, int]]:
    """Collapse a sorted page list into (start, length) contiguous runs."""
    out: list[tuple[int, int]] = []
    for v in pages:
        if out and out[-1][0] + out[-1][1] == v:
            out[-1] = (out[-1][0], out[-1][1] + 1)
        else:
            out.append((v, 1))
    return out
