"""Unified paging telemetry: one dataclass for every memory consumer.

The seed grew four parallel stats records (``StoreStats``, ``KVStats``,
``OffloadStats`` and the fault fields of ``EngineStats``), each with its
own reset logic and half-overlapping field names.  :class:`PagingStats`
replaces all of them: every :class:`~repro.vmem.pager.AddressSpace` and
every :class:`~repro.vmem.pager.Pager` owns one, and the legacy names are
kept as aliases/properties so existing callers keep working.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class PagingStats:
    """Telemetry of one pager (aggregate) or one address space (tenant)."""

    # ---- fault path ------------------------------------------------------
    faults: int = 0              # fault-resolution events
    pages_in: int = 0            # pages paged in at fault/pin time
    pages_out: int = 0           # pages written back / dropped on eviction
    evictions: int = 0
    prefetch_hits: int = 0       # accesses that found a prefetched page
    pin_violations: int = 0      # pool exhausted with everything pinned,
    #                              or a FaultPolicy pin budget exceeded
    # ---- multi-tenant ----------------------------------------------------
    allocs: int = 0              # address spaces created on this pager
    spills: int = 0              # cross-tenant evictions (another space's
    #                              page evicted to satisfy this tenant)
    # ---- remote (fabric-backed) page-ins ---------------------------------
    remote_reads: int = 0        # verbs post_read page-in operations
    remote_bytes_in: int = 0
    remote_dst_faults: int = 0   # destination faults of those reads
    rapf_retransmits: int = 0    # RAPF-triggered retransmits of those reads
    failovers: int = 0           # page-ins re-served by the replica pager
    #                              after the primary backing node crashed
    # ---- NP-RDMA backend (reads through a Strategy.NP_RDMA domain) -------
    mtt_hits: int = 0            # translations served by a fresh MTT entry
    mtt_misses: int = 0          # uncached translations (filled host-side)
    mtt_stale: int = 0           # stale entries caught by verification
    pool_redirects: int = 0      # pages redirected through the DMA pool
    # ---- streaming consumers (block-wise optimizer offload) --------------
    blocks_streamed: int = 0
    prefetch_overlapped: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    # ---- time ------------------------------------------------------------
    simulated_us: float = 0.0    # calibrated cost-model time

    # legacy aliases (KVStats / OffloadStats vocabulary) -------------------
    @property
    def fault_events(self) -> int:
        return self.faults

    @property
    def fault_page_ins(self) -> int:
        return self.pages_in

    def reset(self) -> None:
        """Zero every counter (all fields default to their zero)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    def merge(self, other: "PagingStats") -> None:
        """Accumulate another record into this one (fleet roll-ups)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
