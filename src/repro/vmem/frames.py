"""Frame pools: where resident pages live.

A :class:`FramePool` is a fixed set of page frames shared by one or more
:class:`~repro.vmem.pager.AddressSpace` tenants.  Backends differ in where
the frame payload lives and how a page-in arrives:

* :class:`DeviceFramePool` — frames are rows of a device ``jnp`` array
  (the JAX data plane; copies are real);
* :class:`HostFramePool` — frames are rows of a host ``numpy`` array
  (a second-tier pool, e.g. host swap in front of remote memory);
* :class:`FrameIdPool` — control-plane only: frames are just ids (the KV
  manager's case, where payload lives in the compiled step's cache pools);
* :class:`~repro.vmem.remote.RemoteFramePool` — decorates any of the
  above so page-ins travel over the verbs fabric (``post_read`` + CQ).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PageInReceipt:
    """What one backend page-in cost (returned by ``page_in``)."""
    us: float = 0.0
    remote_reads: int = 0
    rapf_retransmits: int = 0
    dst_faults: int = 0
    bytes_in: int = 0
    # crash-fault layer: page-ins served by the replica pager after the
    # primary backing node failed (RemoteFramePool failover)
    failovers: int = 0
    # NP-RDMA backend counters (zero when the domain runs the thesis path)
    mtt_hits: int = 0
    mtt_misses: int = 0
    mtt_stale: int = 0
    pool_redirects: int = 0


class FramePool:
    """Base pool: allocation bookkeeping + the payload/transport hooks."""

    def __init__(self, n_frames: int, page_elems: int):
        self.n_frames = n_frames
        self.page_elems = page_elems
        self.free: list[int] = list(range(n_frames - 1, -1, -1))
        # every address space mapped over this pool, across ALL pagers —
        # the default eviction-candidate set, so consumers sharing a pool
        # (pool=...) contend correctly even with separate Pager instances
        self.spaces: list = []

    # ------------------------------------------------------------ lifetime
    def alloc(self) -> Optional[int]:
        """Pop a free frame, or None if the pool is exhausted."""
        return self.free.pop() if self.free else None

    def release(self, frame: int) -> None:
        self.free.append(frame)

    @property
    def frames_used(self) -> int:
        return self.n_frames - len(self.free)

    # ---------------------------------------------------------- data plane
    def load(self, frame: int, data: np.ndarray) -> None:
        """Copy page payload into ``frame`` (no-op for id-only pools)."""

    def store(self, frame: int) -> Optional[np.ndarray]:
        """Read a frame's payload back out (writeback); None if id-only."""
        return None

    def gather(self, frames: np.ndarray) -> jnp.ndarray:
        """Gather frame rows for an access; (n, page_elems)."""
        raise NotImplementedError(f"{type(self).__name__} holds no payload")

    # ------------------------------------------------------------ transport
    def page_in(self, space, vpage: int, n_pages: int,
                prefetch: bool = False) -> PageInReceipt:
        """Transport cost of paging ``n_pages`` starting at ``vpage``.

        Local pools are free (the resolver strategy already accounts the
        fault-handling time); the remote backend posts a verbs read here.
        ``prefetch`` marks predictive (non-demand) page-ins, which
        fabric-backed pools schedule as BULK instead of LATENCY traffic.
        """
        return PageInReceipt()


class DeviceFramePool(FramePool):
    """Device (jnp) frame pool — the compiled kernels' working set."""

    def __init__(self, n_frames: int, page_elems: int, dtype=jnp.float32):
        super().__init__(n_frames, page_elems)
        self.dtype = dtype
        self.data = jnp.zeros((n_frames, page_elems), dtype)

    def load(self, frame: int, data: np.ndarray) -> None:
        self.data = self.data.at[frame].set(jnp.asarray(data, self.dtype))

    def store(self, frame: int) -> np.ndarray:
        return np.asarray(self.data[frame])

    def gather(self, frames: np.ndarray) -> jnp.ndarray:
        return jnp.take(self.data, jnp.asarray(frames, jnp.int32), axis=0)


class HostFramePool(FramePool):
    """Host (numpy) frame pool — a spill tier or CPU-side working set."""

    def __init__(self, n_frames: int, page_elems: int, dtype=np.float32):
        super().__init__(n_frames, page_elems)
        self.dtype = jax.dtypes.canonicalize_dtype(dtype)
        self.data = np.zeros((n_frames, page_elems), self.dtype)

    def load(self, frame: int, data: np.ndarray) -> None:
        self.data[frame] = np.asarray(data, self.dtype).reshape(-1)

    def store(self, frame: int) -> np.ndarray:
        return self.data[frame].copy()

    def gather(self, frames: np.ndarray) -> jnp.ndarray:
        return jnp.asarray(self.data[np.asarray(frames, np.int64)])


class FrameIdPool(FramePool):
    """Control-plane pool: frames are ids only (payload lives elsewhere,
    e.g. in the serving engine's compiled-step cache pools)."""

    def __init__(self, n_frames: int):
        super().__init__(n_frames, page_elems=0)
