"""Prefetch predictors: which extra pages ride along with a fault.

Lifted out of the seed's per-consumer pagers so one implementation serves
tensors, KV frames and optimizer blocks alike.  A predictor returns two
page lists for a fault at ``vpage``:

* ``block`` — pages resolved inside the same fault event (the thesis'
  ``get_user_pages`` Touch-Ahead block, charged via ``gup_us``);
* ``stream`` — sequential-stream predictions beyond the block (charged
  per page, ``gup_per_page_us``), the beyond-paper STREAM variant.
"""

from __future__ import annotations

from repro.api.policy import FaultPolicy
from repro.core.resolver import Strategy


class PrefetchPredictor:
    def predict(self, space, vpage: int) -> tuple[list[int], list[int]]:
        """-> (block_pages, stream_pages), both excluding ``vpage``."""
        raise NotImplementedError


class NoPrefetch(PrefetchPredictor):
    """Touch-A-Page: exactly the faulted page."""

    def predict(self, space, vpage: int) -> tuple[list[int], list[int]]:
        return [], []


class TouchAheadPrefetch(PrefetchPredictor):
    """The faulted page + the rest of its ``lookahead``-page block."""

    def __init__(self, lookahead: int = 4):
        self.lookahead = max(1, lookahead)

    def predict(self, space, vpage: int) -> tuple[list[int], list[int]]:
        end = min(space.n_pages, vpage + self.lookahead)
        return list(range(vpage + 1, end)), []


class StreamPrefetch(TouchAheadPrefetch):
    """Touch-Ahead + the first page of the next block, so a sequential
    stream's next fault never lands on the critical path."""

    def predict(self, space, vpage: int) -> tuple[list[int], list[int]]:
        block, _ = super().predict(space, vpage)
        nxt = vpage + self.lookahead
        stream = [nxt] if nxt < space.n_pages else []
        return block, stream


def predictor_for(policy: FaultPolicy) -> PrefetchPredictor:
    """The predictor a :class:`FaultPolicy`'s strategy implies."""
    s = policy.strategy
    if s is Strategy.TOUCH_A_PAGE:
        return NoPrefetch()
    if s is Strategy.STREAM:
        return StreamPrefetch(policy.lookahead)
    # TOUCH_AHEAD / TOUCH_AHEAD_N / KERNEL_RAPF all page in block-wise
    return TouchAheadPrefetch(policy.lookahead)
