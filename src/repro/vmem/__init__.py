"""``repro.vmem`` — the unified demand-paging subsystem.

The thesis' core claim is that page faults can be *handled*, not
avoided, so one mechanism can serve every memory consumer without
pinning ceremony.  This package is that mechanism as an API:

* :class:`AddressSpace` + :class:`Pager` — fault → resolve → map, with
  per-tenant :class:`~repro.api.policy.FaultPolicy` threading;
* :class:`FramePool` backends — :class:`DeviceFramePool` (jnp),
  :class:`HostFramePool` (numpy), :class:`FrameIdPool` (control-plane
  only) and :class:`RemoteFramePool` (page-ins over the verbs fabric:
  ``post_read`` + CQ completions, RAPF stats surfaced);
* pluggable eviction (:class:`LRUEviction`, :class:`ClockEviction`,
  :class:`PinAwareLRU`) and prefetch predictors (:class:`NoPrefetch`,
  :class:`TouchAheadPrefetch`, :class:`StreamPrefetch`);
* one :class:`PagingStats` telemetry record for everything.

``repro.memory.paged_store.PagedTensorStore``,
``repro.memory.kv_cache.PagedKVManager``,
``repro.memory.offload.PagedAdamW`` and
``repro.serving.engine.ServingEngine`` are thin wrappers over this one
pager — serving KV spill/fault-back-in and optimizer-state streaming are
scenarios of the same subsystem.

Quick tour::

    from repro.vmem import DeviceFramePool, Pager
    from repro.api import FaultPolicy, Strategy

    pool = DeviceFramePool(n_frames=64, page_elems=1024)
    pager = Pager(pool, policy=FaultPolicy(Strategy.TOUCH_AHEAD))
    a = pager.create_space(256, name="tenant-a")
    b = pager.create_space(256, name="tenant-b",
                           policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
    a.write(0, data)            # backing image
    x = a.access([0, 1, 2])     # faults + prefetch, per a's policy
    print(pager.stats.faults, a.stats.simulated_us)
"""

from repro.vmem.compat import coerce_policy
from repro.vmem.eviction import (ClockEviction, EvictionPolicy, LRUEviction,
                                 PinAwareLRU)
from repro.vmem.frames import (DeviceFramePool, FrameIdPool, FramePool,
                               HostFramePool, PageInReceipt)
from repro.vmem.pager import NON_RESIDENT, AddressSpace, Pager
from repro.vmem.prefetch import (NoPrefetch, PrefetchPredictor,
                                 StreamPrefetch, TouchAheadPrefetch,
                                 predictor_for)
from repro.vmem.remote import RemoteFramePool
from repro.vmem.stats import PagingStats

__all__ = [
    "AddressSpace", "ClockEviction", "DeviceFramePool", "EvictionPolicy",
    "FrameIdPool", "FramePool", "HostFramePool", "LRUEviction",
    "NON_RESIDENT", "NoPrefetch", "PageInReceipt", "Pager", "PagingStats",
    "PinAwareLRU", "PrefetchPredictor", "RemoteFramePool", "StreamPrefetch",
    "TouchAheadPrefetch", "coerce_policy", "predictor_for",
]
