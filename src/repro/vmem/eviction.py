"""Pluggable eviction policies for the shared frame pool.

A policy selects a ``(space, vpage)`` victim among candidate address
spaces; it must never pick a pinned page (the pager raises the thesis'
pinning-limit ``MemoryError`` when nothing unpinned is left).

* :class:`LRUEviction` — least-recently-used across every candidate
  space (the seed ``PagedTensorStore`` behaviour).
* :class:`ClockEviction` — second-chance: a hand sweeps the resident
  pages, clearing reference bits and evicting the first cold page.
* :class:`PinAwareLRU` — multi-tenant fairness: the victim comes from
  the candidate space holding the most *unpinned resident* frames (the
  tenant hogging the pool pays), LRU within it.  Tenants that pin their
  working set cannot starve the others below their own footprint.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

NON_RESIDENT = -1


def _resident_unpinned(space) -> np.ndarray:
    return np.where((space.page_table != NON_RESIDENT) & ~space.pinned)[0]


class EvictionPolicy:
    """Interface: bookkeeping hooks + victim selection."""

    def note_map(self, space, vpage: int) -> None:
        pass

    def note_access(self, space, vpage: int) -> None:
        pass

    def note_unmap(self, space, vpage: int) -> None:
        pass

    def select_victim(self, spaces) -> Optional[tuple]:
        """Pick ``(space, vpage)`` to evict, or None if all pinned/empty."""
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    def select_victim(self, spaces) -> Optional[tuple]:
        best = None
        best_used = None
        for sp in spaces:
            cands = _resident_unpinned(sp)
            if not len(cands):
                continue
            v = int(cands[np.argmin(sp.last_used[cands])])
            used = int(sp.last_used[v])
            if best is None or used < best_used:
                best, best_used = (sp, v), used
        return best


class ClockEviction(EvictionPolicy):
    """Second-chance clock over the candidates' resident pages."""

    def __init__(self):
        self._hand = 0

    def note_access(self, space, vpage: int) -> None:
        space.referenced[vpage] = True

    def note_map(self, space, vpage: int) -> None:
        space.referenced[vpage] = True

    def select_victim(self, spaces) -> Optional[tuple]:
        ring = [(sp, int(v)) for sp in spaces
                for v in _resident_unpinned(sp)]
        if not ring:
            return None
        start = self._hand % len(ring)
        for i in range(len(ring)):
            sp, v = ring[(start + i) % len(ring)]
            if not sp.referenced[v]:
                self._hand = start + i + 1
                return sp, v
            sp.referenced[v] = False       # second chance granted
        # every page was referenced: the sweep cleared all bits, so the
        # page under the hand is now the (cold) victim
        sp, v = ring[start]
        self._hand = start + 1
        return sp, v


class PinAwareLRU(EvictionPolicy):
    """Fairness under pinning: evict from the biggest unpinned holder."""

    def select_victim(self, spaces) -> Optional[tuple]:
        best_space = None
        best_cands = None
        for sp in spaces:
            cands = _resident_unpinned(sp)
            if not len(cands):
                continue
            if best_cands is None or len(cands) > len(best_cands):
                best_space, best_cands = sp, cands
        if best_space is None:
            return None
        v = int(best_cands[np.argmin(best_space.last_used[best_cands])])
        return best_space, v
