"""The routed interconnect: topology + router + per-direction links.

One :class:`Interconnect` is shared by every node of a fabric.  It owns

* a directed :class:`~repro.net.link.Link` per physical adjacency of the
  :class:`~repro.net.topology.Topology` (plus one loopback link per
  node),
* a deterministic :class:`~repro.net.router.Router`,
* memoized :class:`~repro.net.link.Path` objects — the transmit handle a
  node uses for both data pages and control packets,
* the packet-conservation ledger: every path send is recorded per
  concrete *route tuple*, so ``repro.testing`` can prove that each link
  carried exactly the packets of the routes crossing it (nothing lost,
  nothing duplicated, nothing smuggled around the topology) — keyed by
  route, not (src, dst), so the invariant survives re-pathing: packets
  that crossed the old route before a link failure and packets that
  crossed the detour after it are accounted against the links they
  *actually* traversed,
* the machine-failure model: :meth:`Interconnect.fail_link` /
  :meth:`restore_link` / :meth:`fail_node` mark directed adjacencies
  down; :meth:`path` re-routes around them (deterministic BFS detours)
  and raises :class:`~repro.net.router.NetworkPartitioned` when no live
  route remains.

Per-link telemetry rolls up into :class:`FabricStats`.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Union

from repro.net.link import Link, LinkStats, Path

if TYPE_CHECKING:                                    # pragma: no cover
    # type-only: repro.net is the bottom layer — importing repro.core at
    # runtime would pull core/__init__ -> engine -> api -> net back in
    from repro.core.costmodel import CostModel
    from repro.core.simulator import EventLoop
from repro.net.router import NetworkPartitioned, Router
from repro.net.topology import (Topology, TopologyKind, build_topology,
                                coerce_kind)


@dataclasses.dataclass
class FabricStats:
    """Fabric-wide interconnect telemetry: per-link stats + totals."""

    links: dict                      # "s->d" -> LinkStats.as_dict()
    data_packets: int = 0
    ctrl_packets: int = 0
    data_bytes: int = 0
    busy_us: float = 0.0
    queued: int = 0
    queue_us: float = 0.0
    max_queue_us: float = 0.0
    latency_overtakes: int = 0
    interleaves: int = 0
    elapsed_us: float = 0.0

    def as_dict(self) -> dict:
        """Deterministic JSON-able form (sorted link keys)."""
        return {
            "totals": {
                "data_packets": self.data_packets,
                "ctrl_packets": self.ctrl_packets,
                "data_bytes": self.data_bytes,
                "busy_us": round(self.busy_us, 6),
                "queued": self.queued,
                "queue_us": round(self.queue_us, 6),
                "max_queue_us": round(self.max_queue_us, 6),
                "latency_overtakes": self.latency_overtakes,
                "interleaves": self.interleaves,
            },
            "links": {k: self.links[k] for k in sorted(self.links)},
        }

    def max_utilization(self) -> float:
        if self.elapsed_us <= 0:
            return 0.0
        return max((v["busy_us"] / self.elapsed_us
                    for v in self.links.values()), default=0.0)


class Interconnect:
    """Topology-aware link fabric shared by all nodes of a simulation."""

    def __init__(self, loop: EventLoop, cost: CostModel,
                 topology: Union[Topology, TopologyKind, str],
                 n_nodes: Optional[int] = None,
                 dims: Optional[tuple[int, ...]] = None,
                 qos: Optional[bool] = None,
                 legacy_hops: int = 1):
        if not isinstance(topology, Topology):
            topology = build_topology(coerce_kind(topology), n_nodes, dims)
        self.loop = loop
        self.cost = cost
        self.topology = topology
        self.router = Router(topology)
        self.legacy_hops = legacy_hops
        #: link QoS (LATENCY overtakes BULK on the wire): defaults to on
        #: for routed topologies, off for the seed's dedicated ALL_TO_ALL
        self.qos = (topology.kind is not TopologyKind.ALL_TO_ALL
                    if qos is None else qos)
        self.links: dict[tuple[int, int], Link] = {}
        for (u, v) in topology.edges():
            hops = (legacy_hops
                    if topology.kind is TopologyKind.ALL_TO_ALL else 1)
            self.links[(u, v)] = Link(loop, cost, u, v, hops=hops,
                                      qos=self.qos)
        for n in range(topology.n_nodes):
            self.links[(n, n)] = Link(loop, cost, n, n, hops=1,
                                      qos=self.qos)
        self._paths: dict[tuple[int, int], Path] = {}
        #: route tuple -> [data_packets, ctrl_packets] injected — the
        #: ledger side of the per-link packet-conservation invariant.
        #: Keyed by the concrete route (not (src, dst)) so conservation
        #: holds across re-pathing: each injection is charged against the
        #: exact links its packets traversed at send time.
        self.injected: dict[tuple[int, ...], list] = {}
        #: directed adjacencies currently failed (both directions of a
        #: physical link go down together via fail_link)
        self.down: frozenset[tuple[int, int]] = frozenset()
        #: failure-epoch path memo, cleared on every fail/restore
        self._detour_paths: dict[tuple[int, int], Path] = {}

    # ---------------------------------------------------------------- paths
    def path(self, src: int, dst: int) -> Path:
        """The (memoized) routed path ``src -> dst``.

        With links down, routes detour deterministically around them;
        raises :class:`~repro.net.router.NetworkPartitioned` when no
        live route exists.  With no failures this is exactly the
        oblivious minimal route (bit-exact with the no-crash fabric).
        """
        key = (src, dst)
        if not self.down:
            p = self._paths.get(key)
            if p is None:
                p = self._make_path(self.router.route(src, dst))
                self._paths[key] = p
            return p
        p = self._detour_paths.get(key)
        if p is None:
            route = self.router.route_avoiding(src, dst, self.down)
            base = self._paths.get(key)
            if base is not None and base.route == route:
                p = base                 # clean oblivious route: reuse
            else:
                p = self._make_path(route)
            self._detour_paths[key] = p
        return p

    def _make_path(self, route: tuple[int, ...]) -> Path:
        src, dst = route[0], route[-1]
        if src == dst:
            links = (self.links[(src, src)],)
        else:
            links = tuple(self.links[(u, v)]
                          for u, v in zip(route, route[1:]))
        return Path(self.loop, self.cost, route, links,
                    ledger=self.injected)

    def link(self, src: int, dst: int) -> Link:
        """The directed link of a physical adjacency (or loopback)."""
        return self.links[(src, dst)]

    # -------------------------------------------------------------- failures
    def fail_link(self, u: int, v: int) -> None:
        """Take the physical adjacency ``u <-> v`` down (both directions).

        Future :meth:`path` lookups re-route around it; reservations
        already booked on the wire complete (a failing link does not
        destroy packets mid-flight — endpoint crash handling decides
        what a delivered packet means to a dead node).
        """
        if (u, v) not in self.links or u == v:
            raise KeyError(f"no physical adjacency {u}<->{v}")
        self.down = self.down | {(u, v), (v, u)}
        self._detour_paths.clear()

    def restore_link(self, u: int, v: int) -> None:
        """Bring the physical adjacency ``u <-> v`` back up."""
        if (u, v) not in self.links or u == v:
            raise KeyError(f"no physical adjacency {u}<->{v}")
        self.down = self.down - {(u, v), (v, u)}
        self._detour_paths.clear()

    def fail_node(self, n: int) -> None:
        """Take every physical adjacency incident to node ``n`` down."""
        self.topology._check_node(n)
        incident = {(u, v) for (u, v) in self.links
                    if u != v and (u == n or v == n)}
        self.down = self.down | incident
        self._detour_paths.clear()

    def reachable(self, src: int, dst: int) -> bool:
        """True iff a live route ``src -> dst`` exists right now."""
        try:
            self.router.route_avoiding(src, dst, self.down)
            return True
        except NetworkPartitioned:
            return False

    # ---------------------------------------------------------------- stats
    def stats(self) -> FabricStats:
        out = FabricStats(links={}, elapsed_us=self.loop.now)
        for (u, v), link in sorted(self.links.items()):
            s = link.stats
            if not (s.data_packets or s.ctrl_packets):
                continue                       # quiet links stay out
            out.links[link.name] = s.as_dict()
            for f in LinkStats.ADDITIVE:
                setattr(out, f, getattr(out, f) + getattr(s, f))
            out.max_queue_us = max(out.max_queue_us, s.max_queue_us)
        out.busy_us = round(out.busy_us, 6)
        out.queue_us = round(out.queue_us, 6)
        out.max_queue_us = round(out.max_queue_us, 6)
        return out

    # ----------------------------------------------------------- invariants
    def conservation_violations(self) -> list[str]:
        """Per-link packet conservation against the injection ledger.

        The ledger is keyed by the concrete route tuple each packet was
        sent along, so the expected per-link counts are a pure fold over
        the ledger — no route recomputation, which is what keeps the
        invariant meaningful across link failures and re-pathing (a
        post-failure recompute would charge pre-failure packets to the
        detour they never took).
        """
        expect_data: dict[tuple[int, int], int] = {}
        expect_ctrl: dict[tuple[int, int], int] = {}
        # lint: allow(det-dict-iter): commutative += accumulation
        for route, (n_data, n_ctrl) in self.injected.items():
            for hop in zip(route, route[1:]):
                expect_data[hop] = expect_data.get(hop, 0) + n_data
                expect_ctrl[hop] = expect_ctrl.get(hop, 0) + n_ctrl
        out = []
        for key, link in sorted(self.links.items()):
            want_d = expect_data.get(key, 0)
            want_c = expect_ctrl.get(key, 0)
            if link.stats.data_packets != want_d:
                out.append(
                    f"link {link.name}: carried {link.stats.data_packets} "
                    f"data packets, routes injected {want_d}")
            if link.stats.ctrl_packets != want_c:
                out.append(
                    f"link {link.name}: carried {link.stats.ctrl_packets} "
                    f"ctrl packets, routes injected {want_c}")
        return out
