"""Per-direction link resources and routed paths.

A :class:`Link` is ONE direction of one physical adjacency: a serially
occupied wire with microsecond-resolution reservation cursors, per-link
telemetry (:class:`LinkStats`) and the PLDMA interleave heuristic the
Fig 4.2 dampening model relies on.  A :class:`Path` is the routed chain
of links between two nodes; everything a node transmits — a page of
packets, an ACK, a NACK, a RAPF mailbox message — goes through a path,
so cross-tenant traffic meeting on a shared link genuinely contends.

**Service classes on the wire.**  With ``qos`` enabled (the default on
routed topologies) each link arbitrates like the DMA arbiter's class
scheme (:class:`~repro.core.arbiter.ServiceClass`): LATENCY-class
reservations queue only behind other LATENCY traffic and *overtake* the
BULK backlog (which is pushed back by the stolen wire time), so
fault-resolution control packets stay bounded on hops congested by a
BULK retransmit storm.  With ``qos`` off (legacy ALL_TO_ALL) a link is a
single FIFO cursor — bit-for-bit the seed's behavior — and control
packets charge wire + routed distance without booking the link.

**Interleave hygiene** (ISSUE-4 satellite): ``last_user`` — the stream
identity used to detect two blocks interleaving their packets on one
wire — is cleared whenever the link has fully drained, so a stream that
finished long ago can never flag a later, lone stream as interleaved and
inflate its FIFO dedup-break pushes.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Hashable, Optional

if TYPE_CHECKING:                                    # pragma: no cover
    # type-only: repro.net is the bottom layer — importing repro.core at
    # runtime would pull core/__init__ -> engine -> api -> net back in
    from repro.core.costmodel import CostModel
    from repro.core.simulator import EventLoop


@dataclasses.dataclass(slots=True)
class LinkStats:
    """One direction's wire telemetry (all additive except the maxima)."""

    data_packets: int = 0        # page-stream reservations carried
    ctrl_packets: int = 0        # ACK/NACK/RAPF/read-request messages
    data_bytes: int = 0          # payload bytes serialized
    busy_us: float = 0.0         # wire time booked
    queued: int = 0              # reservations that had to wait
    queue_us: float = 0.0        # total waiting time
    max_queue_us: float = 0.0    # worst single wait (not additive)
    latency_overtakes: int = 0   # LATENCY reservations that jumped BULK
    interleaves: int = 0         # streams flagged interleaved here

    ADDITIVE = ("data_packets", "ctrl_packets", "data_bytes", "busy_us",
                "queued", "queue_us", "latency_overtakes", "interleaves")

    def as_dict(self) -> dict:
        return {
            "data_packets": self.data_packets,
            "ctrl_packets": self.ctrl_packets,
            "data_bytes": self.data_bytes,
            "busy_us": round(self.busy_us, 6),
            "queued": self.queued,
            "queue_us": round(self.queue_us, 6),
            "max_queue_us": round(self.max_queue_us, 6),
            "latency_overtakes": self.latency_overtakes,
            "interleaves": self.interleaves,
        }


class Link:
    """One direction of one physical adjacency (or a node's loopback).

    ``hops`` scales the propagation latency charged per traversal — 1 for
    a real physical link; the legacy ALL_TO_ALL topology keeps the seed's
    ``FabricConfig.hops`` alias by building direct links with
    ``hops=config.hops``.
    """

    __slots__ = ("loop", "cost", "src", "dst", "hops", "qos",
                 "busy_until", "lat_busy_until", "last_user", "stats")

    def __init__(self, loop: EventLoop, cost: CostModel, src: int, dst: int,
                 hops: int = 1, qos: bool = False):
        self.loop = loop
        self.cost = cost
        self.src = src
        self.dst = dst
        self.hops = hops
        self.qos = qos
        self.busy_until = 0.0        # BULK (and, qos off, only) cursor
        self.lat_busy_until = 0.0    # LATENCY-class cursor (qos only)
        self.last_user: Optional[Hashable] = None  # stream key
        self.stats = LinkStats()

    # ---------------------------------------------------------------- state
    @property
    def name(self) -> str:
        return f"{self.src}->{self.dst}"

    @property
    def latency_us(self) -> float:
        """Propagation latency charged per traversal of this link."""
        return self.hops * self.cost.hop_latency_us

    @property
    def drained(self) -> bool:
        """No reservation extends past *now*: the wire is idle."""
        now = self.loop.now
        return self.busy_until <= now and self.lat_busy_until <= now

    def utilization(self, elapsed_us: float) -> float:
        return self.stats.busy_us / elapsed_us if elapsed_us > 0 else 0.0

    # ------------------------------------------------------------- reserve
    def reserve(self, wire_us: float, earliest: float,
                latency_class: bool = False) -> tuple[float, float]:
        """Book ``wire_us`` of serialization no earlier than ``earliest``.

        Returns ``(start, end)``.  LATENCY-class reservations (qos links
        only) queue behind LATENCY traffic alone and push the BULK
        backlog back by the wire time they steal.
        """
        # (hot path: one call per page per hop of a routed stream — locals
        # bound once, the drained check inlined instead of the property)
        now = self.loop.now
        st = self.stats
        if self.busy_until <= now and self.lat_busy_until <= now:
            # the wire went idle since the previous reservation: whatever
            # streamed last finished long ago and must not be mistaken
            # for a live interleaving stream by the next data packet
            self.last_user = None
        floor = earliest if earliest > now else now
        if latency_class and self.qos:
            start = max(floor, self.lat_busy_until)
            end = start + wire_us
            self.lat_busy_until = end
            if self.busy_until > start:          # jumped a BULK backlog
                if wire_us > 0:
                    st.latency_overtakes += 1
                self.busy_until += wire_us       # stolen wire time
            else:
                self.busy_until = end
        else:
            start = max(floor, self.busy_until,
                        self.lat_busy_until if self.qos else 0.0)
            end = start + wire_us
            self.busy_until = end
        waited = start - floor
        if waited > 0:
            st.queued += 1
            st.queue_us += waited
            if waited > st.max_queue_us:
                st.max_queue_us = waited
        st.busy_us += wire_us
        return start, end

    # ----------------------------------------------------------- data path
    def stream_page(self, nbytes: int, block_key: Hashable, earliest: float,
                    latency_class: bool = False) -> tuple[float, bool]:
        """Serialize one page worth of packets of stream ``block_key``.

        Returns ``(end_time, interleaved_with_another_live_stream)``.
        """
        # a stream that finished long ago cannot interleave with us: the
        # drained check (mirrored inside reserve for control bookings)
        # forgets it before the comparison
        now = self.loop.now
        live = self.busy_until > now or self.lat_busy_until > now
        lu = self.last_user
        interleaved = live and lu is not None and lu != block_key
        _, end = self.reserve(self.cost.packet_wire_us(nbytes), earliest,
                              latency_class=latency_class)
        self.last_user = block_key
        st = self.stats
        st.data_packets += 1
        st.data_bytes += nbytes
        if interleaved:
            st.interleaves += 1
        return end, interleaved

    # -------------------------------------------------------- control path
    def send_ctrl(self, nbytes: int, earliest: float,
                  latency_class: bool = True) -> float:
        """Carry one control message (ACK/NACK/RAPF/request) across.

        Returns the time the message clears this link's wire.  On qos
        links control messages book wire time (and so contend — with
        LATENCY priority by default); on legacy links they charge
        serialization + distance without booking, preserving the seed's
        dedicated-link timing bit-for-bit.
        """
        wire_us = self.cost.packet_wire_us(nbytes) if nbytes > 0 else 0.0
        self.stats.ctrl_packets += 1
        if self.qos:
            _, end = self.reserve(wire_us, earliest,
                                  latency_class=latency_class)
            return end
        return max(self.loop.now, earliest) + wire_us


class Path:
    """The routed chain of directed links between two nodes.

    Reservations chain: a packet cannot start serializing on hop *i+1*
    before it cleared hop *i* (virtual cut-through at page granularity),
    so congestion on any shared link along the route delays the packet
    and everything queued behind it.
    """

    __slots__ = ("loop", "cost", "route", "links", "n_hops", "ledger",
                 "_ledger_rec", "latency_us", "_wire_div")

    def __init__(self, loop: EventLoop, cost: CostModel,
                 route: tuple[int, ...], links: tuple[Link, ...],
                 ledger: Optional[dict] = None):
        self.loop = loop
        self.cost = cost
        self.route = route
        self.links = links
        #: propagation distance: the sum of per-link hop charges (equals
        #: len(links) on physical topologies; the legacy ALL_TO_ALL alias
        #: scales its single direct link instead)
        self.n_hops = sum(l.hops for l in links)
        self.ledger = ledger            # (src, dst) -> [data, ctrl] counts
        self._ledger_rec = None         # this path's entry, bound lazily
        #: routed propagation charge, precomputed once per path — the
        #: per-packet hot path reads a slot instead of multiplying (the
        #: operands are both route/cost constants, so this is bit-exact)
        self.latency_us = self.n_hops * cost.hop_latency_us
        #: CostModel.packet_wire_us inlined: ``(nbytes * 8) / _wire_div``
        #: is the identical expression with the divisor hoisted
        self._wire_div = cost.link_gbps * 1e3

    @property
    def src(self) -> int:
        return self.route[0]

    @property
    def dst(self) -> int:
        return self.route[-1]

    def stream_page(self, nbytes: int, block_key: Hashable,
                    latency_class: bool = False) -> tuple[float, bool]:
        """Reserve wire time on every link along the route for one page.

        Returns ``(arrival_delay_from_now, interleaved)`` — the same
        contract the seed's single :class:`Link` offered the PLDMA model.
        """
        now = self.loop.now
        wire_us = (nbytes * 8) / self._wire_div   # CostModel.packet_wire_us
        t = now
        interleaved = False
        for link in self.links:
            # Inlined Link.stream_page + Link.reserve — the call pair per
            # page per hop (and the per-hop packet_wire_us recompute of a
            # route-constant value) was measurable at million-block scale.
            # Bit-identical to the Link methods, which remain the single-
            # link API for control paths and tests.
            st = link.stats
            bb = link.busy_until
            lb = link.lat_busy_until
            if bb > now or lb > now:
                lu = link.last_user
                if lu is not None and lu != block_key:
                    interleaved = True
                    st.interleaves += 1
            else:
                # drained: a stream that finished long ago must not flag
                # this one as interleaved (same hygiene as Link.reserve)
                link.last_user = None
            floor = t if t > now else now
            if latency_class and link.qos:
                start = floor if floor > lb else lb
                end = start + wire_us
                link.lat_busy_until = end
                if bb > start:                   # jumped a BULK backlog
                    if wire_us > 0:
                        st.latency_overtakes += 1
                    link.busy_until = bb + wire_us   # stolen wire time
                else:
                    link.busy_until = end
            else:
                start = floor
                if bb > start:
                    start = bb
                if link.qos and lb > start:
                    start = lb
                end = start + wire_us
                link.busy_until = end
            waited = start - floor
            if waited > 0:
                st.queued += 1
                st.queue_us += waited
                if waited > st.max_queue_us:
                    st.max_queue_us = waited
            st.busy_us += wire_us
            link.last_user = block_key
            st.data_packets += 1
            st.data_bytes += nbytes
            t = end
        if self.ledger is not None:
            self._ledger()[0] += 1
        return (t - now) + self.latency_us, interleaved

    def send_ctrl(self, nbytes: int = 0,
                  latency_class: bool = True) -> float:
        """Carry one control message along the route.

        Returns the delay from *now* until delivery: per-link wire /
        queueing plus the full routed propagation distance — the ISSUE-4
        control-packet distance-accounting fix (the seed charged a single
        ``hop_latency_us`` however far apart the nodes were).
        """
        now = self.loop.now
        wire_us = (nbytes * 8) / self._wire_div if nbytes > 0 else 0.0
        t = now
        for link in self.links:
            # Inlined Link.send_ctrl + Link.reserve — every ACK/NACK/RAPF
            # books per hop on the same hot path as data pages.
            st = link.stats
            st.ctrl_packets += 1
            if not link.qos:
                # legacy links never book: serialization + distance only
                t = (now if now > t else t) + wire_us
                continue
            bb = link.busy_until
            lb = link.lat_busy_until
            if bb <= now and lb <= now:
                link.last_user = None            # drained-wire hygiene
            floor = t if t > now else now
            if latency_class:
                start = floor if floor > lb else lb
                end = start + wire_us
                link.lat_busy_until = end
                if bb > start:                   # jumped a BULK backlog
                    if wire_us > 0:
                        st.latency_overtakes += 1
                    link.busy_until = bb + wire_us   # stolen wire time
                else:
                    link.busy_until = end
            else:
                start = floor
                if bb > start:
                    start = bb
                if lb > start:
                    start = lb
                end = start + wire_us
                link.busy_until = end
            waited = start - floor
            if waited > 0:
                st.queued += 1
                st.queue_us += waited
                if waited > st.max_queue_us:
                    st.max_queue_us = waited
            st.busy_us += wire_us
            t = end
        if self.ledger is not None:
            self._ledger()[1] += 1
        return (t - now) + self.latency_us

    def _ledger(self) -> list:
        """This path's ``[data, ctrl]`` ledger record (bound on first use
        — a dict probe per packet is measurable on million-block soaks).

        Keyed by the concrete route tuple, not (src, dst): two paths for
        the same endpoints before and after a link failure account their
        packets against the links each actually traversed, which is what
        keeps link-ledger conservation exact across down/up cycles.
        """
        rec = self._ledger_rec
        if rec is None:
            rec = self._ledger_rec = self.ledger.setdefault(
                self.route, [0, 0])
        return rec
