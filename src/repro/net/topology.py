"""Interconnect topologies for the deterministic fabric simulator.

The thesis evaluates its page-fault mechanism on the ExaNeSt prototype,
whose QFDBs (Quad FPGA Daughter Boards) wire four FPGAs into a quad and
quads into a larger multi-hop fabric over 10 Gb/s HSS links
(§ experimental setup).  The seed simulator collapsed all of that into a
single uniform ``hops`` scalar on dedicated all-to-all links; this module
models the physical adjacency explicitly so that routed traffic from
different tenants can *share* (and contend for) links.

A :class:`Topology` answers exactly two questions:

* ``neighbors(node)`` — which nodes share a physical link with ``node``;
* ``coords(node)`` — where the node sits in the topology's coordinate
  system (used by dimension-order routing).

Provided kinds:

* ``ALL_TO_ALL`` — a dedicated link between every pair (the seed's
  behavior; ``FabricConfig.hops`` scales every link's latency; with
  ``n_nodes=4`` this is one QFDB quad — its four FPGAs are fully
  connected);
* ``RING`` — 1-D torus;
* ``MESH_2D`` — rows × cols grid without wraparound;
* ``TORUS_2D`` — rows × cols grid with wraparound (how quads tile into
  the larger ExaNeSt fabric; note a 2×2 torus is NOT fully connected —
  diagonal pairs are two hops apart);
* ``DRAGONFLY`` — ``(n_groups, group_size)``: all-to-all inside a group
  (each group a quad-like clique), one global link between every pair
  of groups.
"""

from __future__ import annotations

import enum
import math
from typing import Optional, Union


class TopologyKind(enum.Enum):
    ALL_TO_ALL = "all_to_all"
    RING = "ring"
    MESH_2D = "mesh_2d"
    TORUS_2D = "torus_2d"
    DRAGONFLY = "dragonfly"


class TopologyError(ValueError):
    """Invalid topology specification (dims mismatch, too few nodes, ...)."""


class Topology:
    """Physical adjacency of the fabric (undirected; links are built
    per-direction by the :class:`~repro.net.interconnect.Interconnect`)."""

    kind: TopologyKind

    def __init__(self, n_nodes: int, dims: tuple[int, ...]):
        if n_nodes < 1:
            raise TopologyError(f"need at least one node, got {n_nodes}")
        self.n_nodes = n_nodes
        self.dims = dims

    # -- interface --------------------------------------------------------
    def neighbors(self, node: int) -> tuple[int, ...]:
        raise NotImplementedError

    def coords(self, node: int) -> tuple[int, ...]:
        """Coordinates of ``node`` (1-D for rings, (row, col) for grids)."""
        return (node,)

    # -- helpers ----------------------------------------------------------
    def edges(self) -> list[tuple[int, int]]:
        """Every directed physical adjacency, deterministically ordered."""
        out = []
        for u in range(self.n_nodes):
            for v in self.neighbors(u):
                out.append((u, v))
        return out

    def _check_node(self, node: int) -> None:
        if not 0 <= node < self.n_nodes:
            raise TopologyError(
                f"node {node} outside [0, {self.n_nodes})")

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(n_nodes={self.n_nodes}, "
                f"dims={self.dims})")


class AllToAll(Topology):
    kind = TopologyKind.ALL_TO_ALL

    def __init__(self, n_nodes: int, dims: Optional[tuple[int, ...]] = None):
        super().__init__(n_nodes, dims or (n_nodes,))

    def neighbors(self, node: int) -> tuple[int, ...]:
        self._check_node(node)
        return tuple(v for v in range(self.n_nodes) if v != node)


class Ring(Topology):
    kind = TopologyKind.RING

    def __init__(self, n_nodes: int, dims: Optional[tuple[int, ...]] = None):
        dims = dims or (n_nodes,)
        if dims != (n_nodes,):
            raise TopologyError(
                f"RING dims {dims} must be ({n_nodes},)")
        super().__init__(n_nodes, dims)

    def neighbors(self, node: int) -> tuple[int, ...]:
        self._check_node(node)
        n = self.n_nodes
        if n == 1:
            return ()
        return tuple(sorted({(node - 1) % n, (node + 1) % n}))


class Mesh2D(Topology):
    kind = TopologyKind.MESH_2D
    wrap = False

    def __init__(self, n_nodes: int, dims: Optional[tuple[int, ...]] = None):
        dims = dims or _square_dims(n_nodes)
        if len(dims) != 2 or dims[0] * dims[1] != n_nodes:
            raise TopologyError(
                f"{self.kind.value} dims {dims} do not tile {n_nodes} nodes "
                f"(need rows * cols == n_nodes)")
        super().__init__(n_nodes, dims)
        self.rows, self.cols = dims

    def coords(self, node: int) -> tuple[int, int]:
        self._check_node(node)
        return divmod(node, self.cols)

    def node_at(self, r: int, c: int) -> int:
        return r * self.cols + c

    def neighbors(self, node: int) -> tuple[int, ...]:
        r, c = self.coords(node)
        out = set()
        for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
            nr, nc = r + dr, c + dc
            if self.wrap:
                nr, nc = nr % self.rows, nc % self.cols
            elif not (0 <= nr < self.rows and 0 <= nc < self.cols):
                continue
            if (nr, nc) != (r, c):
                out.add(self.node_at(nr, nc))
        return tuple(sorted(out))


class Torus2D(Mesh2D):
    kind = TopologyKind.TORUS_2D
    wrap = True


class Dragonfly(Topology):
    """``dims = (n_groups, group_size)``: complete graph inside each group,
    one global link between every pair of groups.

    The global link between groups ``a < b`` lands on member
    ``(b - 1) % group_size`` of group ``a`` and member ``a % group_size``
    of group ``b`` — a fixed, deterministic palmtree arrangement.
    """

    kind = TopologyKind.DRAGONFLY

    def __init__(self, n_nodes: int, dims: Optional[tuple[int, ...]] = None):
        if dims is None:
            g = max(2, int(round(math.sqrt(n_nodes))))
            while n_nodes % g:
                g -= 1
            dims = (g, n_nodes // g)
        if len(dims) != 2 or dims[0] * dims[1] != n_nodes:
            raise TopologyError(
                f"dragonfly dims {dims} do not tile {n_nodes} nodes "
                f"(need n_groups * group_size == n_nodes)")
        if dims[0] < 1 or dims[1] < 1:
            raise TopologyError(f"dragonfly dims {dims} must be positive")
        super().__init__(n_nodes, dims)
        self.n_groups, self.group_size = dims

    def coords(self, node: int) -> tuple[int, int]:
        self._check_node(node)
        return divmod(node, self.group_size)

    def node_at(self, group: int, member: int) -> int:
        return group * self.group_size + member

    def gateway(self, src_group: int, dst_group: int) -> int:
        """The member of ``src_group`` holding the global link toward
        ``dst_group``."""
        if src_group < dst_group:
            member = (dst_group - 1) % self.group_size
        else:
            member = dst_group % self.group_size
        return self.node_at(src_group, member)

    def neighbors(self, node: int) -> tuple[int, ...]:
        g, m = self.coords(node)
        out = {self.node_at(g, j) for j in range(self.group_size) if j != m}
        for other in range(self.n_groups):
            if other == g:
                continue
            if self.gateway(g, other) == node:
                out.add(self.gateway(other, g))
        return tuple(sorted(out))


def _square_dims(n_nodes: int) -> tuple[int, int]:
    """Most-square rows × cols factorization of ``n_nodes``."""
    r = int(math.isqrt(n_nodes))
    while n_nodes % r:
        r -= 1
    return (r, n_nodes // r)


_KINDS: dict[TopologyKind, type] = {
    TopologyKind.ALL_TO_ALL: AllToAll,
    TopologyKind.RING: Ring,
    TopologyKind.MESH_2D: Mesh2D,
    TopologyKind.TORUS_2D: Torus2D,
    TopologyKind.DRAGONFLY: Dragonfly,
}


def coerce_kind(kind: Union[TopologyKind, str]) -> TopologyKind:
    if isinstance(kind, TopologyKind):
        return kind
    try:
        return TopologyKind(str(kind).lower())
    except ValueError:
        raise TopologyError(
            f"unknown topology {kind!r}; choose from "
            f"{sorted(k.value for k in TopologyKind)}") from None


def build_topology(kind: Union[TopologyKind, str], n_nodes: int,
                   dims: Optional[tuple[int, ...]] = None) -> Topology:
    """Instantiate a :class:`Topology` by kind name or enum member."""
    cls = _KINDS[coerce_kind(kind)]
    return cls(n_nodes, tuple(dims) if dims is not None else None)
