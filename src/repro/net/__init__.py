"""Topology-aware interconnect for the deterministic fabric simulator.

The seed modelled the network as a dedicated all-to-all :class:`Link`
per node pair with a flat ``hops`` scalar — no link was ever shared, so
cross-tenant traffic never contended and control packets could (and did,
buggily) charge a single hop regardless of distance.  This package
replaces that with a real interconnect:

* :mod:`repro.net.topology` — physical adjacency (ALL_TO_ALL, RING,
  MESH_2D, TORUS_2D matching the QFDB quad layout, DRAGONFLY);
* :mod:`repro.net.router` — deterministic minimal dimension-order
  routing, memoized;
* :mod:`repro.net.link` — per-direction wire reservation with
  LATENCY-over-BULK arbitration and per-link telemetry;
* :mod:`repro.net.interconnect` — the shared fabric object nodes
  transmit through (data pages AND control packets), with the packet
  conservation ledger and :class:`FabricStats` rollup.

Select a topology through :class:`repro.api.FabricConfig`::

    FabricConfig(n_nodes=8, topology="torus_2d", dims=(2, 4))
    FabricConfig(n_nodes=2, hops=4)      # legacy ALL_TO_ALL alias
"""

from repro.net.interconnect import FabricStats, Interconnect
from repro.net.link import Link, LinkStats, Path
from repro.net.router import NetworkPartitioned, Router, RoutingError
from repro.net.topology import (AllToAll, Dragonfly, Mesh2D, Ring, Topology,
                                TopologyError, TopologyKind, Torus2D,
                                build_topology)

__all__ = [
    "AllToAll", "Dragonfly", "FabricStats", "Interconnect", "Link",
    "LinkStats", "Mesh2D", "NetworkPartitioned", "Path", "Ring", "Router",
    "RoutingError", "Topology", "TopologyError", "TopologyKind", "Torus2D",
    "build_topology",
]
