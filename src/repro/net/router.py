"""Deterministic routing over a :class:`~repro.net.topology.Topology`.

One routing function per topology kind, all minimal and all *oblivious*
(the path depends only on (src, dst), never on load), so a simulation's
event trace stays a pure function of its inputs:

* ALL_TO_ALL — the dedicated direct link;
* RING — the shorter way around, ties broken toward increasing node ids;
* MESH_2D / TORUS_2D — dimension-order (column first, then row); the
  torus picks the shorter wrap direction per dimension, ties broken
  toward positive strides;
* DRAGONFLY — local hop to the source group's gateway, one global hop,
  local hop to the destination.

Routes are returned as node-id tuples ``(src, ..., dst)`` and memoized:
route computation is O(path length) once per (src, dst) pair.
"""

from __future__ import annotations

from collections import deque

from repro.net.topology import (Dragonfly, Mesh2D, Ring, Topology,
                                TopologyKind)


class RoutingError(RuntimeError):
    """The router produced (or was asked for) an impossible path."""


class NetworkPartitioned(RoutingError):
    """No live path exists between two endpoints.

    Raised by :meth:`Router.route_avoiding` (and so by
    ``Interconnect.path`` under link failures) when every physical route
    between ``src`` and ``dst`` crosses a down link — the typed partition
    signal the crash-fault layer turns into
    :attr:`~repro.api.completion.WCStatus.REMOTE_OP_ERR` completions.
    """


class Router:
    """Deterministic minimal router: ``route(src, dst)`` -> hop path."""

    def __init__(self, topology: Topology):
        self.topology = topology
        self._cache: dict[tuple[int, int], tuple[int, ...]] = {}

    def route(self, src: int, dst: int) -> tuple[int, ...]:
        """The node sequence ``(src, n1, ..., dst)`` a packet traverses.

        ``route(n, n)`` is the loopback path ``(n, n)``.
        """
        key = (src, dst)
        path = self._cache.get(key)
        if path is None:
            path = self._compute(src, dst)
            self._verify(path)
            self._cache[key] = path
        return path

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst)) - 1

    def route_avoiding(self, src: int, dst: int,
                       down: frozenset) -> tuple[int, ...]:
        """A live path ``src -> dst`` that crosses no link in ``down``.

        ``down`` is a set of directed ``(u, v)`` adjacencies that are
        currently failed.  The oblivious minimal route is preferred when
        it is clean (so restoring every link restores bit-exact paths);
        otherwise a deterministic BFS (neighbors expand in sorted order)
        finds a shortest detour.  Raises :class:`NetworkPartitioned`
        when no live path exists.
        """
        path = self.route(src, dst)
        if src == dst or not any(hop in down
                                 for hop in zip(path, path[1:])):
            return path
        # deterministic BFS: first-found shortest path, sorted expansion
        topo = self.topology
        prev: dict[int, int] = {src: src}
        q: deque[int] = deque((src,))
        while q:
            u = q.popleft()
            if u == dst:
                out = [dst]
                while out[-1] != src:
                    out.append(prev[out[-1]])
                out.reverse()
                path = tuple(out)
                self._verify(path)
                return path
            for v in topo.neighbors(u):
                if v not in prev and (u, v) not in down:
                    prev[v] = u
                    q.append(v)
        raise NetworkPartitioned(
            f"no live route {src}->{dst}: every path crosses a down link "
            f"({len(down)} down)")

    # ------------------------------------------------------------ internals
    def _compute(self, src: int, dst: int) -> tuple[int, ...]:
        topo = self.topology
        topo._check_node(src)
        topo._check_node(dst)
        if src == dst:
            return (src, src)
        kind = topo.kind
        if kind is TopologyKind.ALL_TO_ALL:
            return (src, dst)
        if kind is TopologyKind.RING:
            return self._route_ring(src, dst)
        if kind in (TopologyKind.MESH_2D, TopologyKind.TORUS_2D):
            return self._route_grid(src, dst)
        if kind is TopologyKind.DRAGONFLY:
            return self._route_dragonfly(src, dst)
        raise RoutingError(f"no routing function for {kind}")  # pragma: no cover

    def _route_ring(self, src: int, dst: int) -> tuple[int, ...]:
        topo: Ring = self.topology
        n = topo.n_nodes
        fwd = (dst - src) % n
        step = 1 if fwd <= n - fwd else -1
        path = [src]
        while path[-1] != dst:
            path.append((path[-1] + step) % n)
        return tuple(path)

    def _route_grid(self, src: int, dst: int) -> tuple[int, ...]:
        topo: Mesh2D = self.topology
        (sr, sc), (dr, dc) = topo.coords(src), topo.coords(dst)
        path = [src]
        wrap = topo.wrap
        # dimension order: columns (X) first, then rows (Y)
        c = sc
        while c != dc:
            c = (c + self._stride(c, dc, topo.cols, wrap)) % topo.cols
            path.append(topo.node_at(sr, c))
        r = sr
        while r != dr:
            r = (r + self._stride(r, dr, topo.rows, wrap)) % topo.rows
            path.append(topo.node_at(r, dc))
        return tuple(path)

    @staticmethod
    def _stride(cur: int, tgt: int, size: int, wrap: bool) -> int:
        if not wrap:
            return 1 if tgt > cur else -1
        fwd = (tgt - cur) % size
        return 1 if fwd <= size - fwd else -1

    def _route_dragonfly(self, src: int, dst: int) -> tuple[int, ...]:
        topo: Dragonfly = self.topology
        (sg, _), (dg, _) = topo.coords(src), topo.coords(dst)
        if sg == dg:
            return (src, dst)       # intra-group: complete graph
        out_gw = topo.gateway(sg, dg)
        in_gw = topo.gateway(dg, sg)
        path = [src]
        if out_gw != src:
            path.append(out_gw)
        path.append(in_gw)
        if in_gw != dst:
            path.append(dst)
        return tuple(path)

    def _verify(self, path: tuple[int, ...]) -> None:
        """Every consecutive pair must be a physical adjacency (or the
        loopback pair) and no intermediate node may repeat."""
        if len(path) < 2:
            raise RoutingError(f"degenerate path {path}")
        if len(path) == 2 and path[0] == path[1]:
            return                   # loopback
        topo = self.topology
        for u, v in zip(path, path[1:]):
            if v not in topo.neighbors(u):
                raise RoutingError(
                    f"route {path} uses non-adjacent hop {u}->{v} "
                    f"on {topo!r}")
        if len(set(path)) != len(path):
            raise RoutingError(f"route {path} revisits a node")
