"""Paged KV-cache manager for serving: allocation, spill, fault handling.

Each sequence is one :class:`~repro.vmem.pager.AddressSpace` tenant over
a shared control-plane :class:`~repro.vmem.frames.FrameIdPool` — the
multi-tenant scenario of the ``repro.vmem`` pager.  The device pools
handed to the compiled decode step are fixed-size frame pools; this
manager owns the *page tables* mapping (sequence, page-slot) → frame.
When the pool is exhausted, cold pages of preempted/idle sequences spill
(cross-tenant eviction); re-activating a sequence faults its pages back
in at the granularity of the tenant's
:class:`~repro.api.policy.FaultPolicy` (Touch-Ahead blocks by default).

The compiled step never sees a fault: like the thesis' driver, residency
is resolved on the control plane before dispatch, and the step's page
table only ever names resident frames (unmapped tail slots are -1 and
masked inside the kernel).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.api.policy import FaultPolicy
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy
from repro.vmem import (FrameIdPool, FramePool, NON_RESIDENT, Pager,
                        PagingStats, coerce_policy)

FREE = -1

# unified telemetry: the old name stays importable
KVStats = PagingStats


class PagedKVManager:
    """Frame allocator + per-sequence page tables (one per layer-group)."""

    def __init__(self, n_frames: int, page_tokens: int, max_pages_per_seq: int,
                 strategy: Optional[Strategy] = None,
                 lookahead: Optional[int] = None,
                 cost: CostModel = DEFAULT_COST_MODEL,
                 policy: Optional[FaultPolicy] = None,
                 pool: Optional[FramePool] = None,
                 pager: Optional[Pager] = None):
        self.n_frames = n_frames
        self.page_tokens = page_tokens
        self.max_pages = max_pages_per_seq
        # only pin a per-sequence policy when the caller asked for one;
        # otherwise an injected pager's own policy must govern
        explicit = (policy is not None or strategy is not None
                    or lookahead is not None)
        coerced = coerce_policy("PagedKVManager", policy, strategy,
                                lookahead)
        self.cost = cost
        if pager is None:
            pager = Pager(pool or FrameIdPool(n_frames), policy=coerced,
                          cost=cost)
        self.pager = pager
        self._space_policy = coerced if explicit else None
        self.policy = coerced if explicit else pager.policy
        self.strategy = self.policy.strategy
        self.lookahead = self.policy.lookahead
        self.stats = self.pager.stats
        # seq_id -> its address space (one tenant per sequence)
        self.seq_spaces: dict[int, "object"] = {}
        self.lengths: dict[int, int] = {}

    # ---------------------------------------------------- compat views
    @property
    def tables(self) -> dict[int, np.ndarray]:
        """seq_id -> np.array(max_pages) of frame ids / FREE."""
        return {s: sp.page_table for s, sp in self.seq_spaces.items()}

    @property
    def spilled(self) -> dict[int, set[int]]:
        """seq_id -> slots evicted to host, awaiting fault-back-in."""
        return {s: set(map(int, np.where(sp.swapped)[0]))
                for s, sp in self.seq_spaces.items()}

    def _victims(self, for_seq: int,
                 spill_candidates: Optional[list[int]]) -> list:
        """Candidate spaces to spill from (never the requesting seq)."""
        if spill_candidates:
            return [self.seq_spaces[s] for s in spill_candidates
                    if s in self.seq_spaces]
        return [sp for s, sp in self.seq_spaces.items() if s != for_seq]

    # ------------------------------------------------------------ sequences
    def add_sequence(self, seq_id: int) -> None:
        self.seq_spaces[seq_id] = self.pager.create_space(
            self.max_pages, name=f"seq{seq_id}", policy=self._space_policy)
        self.lengths[seq_id] = 0

    def free_sequence(self, seq_id: int) -> None:
        space = self.seq_spaces.pop(seq_id, None)
        if space is not None:
            self.pager.destroy_space(space)
        self.lengths.pop(seq_id, None)

    # ------------------------------------------------------------- growing
    def append_tokens(self, seq_id: int, n: int,
                      spill_candidates: Optional[list[int]] = None) -> None:
        """Extend a sequence by n tokens, allocating pages on demand."""
        new_len = self.lengths[seq_id] + n
        needed = -(-new_len // self.page_tokens)
        space = self.seq_spaces[seq_id]
        victims = self._victims(seq_id, spill_candidates)
        for slot in range(needed):
            if space.page_table[slot] == NON_RESIDENT \
                    and not space.swapped[slot]:
                self.pager.map_fresh(space, slot, victims=victims)
        self.lengths[seq_id] = new_len

    # --------------------------------------------------------------- faults
    def ensure_resident(self, seq_id: int,
                        spill_candidates: Optional[list[int]] = None) -> int:
        """Resolve all spilled pages of a sequence before dispatch.

        Returns the number of pages faulted back in.  Touch-Ahead pages in
        ``lookahead``-page blocks (one fault event per block — the 16 KB
        block of the thesis); Touch-A-Page pays one event per page.
        """
        space = self.seq_spaces[seq_id]
        spilled = np.where(space.swapped)[0]
        if not len(spilled):
            return 0
        return self.pager.resolve_batch(
            space, spilled, victims=self._victims(seq_id, spill_candidates))

    # ---------------------------------------------------------------- views
    def device_table(self, seq_ids: list[int]) -> np.ndarray:
        """(B, max_pages) int32 page table for the compiled step."""
        out = np.full((len(seq_ids), self.max_pages), FREE, np.int32)
        for i, s in enumerate(seq_ids):
            out[i] = self.seq_spaces[s].page_table
        return out

    def batch_lengths(self, seq_ids: list[int]) -> np.ndarray:
        return np.asarray([self.lengths[s] for s in seq_ids], np.int32)

    @property
    def frames_used(self) -> int:
        return self.pager.pool.frames_used

    @property
    def free(self) -> list[int]:
        return self.pager.pool.free
