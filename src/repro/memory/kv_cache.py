"""Paged KV-cache manager for serving: allocation, spill, fault handling.

The device pools handed to the compiled decode step are fixed-size frame
pools; this manager owns the *page tables* mapping (sequence, page-slot) →
frame.  When the pool is exhausted, cold pages of preempted/idle sequences
spill to the host pool; re-activating a sequence faults its pages back in
with the thesis' Touch-Ahead (block) granularity.

The compiled step never sees a fault: like the thesis' driver, residency
is resolved on the control plane before dispatch, and the step's page
table only ever names resident frames (unmapped tail slots are -1 and
masked inside the kernel).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy
from repro.api.policy import FaultPolicy

FREE = -1


@dataclasses.dataclass
class KVStats:
    allocs: int = 0
    spills: int = 0
    fault_page_ins: int = 0
    fault_events: int = 0
    simulated_us: float = 0.0


class PagedKVManager:
    """Frame allocator + per-sequence page tables (one per layer-group)."""

    def __init__(self, n_frames: int, page_tokens: int, max_pages_per_seq: int,
                 strategy: Strategy = Strategy.TOUCH_AHEAD, lookahead: int = 4,
                 cost: CostModel = DEFAULT_COST_MODEL,
                 policy: Optional[FaultPolicy] = None):
        self.n_frames = n_frames
        self.page_tokens = page_tokens
        self.max_pages = max_pages_per_seq
        # a FaultPolicy (the verbs-API per-tenant knob) wins over the legacy
        # strategy/lookahead pair
        self.policy = policy or FaultPolicy(strategy=strategy,
                                            lookahead=lookahead)
        self.strategy = self.policy.strategy
        self.lookahead = self.policy.lookahead
        self.cost = cost
        self.stats = KVStats()
        self.free = list(range(n_frames - 1, -1, -1))
        # seq_id -> np.array(max_pages) of frame ids / FREE
        self.tables: dict[int, np.ndarray] = {}
        self.lengths: dict[int, int] = {}
        # host-spilled pages: (seq, slot) -> True (payload handled by the
        # engine's PagedTensorStore; here we track residency control state)
        self.spilled: dict[int, set[int]] = {}

    # ------------------------------------------------------------ sequences
    def add_sequence(self, seq_id: int) -> None:
        self.tables[seq_id] = np.full((self.max_pages,), FREE, np.int64)
        self.lengths[seq_id] = 0
        self.spilled[seq_id] = set()
        self.stats.allocs += 1

    def free_sequence(self, seq_id: int) -> None:
        for f in self.tables.pop(seq_id):
            if f >= 0:
                self.free.append(int(f))
        self.lengths.pop(seq_id, None)
        self.spilled.pop(seq_id, None)

    # ------------------------------------------------------------- growing
    def append_tokens(self, seq_id: int, n: int,
                      spill_candidates: Optional[list[int]] = None) -> None:
        """Extend a sequence by n tokens, allocating pages on demand."""
        new_len = self.lengths[seq_id] + n
        needed = -(-new_len // self.page_tokens)
        table = self.tables[seq_id]
        for slot in range(needed):
            if table[slot] == FREE and slot not in self.spilled[seq_id]:
                table[slot] = self._alloc_frame(seq_id, spill_candidates)
        self.lengths[seq_id] = new_len

    def _alloc_frame(self, for_seq: int,
                     spill_candidates: Optional[list[int]]) -> int:
        if self.free:
            return self.free.pop()
        # pool exhausted: spill the coldest page of an inactive sequence
        victims = spill_candidates if spill_candidates else \
            [s for s in self.tables if s != for_seq]
        for v in victims:
            tbl = self.tables.get(v)
            if tbl is None:
                continue
            resident = np.where(tbl >= 0)[0]
            if len(resident):
                slot = int(resident[-1])
                frame = int(tbl[slot])
                tbl[slot] = FREE
                self.spilled[v].add(slot)
                self.stats.spills += 1
                self.stats.simulated_us += self.cost.touch_page_us
                return frame
        raise MemoryError("KV pool exhausted with no spill candidates "
                          "(all sequences active == all pages pinned)")

    # --------------------------------------------------------------- faults
    def ensure_resident(self, seq_id: int,
                        spill_candidates: Optional[list[int]] = None) -> int:
        """Resolve all spilled pages of a sequence before dispatch.

        Returns the number of pages faulted back in.  Touch-Ahead pages in
        ``lookahead``-page blocks (one fault event per block — the 16 KB
        block of the thesis); Touch-A-Page pays one event per page.
        """
        spilled = sorted(self.spilled[seq_id])
        if not spilled:
            return 0
        table = self.tables[seq_id]
        c = self.cost
        n_in = 0
        if self.strategy is Strategy.TOUCH_A_PAGE:
            for slot in spilled:
                table[slot] = self._alloc_frame(seq_id, spill_candidates)
                self.spilled[seq_id].discard(slot)
                self.stats.fault_events += 1
                self.stats.simulated_us += (c.netlink_send_us + c.wakeup_us
                                            + c.touch_page_us)
                n_in += 1
        else:
            i = 0
            while i < len(spilled):
                block = spilled[i:i + self.lookahead]
                for slot in block:
                    table[slot] = self._alloc_frame(seq_id, spill_candidates)
                    self.spilled[seq_id].discard(slot)
                self.stats.fault_events += 1
                self.stats.simulated_us += c.gup_us(len(block))
                n_in += len(block)
                i += self.lookahead
        self.stats.fault_page_ins += n_in
        return n_in

    # ---------------------------------------------------------------- views
    def device_table(self, seq_ids: list[int]) -> np.ndarray:
        """(B, max_pages) int32 page table for the compiled step."""
        out = np.full((len(seq_ids), self.max_pages), FREE, np.int32)
        for i, s in enumerate(seq_ids):
            out[i] = self.tables[s]
        return out

    def batch_lengths(self, seq_ids: list[int]) -> np.ndarray:
        return np.asarray([self.lengths[s] for s in seq_ids], np.int32)

    @property
    def frames_used(self) -> int:
        return self.n_frames - len(self.free)
