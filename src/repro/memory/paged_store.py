"""Demand-paged tensor storage: one tenant of the ``repro.vmem`` pager.

A :class:`PagedTensorStore` is a thin compatibility wrapper over one
:class:`~repro.vmem.pager.AddressSpace` on a
:class:`~repro.vmem.frames.DeviceFramePool` (jnp frames, numpy backing).
Accessing a non-resident page is a **page fault**, resolved by the
tenant's :class:`~repro.api.policy.FaultPolicy` — Touch-A-Page,
Touch-Ahead (the ``get_user_pages`` block, default lookahead 4), or the
beyond-paper STREAM predictor — with eviction, prefetch, pinning and
telemetry all provided by the shared subsystem.

Timing is accounted with the calibrated :class:`CostModel` (simulated
microseconds, reported in benchmarks) while the data movement itself is
real (host numpy ↔ device jnp copies).  Pass ``pool=`` to share frames
with other tenants, or a :class:`~repro.vmem.remote.RemoteFramePool` to
page in over the verbs fabric.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.api.policy import FaultPolicy
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy
from repro.vmem import (DeviceFramePool, FramePool, NON_RESIDENT, Pager,
                        PagingStats, coerce_policy)

# unified telemetry: the old name stays importable
StoreStats = PagingStats


class PagedTensorStore:
    """One tenant's paged storage over a (shareable) device frame pool."""

    def __init__(self, page_elems: int, n_device_frames: int,
                 n_host_pages: int, dtype=jnp.float32,
                 strategy: Optional[Strategy] = None,
                 lookahead: Optional[int] = None,
                 cost: CostModel = DEFAULT_COST_MODEL,
                 policy: Optional[FaultPolicy] = None,
                 pool: Optional[FramePool] = None,
                 pager: Optional[Pager] = None):
        self.page_elems = page_elems
        self.dtype = dtype
        # only pin a per-space policy when the caller actually asked for
        # one; otherwise an injected pager's own policy must govern
        explicit = (policy is not None or strategy is not None
                    or lookahead is not None)
        policy = coerce_policy("PagedTensorStore", policy, strategy,
                               lookahead)
        self.cost = cost
        if pager is None:
            pool = pool or DeviceFramePool(n_device_frames, page_elems,
                                           dtype)
            pager = Pager(pool, policy=policy, cost=cost)
        self.pager = pager
        self.pool = pager.pool
        self.space = self.pager.create_space(
            n_host_pages, name="store",
            policy=policy if explicit else None)
        self.policy = self.pager.policy_of(self.space)
        self.strategy = self.policy.strategy
        self.lookahead = max(1, self.policy.lookahead)
        self.stats = self.space.stats

    # ---------------------------------------------------- compat views
    @property
    def page_table(self) -> np.ndarray:
        return self.space.page_table

    @property
    def pinned(self) -> np.ndarray:
        return self.space.pinned

    @property
    def prefetched(self) -> np.ndarray:
        return self.space.prefetched

    @property
    def host(self) -> np.ndarray:
        return self.space.backing

    @property
    def frames(self) -> jnp.ndarray:
        return self.pool.data

    @frames.setter
    def frames(self, value) -> None:
        self.pool.data = value

    @property
    def free_frames(self) -> list[int]:
        return self.pool.free

    # ------------------------------------------------------------- writes
    def write_host(self, vpage: int, data: np.ndarray) -> None:
        """Populate a page's backing store (host)."""
        self.space.write(vpage, data)

    def write_back(self, vpage: int) -> None:
        """Device -> host writeback for a resident page."""
        self.space.write_back(vpage)

    # ----------------------------------------------------------- residency
    def is_resident(self, vpage: int) -> bool:
        return self.space.is_resident(vpage)

    def resident_pages(self) -> int:
        return self.space.resident_pages()

    def pin(self, vpages) -> None:
        self.space.pin(vpages)

    def unpin(self, vpages) -> None:
        self.space.unpin(vpages)

    # --------------------------------------------------------------- reads
    def access(self, vpages) -> jnp.ndarray:
        """Read pages (faulting in non-resident ones). Returns (n, elems)."""
        return self.space.access(vpages)

    def frame_ids(self, vpages) -> np.ndarray:
        """Resident frame ids for compiled-kernel page tables (must be
        resolved first — the engine calls access() or ensure_resident())."""
        return self.space.frame_ids(vpages)

    def ensure_resident(self, vpages) -> None:
        self.space.ensure_resident(vpages)
