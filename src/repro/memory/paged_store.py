"""Demand-paged tensor storage: the thesis' mechanism as a JAX data plane.

A :class:`PagedTensorStore` owns a **device frame pool** (jnp array) and a
**host pool** (numpy).  Tensors are stored as fixed-size pages; a page is
either *resident* (has a device frame) or *non-resident* (host only).
Accessing a non-resident page is a **page fault**, resolved by the same
policies the thesis evaluates:

* ``TOUCH_A_PAGE``  — page in exactly the faulted page;
* ``TOUCH_AHEAD``   — page in the faulted page + the rest of its block
  (the ``get_user_pages`` optimization, default lookahead 4);
* ``STREAM``        — beyond-paper: sequential-stream prediction pages the
  next block in ahead of the fault.

Timing is accounted with the calibrated :class:`CostModel` (simulated
microseconds, reported in benchmarks) while the data movement itself is
real (host numpy ↔ device jnp copies), so correctness and the paper's
latency relationships are both testable.  Pinning (the baseline the thesis
argues against) is supported per page and enforced by eviction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy

NON_RESIDENT = -1


@dataclasses.dataclass
class StoreStats:
    faults: int = 0
    pages_in: int = 0
    pages_out: int = 0
    evictions: int = 0
    prefetch_hits: int = 0      # accesses that found a prefetched page
    pin_violations: int = 0
    simulated_us: float = 0.0   # calibrated cost-model time

    def reset(self):
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(StoreStats, f.name, 0)
                    if f.default is dataclasses.MISSING else f.default)


class PagedTensorStore:
    """One tenant's paged storage over a shared device frame pool."""

    def __init__(self, page_elems: int, n_device_frames: int,
                 n_host_pages: int, dtype=jnp.float32,
                 strategy: Strategy = Strategy.TOUCH_AHEAD,
                 lookahead: int = 4,
                 cost: CostModel = DEFAULT_COST_MODEL):
        self.page_elems = page_elems
        self.dtype = dtype
        self.strategy = strategy
        self.lookahead = max(1, lookahead)
        self.cost = cost
        self.stats = StoreStats()
        # device pool
        self.frames = jnp.zeros((n_device_frames, page_elems), dtype)
        self.free_frames = list(range(n_device_frames - 1, -1, -1))
        self.frame_owner: dict[int, int] = {}      # frame -> vpage
        # host pool (the "swap"/backing store)
        self.host = np.zeros((n_host_pages, page_elems),
                             jax.dtypes.canonicalize_dtype(dtype))
        # virtual page table: vpage -> frame (or NON_RESIDENT)
        self.page_table = np.full((n_host_pages,), NON_RESIDENT, np.int64)
        self.pinned = np.zeros((n_host_pages,), bool)
        self.prefetched = np.zeros((n_host_pages,), bool)
        self._clock = 0
        self._last_used = np.zeros((n_host_pages,), np.int64)

    # ------------------------------------------------------------- writes
    def write_host(self, vpage: int, data: np.ndarray) -> None:
        """Populate a page's backing store (host)."""
        self.host[vpage] = np.asarray(data,
                                      self.host.dtype).reshape(self.page_elems)
        if self.page_table[vpage] != NON_RESIDENT:
            # keep device copy coherent
            f = int(self.page_table[vpage])
            self.frames = self.frames.at[f].set(
                jnp.asarray(self.host[vpage], self.dtype))

    def write_back(self, vpage: int) -> None:
        """Device -> host writeback for a resident page."""
        f = self.page_table[vpage]
        if f != NON_RESIDENT:
            self.host[vpage] = np.asarray(self.frames[int(f)])

    # ----------------------------------------------------------- residency
    def is_resident(self, vpage: int) -> bool:
        return self.page_table[vpage] != NON_RESIDENT

    def resident_pages(self) -> int:
        return int((self.page_table != NON_RESIDENT).sum())

    def pin(self, vpages) -> None:
        for v in np.atleast_1d(vpages):
            self._page_in(int(v))
            self.pinned[v] = True
        self.stats.simulated_us += self.cost.pin_us(
            len(np.atleast_1d(vpages)) * 4096)

    def unpin(self, vpages) -> None:
        for v in np.atleast_1d(vpages):
            self.pinned[v] = False
        self.stats.simulated_us += self.cost.unpin_us(
            len(np.atleast_1d(vpages)) * 4096)

    # --------------------------------------------------------------- fault
    def _evict_one(self) -> int:
        """LRU-evict an unpinned resident page; returns the freed frame."""
        resident = np.where((self.page_table != NON_RESIDENT)
                            & ~self.pinned)[0]
        if len(resident) == 0:
            self.stats.pin_violations += 1
            raise MemoryError("device pool exhausted and all pages pinned "
                              "(the thesis' pinning-limit failure mode)")
        victim = int(resident[np.argmin(self._last_used[resident])])
        f = int(self.page_table[victim])
        self.write_back(victim)
        self.page_table[victim] = NON_RESIDENT
        self.frame_owner.pop(f, None)
        self.stats.evictions += 1
        self.stats.pages_out += 1
        return f

    def _page_in(self, vpage: int) -> int:
        if self.page_table[vpage] != NON_RESIDENT:
            return int(self.page_table[vpage])
        if not self.free_frames:
            self.free_frames.append(self._evict_one())
        f = self.free_frames.pop()
        self.frames = self.frames.at[f].set(
            jnp.asarray(self.host[vpage], self.dtype))
        self.page_table[vpage] = f
        self.frame_owner[f] = vpage
        self.stats.pages_in += 1
        return f

    def _resolve_fault(self, vpage: int) -> None:
        """Apply the configured resolution strategy at a fault."""
        self.stats.faults += 1
        c = self.cost
        if self.strategy is Strategy.TOUCH_A_PAGE:
            self._page_in(vpage)
            self.stats.simulated_us += (c.netlink_send_us + c.wakeup_us
                                        + c.touch_page_us)
        else:
            # touch-ahead: the faulted page + the rest of its block
            n = 0
            block_end = min(len(self.page_table),
                            vpage + self.lookahead)
            for v in range(vpage, block_end):
                if self.page_table[v] == NON_RESIDENT:
                    self._page_in(v)
                    if v != vpage:
                        self.prefetched[v] = True
                    n += 1
            self.stats.simulated_us += c.gup_us(max(1, n))
            if self.strategy is Strategy.STREAM:
                nxt = block_end
                if nxt < len(self.page_table) \
                        and self.page_table[nxt] == NON_RESIDENT:
                    self._page_in(nxt)
                    self.prefetched[nxt] = True
                    self.stats.simulated_us += c.gup_per_page_us

    # --------------------------------------------------------------- reads
    def access(self, vpages) -> jnp.ndarray:
        """Read pages (faulting in non-resident ones). Returns (n, elems)."""
        vpages = np.atleast_1d(np.asarray(vpages, np.int64))
        self._clock += 1
        for v in vpages:
            v = int(v)
            if self.page_table[v] == NON_RESIDENT:
                self._resolve_fault(v)
            elif self.prefetched[v]:
                self.stats.prefetch_hits += 1
                self.prefetched[v] = False
            self._last_used[v] = self._clock
        frames = jnp.asarray(self.page_table[vpages], jnp.int32)
        return jnp.take(self.frames, frames, axis=0)

    def frame_ids(self, vpages) -> np.ndarray:
        """Resident frame ids for compiled-kernel page tables (must be
        resolved first — the engine calls access() or ensure_resident())."""
        return self.page_table[np.atleast_1d(vpages)]

    def ensure_resident(self, vpages) -> None:
        for v in np.atleast_1d(vpages):
            if self.page_table[int(v)] == NON_RESIDENT:
                self._resolve_fault(int(v))
            self._last_used[int(v)] = self._clock
