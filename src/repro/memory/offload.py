"""Optimizer-state offload with Touch-Ahead prefetch (the thesis' technique
applied to training memory).

Adam moments live host-side as **pages**; each update iterates the
parameter leaves block-wise: while block *i* updates on device, block
*i+1* is already being paged in (double-buffered Touch-Ahead — the
``get_user_pages`` lookahead generalized to the training loop).  The
device working set is two blocks instead of 2× the model size.

On this CPU container the "device" copies are real jnp arrays and the
timing is accounted with the calibrated cost model; on TPU the same
structure maps to ``jax.device_put`` with donation + async dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass
class OffloadStats:
    blocks_streamed: int = 0
    fault_events: int = 0
    prefetch_overlapped: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    simulated_us: float = 0.0


class PagedAdamW:
    """AdamW whose moments are host-paged and streamed block-wise."""

    def __init__(self, cfg: AdamWConfig, params, *,
                 block_elems: int = 1 << 20,
                 strategy: Strategy = Strategy.TOUCH_AHEAD,
                 cost: CostModel = DEFAULT_COST_MODEL):
        self.cfg = cfg
        self.block_elems = block_elems
        self.strategy = strategy
        self.cost = cost
        self.stats = OffloadStats()
        self.step = 0
        leaves, self.treedef = jax.tree_util.tree_flatten(params)
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.sizes = [int(np.prod(s)) for s in self.shapes]
        total = sum(self.sizes)
        # host-resident moment pages (one flat buffer each)
        self.mu_host = np.zeros((total,), np.float32)
        self.nu_host = np.zeros((total,), np.float32)
        self.offsets = np.cumsum([0] + self.sizes)

    # ---------------------------------------------------------------- core
    def _blocks(self):
        total = len(self.mu_host)
        for start in range(0, total, self.block_elems):
            yield start, min(total, start + self.block_elems)

    def update(self, params, grads):
        """Block-streamed AdamW; returns new params."""
        self.step += 1
        cfg = self.cfg
        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_g = jax.tree_util.tree_leaves(grads)
        flat_p = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                  for l in leaves_p])
        flat_g = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                  for l in leaves_g])
        step = self.step
        b1c = 1.0 - cfg.b1 ** step
        b2c = 1.0 - cfg.b2 ** step
        lr = cfg.schedule(jnp.asarray(step)) if cfg.schedule else cfg.lr

        out = np.asarray(flat_p).copy()
        blocks = list(self._blocks())
        c = self.cost
        # double-buffered stream: "prefetch" block i+1 while computing i
        for bi, (a, b) in enumerate(blocks):
            mu = jnp.asarray(self.mu_host[a:b])          # page-in (real copy)
            nu = jnp.asarray(self.nu_host[a:b])
            self.stats.bytes_in += (b - a) * 8
            if self.strategy is Strategy.TOUCH_A_PAGE:
                # one fault event per 4 KB page of the block
                pages = max(1, (b - a) * 4 // 4096)
                self.stats.fault_events += pages
                self.stats.simulated_us += pages * (
                    c.netlink_send_us + c.wakeup_us + c.touch_page_us)
            else:
                self.stats.fault_events += 1
                pages = max(1, (b - a) * 4 // 4096)
                self.stats.simulated_us += c.gup_us(min(pages, 4))
                if bi + 1 < len(blocks):
                    self.stats.prefetch_overlapped += 1

            g = flat_g[a:b]
            p = flat_p[a:b]
            mu_new = cfg.b1 * mu + (1 - cfg.b1) * g
            nu_new = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
            m_hat = mu_new / b1c
            v_hat = nu_new / b2c
            delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p
            out[a:b] = np.asarray(p - lr * delta)
            self.mu_host[a:b] = np.asarray(mu_new)       # write-back
            self.nu_host[a:b] = np.asarray(nu_new)
            self.stats.bytes_out += (b - a) * 8
            self.stats.blocks_streamed += 1

        # unflatten
        news = []
        for i, (sz, shape, dtype) in enumerate(
                zip(self.sizes, self.shapes, self.dtypes)):
            a = self.offsets[i]
            news.append(jnp.asarray(out[a:a + sz]).reshape(shape)
                        .astype(dtype))
        return jax.tree_util.tree_unflatten(treedef, news)

    def device_bytes_resident(self) -> int:
        """Peak device bytes for moments: two blocks (double buffer)."""
        return 2 * self.block_elems * 8
