"""Optimizer-state offload with Touch-Ahead prefetch (the thesis' technique
applied to training memory).

Adam moments live host-side as **pages** of one block each; the ``mu``
and ``nu`` buffers are two :class:`~repro.vmem.pager.AddressSpace`
tenants over one shared :class:`~repro.vmem.frames.DeviceFramePool` of
four block-frames (two per buffer — the double buffer).  Each update
iterates the parameter leaves block-wise: while block *i* updates on
device, block *i+1* is already paged in by the pager's block prefetch
(the ``get_user_pages`` lookahead generalized to the training loop), so
the device working set is two blocks instead of 2× the model size.

On this CPU container the "device" copies are real jnp arrays and the
timing is accounted with the calibrated cost model; on TPU the same
structure maps to ``jax.device_put`` with donation + async dispatch.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policy import FaultPolicy
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.resolver import Strategy
from repro.optim.adamw import AdamWConfig
from repro.vmem import (DeviceFramePool, Pager, PagingStats, coerce_policy)

# unified telemetry: the old name stays importable
OffloadStats = PagingStats

_DEFAULT = FaultPolicy(strategy=Strategy.TOUCH_AHEAD)


class PagedAdamW:
    """AdamW whose moments are host-paged and streamed block-wise."""

    def __init__(self, cfg: AdamWConfig, params, *,
                 block_elems: int = 1 << 20,
                 strategy: Optional[Strategy] = None,
                 cost: CostModel = DEFAULT_COST_MODEL,
                 policy: Optional[FaultPolicy] = None):
        self.cfg = cfg
        self.block_elems = block_elems
        self.policy = coerce_policy("PagedAdamW", policy, strategy,
                                    default=_DEFAULT)
        self.strategy = self.policy.strategy
        self.cost = cost
        self.step = 0
        leaves, self.treedef = jax.tree_util.tree_flatten(params)
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.sizes = [int(np.prod(s)) for s in self.shapes]
        total = sum(self.sizes)
        self.total = total
        self.offsets = np.cumsum([0] + self.sizes)
        n_blocks = max(1, -(-total // block_elems))
        # the vmem pager: one page per block, double-buffered per moment
        # buffer (fault brings the block + the next one, pool holds 4)
        stream = (self.policy.strategy is not Strategy.TOUCH_A_PAGE)
        inner = FaultPolicy(
            strategy=Strategy.TOUCH_AHEAD_N if stream
            else Strategy.TOUCH_A_PAGE,
            lookahead=2 if stream else 1)
        self.pager = Pager(DeviceFramePool(4, block_elems, jnp.float32),
                           policy=inner, cost=cost,
                           page_bytes=max(1, block_elems * 4))
        self.mu_space = self.pager.create_space(n_blocks, name="mu")
        self.nu_space = self.pager.create_space(n_blocks, name="nu")
        self.stats = self.pager.stats
        # host-resident moment pages, exposed flat (views of the backing)
        self.mu_host = self.mu_space.backing.reshape(-1)[:total]
        self.nu_host = self.nu_space.backing.reshape(-1)[:total]

    # ---------------------------------------------------------------- core
    def _blocks(self):
        for start in range(0, self.total, self.block_elems):
            yield start, min(self.total, start + self.block_elems)

    def _page(self, space, bi: int, width: int) -> jnp.ndarray:
        hits = self.pager.stats.prefetch_hits
        page = self.pager.access(space, [bi])[0][:width]
        if self.pager.stats.prefetch_hits > hits:
            # the block was already in flight while its predecessor
            # computed: the double-buffered overlap
            self.stats.prefetch_overlapped += 1
        return page

    def update(self, params, grads):
        """Block-streamed AdamW; returns new params."""
        self.step += 1
        cfg = self.cfg
        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_g = jax.tree_util.tree_leaves(grads)
        flat_p = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                  for l in leaves_p])
        flat_g = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                                  for l in leaves_g])
        step = self.step
        b1c = 1.0 - cfg.b1 ** step
        b2c = 1.0 - cfg.b2 ** step
        lr = cfg.schedule(jnp.asarray(step)) if cfg.schedule else cfg.lr

        out = np.asarray(flat_p).copy()
        for bi, (a, b) in enumerate(self._blocks()):
            mu = self._page(self.mu_space, bi, b - a)   # page-in (real copy)
            nu = self._page(self.nu_space, bi, b - a)
            self.stats.bytes_in += (b - a) * 8

            g = flat_g[a:b]
            p = flat_p[a:b]
            mu_new = cfg.b1 * mu + (1 - cfg.b1) * g
            nu_new = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
            m_hat = mu_new / b1c
            v_hat = nu_new / b2c
            delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p
            out[a:b] = np.asarray(p - lr * delta)
            self.mu_space.write(bi, np.asarray(mu_new),  # write-through
                                allow_partial=True)
            self.nu_space.write(bi, np.asarray(nu_new), allow_partial=True)
            self.stats.bytes_out += (b - a) * 8
            self.stats.blocks_streamed += 1

        # unflatten
        news = []
        for i, (sz, shape, dtype) in enumerate(
                zip(self.sizes, self.shapes, self.dtypes)):
            a = self.offsets[i]
            news.append(jnp.asarray(out[a:a + sz]).reshape(shape)
                        .astype(dtype))
        return jax.tree_util.tree_unflatten(treedef, news)

    def device_bytes_resident(self) -> int:
        """Peak device bytes for moments: two blocks per buffer (the
        shared 4-frame f32 pool = 2 × block_elems × 8)."""
        return 2 * self.block_elems * 8
