"""Version-compat shims for the installed jax.

The repo targets current jax but must run on older releases (e.g. the
CI/container pin): ``jax.sharding.AxisType`` and top-level
``jax.shard_map`` only exist in newer versions.  Every use site goes
through these helpers instead of feature-detecting inline.
"""

from __future__ import annotations

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(AxisType.Auto, ...)`` where the installed jax has it.

    Older jax defaults every mesh axis to auto sharding anyway, so
    omitting the kwarg there is behaviour-identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict on every jax version
    (older releases return a one-element list of per-device dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def import_shard_map():
    """The ``shard_map`` transform, kwarg-normalized across jax versions.

    ``jax.shard_map`` (new) vs ``jax.experimental.shard_map.shard_map``
    (old), and ``check_vma=`` (new) vs ``check_rep=`` (old): call sites
    use the new spelling; this shim translates for older releases.
    """
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    import inspect
    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):      # pragma: no cover
        return sm
    if "check_vma" in params:
        return sm

    def compat_shard_map(f=None, **kwargs):
        vma = kwargs.pop("check_vma", None)
        if vma is not None and "check_rep" in params:
            kwargs.setdefault("check_rep", vma)
        return sm(f, **kwargs) if f is not None else sm(**kwargs)

    return compat_shard_map
