"""Shared receive queue (SRQ) + queue-pair multiplexing bookkeeping.

Real multi-tenant RDMA NICs do not give every tenant a private receive
queue: RDMAbox-style designs pool receive entries into a bounded shared
receive queue (SRQ) and multiplex many *virtual* queue pairs onto a few
*physical* ones.  The reproduction models both as admission-control
bookkeeping in front of the existing ``DMAArbiter`` quotas:

* ``SRQ`` — a bounded pool of per-node receive entries.  Every posted
  block consumes one entry on the destination node for the life of the
  transfer; when the pool is dry the posting verb raises
  ``TenantQuotaExceeded`` (typed backpressure, not silent queueing).  A
  ``gold_reserve`` slice is usable only by GOLD tenants so best-effort
  floods cannot starve the latency tier's receive path.
* ``QPMux`` — maps virtual per-domain queue pairs onto a bounded set of
  physical QP contexts (hash by pd).  Pure telemetry today: it proves
  the 10k-tenant soak runs with 16 physical QPs per node, and gives the
  invariants a place to check that multiplexing never loses a tenant.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.errors import ConfigError

__all__ = ["SRQ", "SRQStats", "QPMux"]


@dataclass
class SRQStats:
    admitted: int = 0        #: receive entries granted
    rejected: int = 0        #: acquire attempts bounced (backpressure)
    released: int = 0        #: entries returned on completion
    peak_held: int = 0       #: high-water mark of concurrently-held entries

    def as_dict(self) -> Dict[str, int]:
        return {"admitted": self.admitted, "rejected": self.rejected,
                "released": self.released, "peak_held": self.peak_held}


class SRQ:
    """Bounded shared receive-entry pool; ``entries=None`` = unbounded."""

    def __init__(self, entries: Optional[int] = None,
                 gold_reserve: int = 0) -> None:
        if entries is not None and gold_reserve > entries:
            raise ConfigError("gold_reserve exceeds SRQ entries")
        self.entries = entries
        self.gold_reserve = gold_reserve
        self.held = 0
        self.stats = SRQStats()

    def limit_for(self, gold: bool) -> Optional[int]:
        if self.entries is None:
            return None
        return self.entries if gold else self.entries - self.gold_reserve

    def try_acquire(self, n: int, gold: bool = False) -> bool:
        limit = self.limit_for(gold)
        if limit is not None and self.held + n > limit:
            self.stats.rejected += 1
            return False
        self.held += n
        self.stats.admitted += n
        self.stats.peak_held = max(self.stats.peak_held, self.held)
        return True

    def release(self, n: int) -> None:
        assert self.held >= n, "SRQ release underflow"
        self.held -= n
        self.stats.released += n


class QPMux:
    """Virtual-QP -> physical-QP multiplexer (deterministic hash by pd)."""

    def __init__(self, phys_qps: int = 16) -> None:
        self.phys_qps = int(phys_qps)
        self._virtual: Dict[int, int] = {}          # pd -> physical qp
        self._share: Dict[int, int] = {}            # physical qp -> count

    def attach(self, pd: int) -> int:
        if pd in self._virtual:
            return self._virtual[pd]
        qp = pd % self.phys_qps
        self._virtual[pd] = qp
        self._share[qp] = self._share.get(qp, 0) + 1
        return qp

    def detach(self, pd: int) -> None:
        qp = self._virtual.pop(pd, None)
        if qp is not None:
            self._share[qp] -= 1
            if not self._share[qp]:
                del self._share[qp]

    def qp_of(self, pd: int) -> Optional[int]:
        return self._virtual.get(pd)

    @property
    def virtual_qps(self) -> int:
        return len(self._virtual)

    @property
    def max_share(self) -> int:
        return max(self._share.values(), default=0)

    def as_dict(self) -> Dict[str, int]:
        return {"phys_qps": self.phys_qps, "virtual_qps": self.virtual_qps,
                "max_share": self.max_share}
