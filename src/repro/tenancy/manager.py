"""Per-node tenancy control plane: banks + SRQ + QP mux + admission.

One ``TenancyManager`` per ``Node`` owns every virtualized resource the
tenancy layer multiplexes — the SMMU context-bank binding table
(``BankManager``), the shared receive queue (``SRQ``), the queue-pair
multiplexer (``QPMux``) and the per-node tenant admission counters.  The
manager never touches the event loop, the SMMU model or the cost model:
it *decides* (who is bound where, who is admitted, who is evicted) and
returns the decision; the node/fabric layers *execute* and charge time.
That split keeps the control plane deterministic and unit-testable
without a fabric.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.core import addresses as A
from repro.errors import AdmissionError
from repro.tenancy.banks import BankManager, BankStats, Binding
from repro.tenancy.qp import QPMux, SRQ
from repro.tenancy.slo import SLOClass

__all__ = ["TenancyManager"]


class TenancyManager:
    """All per-node multi-tenant resource bookkeeping in one place."""

    def __init__(self,
                 bank_capacity: int = A.NUM_CONTEXT_BANKS,
                 srq_entries: Optional[int] = None,
                 srq_gold_reserve: int = 0,
                 tenants_per_node: Optional[int] = None,
                 phys_qps: int = 16) -> None:
        self.banks = BankManager(capacity=bank_capacity)
        self.srq = SRQ(entries=srq_entries, gold_reserve=srq_gold_reserve)
        self.qp = QPMux(phys_qps=phys_qps)
        self.tenants_per_node = tenants_per_node
        self.tenants = 0
        self.gold_tenants = 0
        self.admission_rejections = 0
        self._slo: Dict[int, Optional[SLOClass]] = {}

    # ------------------------------------------------------------------
    # admission + lifecycle
    # ------------------------------------------------------------------
    def admission_error(self, slo: Optional[SLOClass]) -> Optional[str]:
        """Reason this node cannot take one more tenant, else ``None``.

        GOLD tenants are capped one *below* bank capacity: every GOLD
        bank is steal-immune, so at least one bank must stay stealable
        or a 17th domain could deadlock on an all-immune node.
        """
        if (self.tenants_per_node is not None
                and self.tenants >= self.tenants_per_node):
            return (f"node at tenant capacity "
                    f"({self.tenants}/{self.tenants_per_node})")
        if (slo is SLOClass.GOLD
                and self.gold_tenants >= self.banks.capacity - 1):
            return (f"node at GOLD capacity ({self.gold_tenants}/"
                    f"{self.banks.capacity - 1}: one bank must stay "
                    f"stealable)")
        return None

    def register(self, pd: int, slo: Optional[SLOClass] = None) -> None:
        reason = self.admission_error(slo)
        if reason is not None:
            self.admission_rejections += 1
            raise AdmissionError(reason)
        self.banks.register(pd, steal_immune=bool(slo and slo.steal_immune))
        self.qp.attach(pd)
        self._slo[pd] = slo
        self.tenants += 1
        if slo is SLOClass.GOLD:
            self.gold_tenants += 1

    def release(self, pd: int) -> Optional[int]:
        """Drop every per-tenant resource; returns the bank held, if any."""
        if pd not in self._slo:
            return None
        slo = self._slo.pop(pd)
        self.qp.detach(pd)
        self.tenants -= 1
        if slo is SLOClass.GOLD:
            self.gold_tenants -= 1
        return self.banks.release(pd)

    def slo_of(self, pd: int) -> Optional[SLOClass]:
        return self._slo.get(pd)

    def is_gold(self, pd: int) -> bool:
        return self._slo.get(pd) is SLOClass.GOLD

    # ------------------------------------------------------------------
    # bank binding passthroughs (node executes the SMMU side)
    # ------------------------------------------------------------------
    def bind_bank(self, pd: int, fault_active) -> Binding:
        return self.banks.bind(pd, fault_active=fault_active)

    # ------------------------------------------------------------------
    # telemetry
    # ------------------------------------------------------------------
    @property
    def bank_stats(self) -> BankStats:
        return self.banks.stats

    def as_dict(self) -> Dict[str, object]:
        """Deterministic snapshot for soak stats / protocol_stats."""
        return {
            "tenants": self.tenants,
            "gold_tenants": self.gold_tenants,
            "admission_rejections": self.admission_rejections,
            "banks_bound": self.banks.bound_count(),
            "banks": self.banks.stats.as_dict(),
            "srq": dict(self.srq.stats.as_dict(), held=self.srq.held),
            "qp": self.qp.as_dict(),
        }
