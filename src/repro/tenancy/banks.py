"""SMMU context-bank virtualization: overcommit 16 banks across N domains.

The hardware (and the seed reproduction) pins one protection domain to
``pd % 16`` forever — the 17th tenant on a node is simply rejected.  The
``BankManager`` breaks that ceiling the way an SMMU driver would: virtual
domains *bind* to a physical context bank on demand, and when every bank
is occupied a cold domain's bank is *stolen* (LRU), which costs a full
``tlb_invalidate_all`` shootdown plus a page-table rebind before the new
domain can translate.  The manager is pure bookkeeping — deciding who is
bound where and who gets evicted — while the ``Node`` executes the
detach/attach against the SMMU model and charges the ``CostModel``
shootdown/rebind time, so determinism and cost accounting stay in the
datapath where the rest of the simulator keeps them.

Binding policy (deterministic):

1. already bound -> hit (LRU touch);
2. prefer the legacy ``pd % capacity`` bank when it is free, so any
   workload that fits in 16 banks binds *exactly* like the seed did;
3. otherwise the lowest-indexed free bank;
4. otherwise steal the least-recently-used bank whose domain is not
   steal-immune (GOLD) and whose bank has no fault in flight;
5. otherwise (all candidates immune) steal the LRU immune bank anyway —
   forward progress beats immunity — counting ``immune_steals``;
6. if every bank has a fault in flight, raise ``NoBankAvailable``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core import addresses as A
from repro.errors import DomainExists

__all__ = ["BankManager", "BankStats", "Binding", "NoBankAvailable"]


class NoBankAvailable(RuntimeError):
    """Every context bank has a fault in flight; binding must wait."""


@dataclass
class BankStats:
    """Per-node context-bank virtualization counters (ADDITIVE)."""

    binds: int = 0          #: bindings established (fresh or after steal)
    hits: int = 0           #: lookups served by an existing binding
    steals: int = 0         #: binds that evicted another domain's bank
    shootdowns: int = 0     #: tlb_invalidate_all shootdowns executed
    immune_steals: int = 0  #: steals that had to evict a GOLD domain
    rebinds: int = 0        #: domains re-bound after losing their bank

    def as_dict(self) -> Dict[str, int]:
        return {"binds": self.binds, "hits": self.hits,
                "steals": self.steals, "shootdowns": self.shootdowns,
                "immune_steals": self.immune_steals,
                "rebinds": self.rebinds}


@dataclass(frozen=True)
class Binding:
    """Outcome of ``BankManager.bind``: where, and who was evicted."""

    bank: int
    stolen: bool = False
    victim_pd: Optional[int] = None
    hit: bool = False           #: binding already existed (no attach needed)


@dataclass
class _Domain:
    pd: int
    steal_immune: bool = False
    bank: Optional[int] = None
    last_use: int = 0
    ever_bound: bool = False


class BankManager:
    """Per-node binding table: virtual domains over physical banks."""

    def __init__(self, capacity: int = A.NUM_CONTEXT_BANKS) -> None:
        self.capacity = int(capacity)
        self.stats = BankStats()
        self._domains: Dict[int, _Domain] = {}        # pd -> domain
        self._bank_owner: Dict[int, int] = {}          # bank -> pd
        self._tick = 0

    # ------------------------------------------------------------------
    # registration / teardown
    # ------------------------------------------------------------------
    def register(self, pd: int, steal_immune: bool = False) -> None:
        if pd in self._domains:
            raise DomainExists(f"pd {pd} already registered")
        self._domains[pd] = _Domain(pd=pd, steal_immune=steal_immune)

    def release(self, pd: int) -> Optional[int]:
        """Forget ``pd`` entirely; returns the bank it held, if any."""
        dom = self._domains.pop(pd, None)
        if dom is None:
            return None
        if dom.bank is not None:
            del self._bank_owner[dom.bank]
        return dom.bank

    # ------------------------------------------------------------------
    # lookups (no side effects beyond LRU)
    # ------------------------------------------------------------------
    def bank_of(self, pd: int) -> Optional[int]:
        dom = self._domains.get(pd)
        return None if dom is None else dom.bank

    def pd_for_bank(self, bank: int) -> Optional[int]:
        return self._bank_owner.get(bank)

    def bound_count(self) -> int:
        return len(self._bank_owner)

    def registered(self, pd: int) -> bool:
        return pd in self._domains

    def is_immune(self, pd: int) -> bool:
        dom = self._domains.get(pd)
        return bool(dom and dom.steal_immune)

    def bindings(self) -> Dict[int, int]:
        """Snapshot ``{bank: pd}`` for invariant checks."""
        return dict(self._bank_owner)

    # ------------------------------------------------------------------
    # binding
    # ------------------------------------------------------------------
    def touch(self, pd: int) -> None:
        dom = self._domains[pd]
        self._tick += 1
        dom.last_use = self._tick

    def domain_handle(self, pd: int) -> Optional[_Domain]:
        """The mutable per-domain record, for caller-side caching.

        The node's per-page hot path holds on to this handle: while
        ``handle.bank`` is not None the binding is live, and a steal
        nulls the victim's ``bank`` in place — so a cached handle can
        never serve a stale bank.  Pair with :meth:`note_hit`.
        """
        return self._domains.get(pd)

    def note_hit(self, dom: _Domain) -> None:
        """Hit accounting for a caller-cached live binding: exactly what
        :meth:`bind` does for an already-bound domain (LRU touch + hit
        counter), minus the dict probe and the Binding allocation."""
        self.stats.hits += 1
        self._tick += 1
        dom.last_use = self._tick

    def bind(self, pd: int,
             fault_active: Callable[[int], bool] = lambda bank: False,
             ) -> Binding:
        """Ensure ``pd`` holds a bank; may steal one.  LRU-touches ``pd``.

        ``fault_active(bank)`` marks banks the SMMU is mid-fault on —
        those must not be ripped out from under the fault FIFO.
        """
        dom = self._domains[pd]
        self.touch(pd)
        if dom.bank is not None:
            self.stats.hits += 1
            return Binding(bank=dom.bank, hit=True)

        bank = self._free_bank(pd)
        if bank is not None:
            self._attach(dom, bank)
            return Binding(bank=bank)

        victim = self._steal_victim(fault_active)
        if victim is None:
            raise NoBankAvailable(
                f"pd {pd}: no bound context bank to steal")
        bank = victim.bank
        assert bank is not None
        if victim.steal_immune:
            self.stats.immune_steals += 1
        victim.bank = None
        del self._bank_owner[bank]
        self.stats.steals += 1
        self._attach(dom, bank)
        return Binding(bank=bank, stolen=True, victim_pd=victim.pd)

    def _attach(self, dom: _Domain, bank: int) -> None:
        dom.bank = bank
        self._bank_owner[bank] = dom.pd
        self.stats.binds += 1
        if dom.ever_bound:
            self.stats.rebinds += 1
        dom.ever_bound = True

    def _free_bank(self, pd: int) -> Optional[int]:
        if self.capacity == 0:
            return None
        preferred = pd % self.capacity
        if preferred not in self._bank_owner:
            return preferred
        for bank in range(self.capacity):
            if bank not in self._bank_owner:
                return bank
        return None

    def try_bind(self, pd: int) -> Optional[int]:
        """Bind only if a bank is free (eager bind at create_domain);
        returns the bank or ``None`` without ever stealing."""
        dom = self._domains[pd]
        if dom.bank is not None:
            return dom.bank
        bank = self._free_bank(pd)
        if bank is not None:
            self.touch(pd)
            self._attach(dom, bank)
        return bank

    def _steal_victim(self, fault_active) -> Optional[_Domain]:
        """LRU victim, preferring (in order): non-immune quiet banks,
        immune quiet banks, then fault-active banks as a last resort —
        losing a fault record only costs the faulting block its 1 ms
        timeout round, while refusing to bind would deadlock the node."""
        def lru(candidates):
            return min(candidates,
                       key=lambda d: (d.last_use, d.bank),
                       default=None)
        # lint: allow(det-dict-iter): feeds min() with a unique tie-break key
        bound = [self._domains[pd] for pd in self._bank_owner.values()]
        quiet = [d for d in bound if not fault_active(d.bank)]
        return (lru([d for d in quiet if not d.steal_immune])
                or lru(quiet)
                or lru([d for d in bound if not d.steal_immune])
                or lru(bound))
