"""Tenancy control plane: virtualizing the fabric's fixed resources.

The thesis hardware exposes hard limits — 16 SMMU context banks per
node (§1.3.1.4), a fixed PLDMA descriptor pool, one receive path — and
the seed reproduction inherited them literally: the 17th protection
domain on a node was rejected.  This package is the control-plane layer
between the verbs API (``repro.api``) and the datapath (``repro.core``)
that multiplexes *many* virtual tenants onto those fixed resources, in
the spirit of RDMAvisor/RDMAbox-style NIC virtualization:

* ``BankManager`` — context-bank overcommit with LRU bank stealing
  (shootdown + rebind cost-modeled in ``CostModel``);
* ``SRQ`` / ``QPMux`` — bounded shared receive entries and queue-pair
  multiplexing with typed ``TenantQuotaExceeded`` backpressure;
* ``SLOClass`` — GOLD/SILVER/BEST_EFFORT tiers mapped onto arbiter
  service classes, weights and bank-steal immunity;
* ``TenancyManager`` — the per-node composition of all of the above,
  surfaced through ``Fabric.protocol_stats().tenancy`` and the soak
  harness' ``"tenancy"`` stats section.

Import discipline: this package sits *below* ``repro.api`` (which
imports it) and imports only ``repro.core`` leaf modules, never the
api layer or ``repro.core.node``.
"""

from repro.tenancy.banks import (BankManager, BankStats, Binding,
                                 NoBankAvailable)
from repro.tenancy.manager import TenancyManager
from repro.tenancy.qp import QPMux, SRQ, SRQStats
from repro.tenancy.slo import SLOClass, coerce_slo

__all__ = [
    "BankManager", "BankStats", "Binding", "NoBankAvailable",
    "QPMux", "SLOClass", "SRQ", "SRQStats", "TenancyManager",
    "coerce_slo",
]
