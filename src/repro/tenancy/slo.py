"""Per-tenant SLO classes for the tenancy control plane.

The thesis models per-domain QoS only implicitly (one protection domain
per SMMU context bank, §1.3.1.4); the multi-tenant reproduction already
splits DMA service into ``ServiceClass.LATENCY``/``BULK``.  The SLO
class is the *tenant-facing* knob that maps a business-level tier onto
the three datapath levers at once:

=============  ==============  ==========  ====================
SLO class      ServiceClass    arb weight  bank-steal immunity
=============  ==============  ==========  ====================
GOLD           LATENCY         4           yes (bank is sticky)
SILVER         BULK            2           no
BEST_EFFORT    BULK            1           no
=============  ==============  ==========  ====================

GOLD tenants keep their SMMU context bank once bound: the BankManager's
LRU steal skips them, so a GOLD tenant never pays the
shootdown-and-rebind penalty on its own faults (it may still queue
behind another tenant's shootdown on the shared driver CPU).
"""

from __future__ import annotations

import enum

from repro.errors import ConfigError
from repro.core.arbiter import ServiceClass

__all__ = ["SLOClass", "coerce_slo"]


class SLOClass(enum.Enum):
    """Tenant service tier; maps onto arbiter class/weight + bank policy."""

    GOLD = "gold"
    SILVER = "silver"
    BEST_EFFORT = "best_effort"

    @property
    def service_class(self) -> ServiceClass:
        return (ServiceClass.LATENCY if self is SLOClass.GOLD
                else ServiceClass.BULK)

    @property
    def arb_weight(self) -> int:
        return {SLOClass.GOLD: 4, SLOClass.SILVER: 2,
                SLOClass.BEST_EFFORT: 1}[self]

    @property
    def steal_immune(self) -> bool:
        """GOLD domains' context banks are never LRU-stolen."""
        return self is SLOClass.GOLD


def coerce_slo(value) -> "SLOClass | None":
    """Accept an ``SLOClass``, its name/value string, or ``None``."""
    if value is None or isinstance(value, SLOClass):
        return value
    if isinstance(value, str):
        key = value.strip().lower()
        for slo in SLOClass:
            if key in (slo.value, slo.name.lower()):
                return slo
    raise ConfigError(
        f"not an SLO class: {value!r} (expected one of "
        f"{', '.join(s.name for s in SLOClass)})")
