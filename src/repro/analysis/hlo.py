"""Optimized-HLO analysis: FLOPs / bytes / collective bytes with loop
trip-count multiplication.

``compiled.cost_analysis()`` visits a while-loop body **once**, so a
scan-over-layers model under-reports by ~n_layers×.  The optimized HLO
text, however, annotates every while with ``known_trip_count`` — this
module parses the module into computations (building a per-computation
symbol table, since optimized HLO references operands by name), walks the
call graph from ENTRY multiplying multiplicities through ``while``
(× trip count) and ``fusion``/``call`` (× 1), and sums:

* **dot FLOPs** — 2 × out_elems × k from the dot's operand shapes
  (matmul-dominated models: this IS the FLOP count; elementwise FLOPs are
  O(bytes) and ignored, as in every MFU accounting);
* **collective bytes** — operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute;
* **HBM bytes** — parameter + output bytes of top-level fusions, dots,
  copies and collectives at multiplicity (an estimate of HBM traffic
  under XLA's fusion).

The text analyzed comes from ``compiled.as_text()`` — post-GSPMD, so all
shapes are already **per-device**; sums are per-chip numbers directly.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            n *= int(d)
    return n


def shape_dims(shape_str: str) -> list:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    out_shape: str
    operands: list                 # operand instruction names
    called: list                   # computation names invoked
    trip_count: int = 1
    raw: str = ""


@dataclasses.dataclass
class HLOAnalysis:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    dot_count: int = 0
    collective_count: int = 0
    computations: int = 0


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRIP_RE = re.compile(r'known_trip_count"?[:=]\s*\{"?n"?:\s*"?(\d+)"?\}')
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_instruction(line: str) -> Optional[Instruction]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, out_shape, opcode, rest = m.groups()
    # operand names: inside the first balanced paren chunk
    depth = 1
    end = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args = rest[:end]
    attrs = rest[end + 1:]
    operands = _OPERAND_RE.findall(args)
    called = [c for c in _CALLED_RE.findall(attrs)]
    bm = _BRANCHES_RE.search(attrs)
    if bm:
        called += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
    trip = 1
    tm = _TRIP_RE.search(attrs)
    if tm:
        trip = int(tm.group(1))
    return Instruction(name=name, opcode=opcode, out_shape=out_shape,
                       operands=operands, called=called, trip_count=trip,
                       raw=line)


def _parse_computations(hlo: str) -> tuple[dict, str]:
    comps: dict[str, list[Instruction]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s and ("(" in s):
            header = s
            is_entry = header.startswith("ENTRY")
            if is_entry:
                header = header[len("ENTRY"):].strip()
            if not header.startswith("%") and not is_entry:
                # could be e.g. "HloModule ... {" — skip
                if not header.startswith("%"):
                    continue
            name = header.split()[0].split("(")[0].lstrip("%")
            cur = name
            comps[cur] = []
            if is_entry:
                entry = cur
        elif s.startswith("}"):
            cur = None
        elif cur is not None and "=" in s and s.lstrip().startswith(("%", "ROOT")):
            instr = _parse_instruction(s)
            if instr is not None:
                comps[cur].append(instr)
    return comps, entry or (next(iter(comps)) if comps else "")


def _dot_flops(instr: Instruction, symbols: dict) -> float:
    out_elems = shape_elems(instr.out_shape)
    cm = _CONTRACT_RE.search(instr.raw)
    if not cm or not instr.operands:
        return 2.0 * out_elems
    lhs = symbols.get(instr.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    dims = shape_dims(lhs.out_shape)
    k = 1
    for d in (int(x) for x in cm.group(1).split(",") if x != ""):
        if d < len(dims):
            k *= dims[d]
    return 2.0 * out_elems * k


def _fusion_read_bytes(instr: Instruction, sym: dict, parsed: dict) -> float:
    """HBM reads of a fusion: operands, except that a parameter whose only
    use inside the fused computation is the *gathered* operand of a
    gather/dynamic-slice contributes only the gathered rows (otherwise a
    paged-KV pool would be counted in full on every page step)."""
    comp = parsed.get(instr.called[0]) if instr.called else None
    total = 0.0
    if comp is None:
        for o in instr.operands:
            if o in sym:
                total += shape_bytes(sym[o].out_shape)
        return total
    # map parameter index -> gather-only? and gathered-output bytes
    params: dict[int, Instruction] = {}
    for fi in comp:
        if fi.opcode == "parameter":
            mnum = re.search(r"parameter\((\d+)\)", fi.raw)
            if mnum:
                params[int(mnum.group(1))] = fi
    for idx, o in enumerate(instr.operands):
        if o not in sym:
            continue
        full = shape_bytes(sym[o].out_shape)
        p_instr = params.get(idx)
        if p_instr is not None:
            users = [fi for fi in comp if p_instr.name in fi.operands]
            if users and all(u.opcode in ("gather", "dynamic-slice")
                             and u.operands and u.operands[0] == p_instr.name
                             for u in users):
                total += sum(shape_bytes(u.out_shape) for u in users)
                continue
        total += full
    return total


def analyze_hlo(hlo: str) -> HLOAnalysis:
    comps, entry = _parse_computations(hlo)
    symtabs = {name: {i.name: i for i in instrs}
               for name, instrs in comps.items()}

    mult: dict[str, float] = defaultdict(float)

    def walk(comp: str, m: float, depth=0):
        if depth > 100 or comp not in comps:
            return
        mult[comp] += m
        for instr in comps[comp]:
            child_m = m * (instr.trip_count if instr.opcode == "while" else 1)
            for c in instr.called:
                walk(c, child_m, depth + 1)

    walk(entry, 1.0)

    res = HLOAnalysis()
    res.computations = len(comps)
    breakdown: dict[str, float] = defaultdict(float)
    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        sym = symtabs[comp]
        for instr in instrs:
            op = instr.opcode
            if op in ("dot", "dot-general"):
                res.dot_flops += m * _dot_flops(instr, sym)
                res.dot_count += 1
            elif any(op.startswith(c) for c in _COLLECTIVES):
                b = sum(shape_bytes(sym[o].out_shape) for o in instr.operands
                        if o in sym) or shape_bytes(instr.out_shape)
                res.collective_bytes += m * b
                base = op
                for suf in ("-start", "-done"):
                    base = base[:-len(suf)] if base.endswith(suf) else base
                breakdown[base] += m * b
                res.collective_count += 1
            if op in ("fusion", "dot", "dot-general", "custom-call",
                      "convolution", "copy", "gather", "dynamic-slice") \
                    or any(op.startswith(c) for c in _COLLECTIVES):
                io = shape_bytes(instr.out_shape)
                operand_bytes = [shape_bytes(sym[o].out_shape)
                                 for o in instr.operands if o in sym]
                if op in ("gather", "dynamic-slice"):
                    # reads only the gathered rows, not the whole operand
                    io += shape_bytes(instr.out_shape)
                elif op == "fusion" and instr.called:
                    io += _fusion_read_bytes(instr, sym, parsed=comps)
                else:
                    io += sum(operand_bytes)
                res.hbm_bytes += m * io
    res.collective_breakdown = dict(breakdown)
    return res
