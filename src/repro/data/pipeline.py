"""Deterministic, shardable token pipeline.

Two sources:

* :class:`SyntheticLM` — seeded Zipf-ish token stream with local structure
  (learnable bigram bias) so smoke-training shows a real loss drop;
* :class:`PackedFileDataset` — flat uint16/uint32 token files (the
  production path), memory-mapped and sharded by (host, data-axis) with
  deterministic resume (step -> offset is pure arithmetic, so restoring a
  checkpoint replays the exact batch order — required for fault-tolerant
  restarts).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    shard: int = 0        # this host's data-parallel index
    n_shards: int = 1


class SyntheticLM:
    """Deterministic synthetic LM data with predictable structure."""

    def __init__(self, vocab_size: int, seq_len: int, batch_per_shard: int,
                 shard: ShardInfo = ShardInfo(), seed: int = 1234):
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.batch = batch_per_shard
        self.shard = shard
        self.seed = seed
        # fixed random bigram table: next token = f(prev) with noise
        rng = np.random.default_rng(seed)
        self.bigram = rng.integers(0, vocab_size, size=(vocab_size,))

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, step, self.shard.shard))
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=B)
        noise = rng.random((B, S)) < 0.15
        rand = rng.integers(0, self.vocab, size=(B, S))
        for t in range(1, S):
            nxt = self.bigram[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1        # masked
        return toks, labels

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedFileDataset:
    """Flat binary token file, deterministic strided sharding."""

    def __init__(self, path: str, vocab_size: int, seq_len: int,
                 batch_per_shard: int, shard: ShardInfo = ShardInfo(),
                 dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab_size
        self.seq_len = seq_len
        self.batch = batch_per_shard
        self.shard = shard
        self.n_windows = (len(self.tokens) - 1) // seq_len

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        B, S = self.batch, self.seq_len
        base = (step * self.shard.n_shards + self.shard.shard) * B
        idx = (base + np.arange(B)) % self.n_windows
        toks = np.stack([self.tokens[i * S:(i + 1) * S] for i in idx])
        labels = np.stack([self.tokens[i * S + 1:(i + 1) * S + 1] for i in idx])
        return toks.astype(np.int32), labels.astype(np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def write_packed_file(path: str, tokens: np.ndarray, dtype=np.uint16) -> None:
    np.asarray(tokens, dtype).tofile(path)
