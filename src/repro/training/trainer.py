"""Training loop: remat, microbatch accumulation, checkpoint/restart.

``make_train_step`` builds the jit-able step used both by the real CPU
training examples and by the 512-device dry-run (same code path — the
dry-run just lowers it under the production mesh with ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import model_for
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, AdamWState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1           # gradient-accumulation factor
    remat: bool = True              # checkpoint the layer scan
    q_chunk: int = 512
    kv_chunk: int = 512
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    model = model_for(cfg)

    def loss(params, tokens, labels, *extra):
        kw = {"remat": tcfg.remat}
        if cfg.family in ("dense", "moe", "mla_moe"):
            kw.update(q_chunk=tcfg.q_chunk, kv_chunk=tcfg.kv_chunk)
        if cfg.is_encdec and extra:
            kw["frame_embeddings"] = extra[0]
        return model.loss_fn(params, cfg, tokens, labels, **kw)

    return loss


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """(params, opt_state, tokens, labels) -> (params, opt_state, metrics).

    tokens/labels: (global_batch, seq).  With ``microbatches = m`` the
    batch is split on axis 0 and gradients accumulate in fp32 across an
    inner scan — the standard memory/throughput lever.
    """
    loss_fn = make_loss_fn(cfg, tcfg)

    def step(params, opt_state: AdamWState, tokens, labels, *extra):
        m = tcfg.microbatches
        if m == 1:
            l, grads = jax.value_and_grad(loss_fn)(params, tokens, labels,
                                                   *extra)
        else:
            B = tokens.shape[0]
            split = lambda a: a.reshape(m, B // m, *a.shape[1:])
            xs = (split(tokens), split(labels)) + tuple(
                split(e) for e in extra)

            def micro(carry, xs):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, *xs)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), xs)
            grads = jax.tree_util.tree_map(lambda g: g / m, gacc)
            l = lsum / m

        params, opt_state, metrics = adamw.update(tcfg.optimizer, opt_state,
                                                  params, grads)
        metrics["loss"] = l
        return params, opt_state, metrics

    return step


class Trainer:
    """Host-side loop: data, jit step, periodic checkpoint, metrics."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, params,
                 dataset, checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 checkpointer: Optional[Any] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.params = params
        self.opt_state = adamw.init(tcfg.optimizer, params)
        self.dataset = dataset
        self.step_fn = jax.jit(make_train_step(cfg, tcfg))
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.checkpointer = checkpointer
        self.step = 0
        self.history: list[dict] = []

    def restore(self) -> bool:
        if self.checkpointer is None or self.checkpoint_dir is None:
            return False
        restored = self.checkpointer.restore_latest(self.checkpoint_dir)
        if restored is None:
            return False
        self.params, self.opt_state, self.step = restored
        return True

    def run(self, n_steps: int, log_every: int = 10,
            log_fn: Callable[[str], None] = print) -> list[dict]:
        # lint: allow(det-wallclock): host step-rate telemetry only
        t0 = time.perf_counter()
        for _ in range(n_steps):
            tokens, labels = self.dataset.batch_at(self.step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, tokens, labels)
            self.step += 1
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = self.step
            self.history.append(rec)
            if log_every and self.step % log_every == 0:
                # lint: allow(det-wallclock): host step-rate telemetry only
                dt = time.perf_counter() - t0
                log_fn(f"step {self.step:5d}  loss {rec['loss']:.4f}  "
                       f"gnorm {rec['grad_norm']:.3f}  "
                       f"{dt / log_every:.2f}s/step")
                # lint: allow(det-wallclock): host step-rate telemetry only
                t0 = time.perf_counter()
            if (self.checkpointer is not None and self.checkpoint_every
                    and self.step % self.checkpoint_every == 0):
                self.checkpointer.save(self.checkpoint_dir, self.params,
                                       self.opt_state, self.step)
        return self.history
