"""Invariant checkers for the stress harness (and the property tests).

Each checker returns a list of human-readable violation strings — empty
means the invariant holds.  They are pure observers: no checker mutates
fabric, pager or page-table state, so they can run mid-soak as well as
at the end.
"""

from __future__ import annotations

from typing import Iterable, List

from repro.core.arbiter import ArbiterStats
from repro.net.link import LinkStats

NON_RESIDENT = -1


# ---------------------------------------------------------------- fabric
def check_completion_conservation(posted_ids: Iterable[int],
                                  completed_ids: Iterable[int],
                                  label: str = "") -> List[str]:
    """Every posted work request completes exactly once (no lost or
    duplicated completions) — the block-conservation invariant at WR
    granularity."""
    posted = list(posted_ids)
    completed = list(completed_ids)
    out = []
    tag = f" [{label}]" if label else ""
    if len(set(posted)) != len(posted):
        out.append(f"duplicate wr_ids posted{tag}")
    dupes = {w for w in completed if completed.count(w) > 1}
    if dupes:
        out.append(f"wr_ids completed more than once{tag}: {sorted(dupes)}")
    lost = set(posted) - set(completed)
    if lost:
        out.append(f"posted but never completed{tag}: {sorted(lost)}")
    phantom = set(completed) - set(posted)
    if phantom:
        out.append(f"completed but never posted{tag}: {sorted(phantom)}")
    return out


def check_pinned_resident(fabric) -> List[str]:
    """Pinned pages are exempt from reclaim/THP: every pinned PTE must
    still be RESIDENT, whatever churn the injection schedule applied."""
    out = []
    for node in fabric.nodes:
        for pd, pt in node.page_tables.items():
            for vpn, pte in pt.entries.items():
                if pte.pinned and pte.state.name != "RESIDENT":
                    out.append(
                        f"node {node.node_id} pd={pd} vpn={vpn:#x}: pinned "
                        f"page in state {pte.state.name}")
    return out


def check_link_conservation(fabric) -> List[str]:
    """Per-link packet conservation over the routed interconnect: every
    directed link carried exactly the data/ctrl packets of the routes
    that cross it (ledger recomputed from the deterministic router) —
    nothing lost, duplicated, or smuggled around the topology."""
    return fabric.interconnect.conservation_violations()


def check_route_sanity(fabric) -> List[str]:
    """Static route invariants for every (src, dst) pair: consecutive
    hops are physical adjacencies, no node repeats, and hop counts are
    symmetric (|route(a, b)| == |route(b, a)| for minimal routing)."""
    out = []
    ic = fabric.interconnect
    n = ic.topology.n_nodes
    for a in range(n):
        for b in range(n):
            try:
                fwd = ic.router.route(a, b)      # router verifies adjacency
                rev = ic.router.route(b, a)
            except Exception as e:               # RoutingError et al.
                out.append(f"route {a}->{b}: {e}")
                continue
            if len(fwd) != len(rev):
                out.append(
                    f"asymmetric hop count: |{a}->{b}|={len(fwd) - 1} "
                    f"but |{b}->{a}|={len(rev) - 1}")
    return out


def check_tr_id_lifecycle(fabric) -> List[str]:
    """The tr_ID free-list/index invariants on every node's R5:

    * no ID is simultaneously free and owned by a pending block;
    * the free list holds no duplicates;
    * the accounting identity ``fresh_issued == pending + free`` holds
      (every issued ID is either owned or recyclable);
    * the per-(pd, vpn) source-fault index contains exactly the pending
      blocks, in launch order — the O(1) lookup must answer precisely
      what the seed's O(pending) scan would have;
    * once the fabric drained: nothing pending, no deferred launches.
    """
    out = []
    for node in fabric.nodes:
        r5 = node.r5
        tag = f"node {node.node_id}"
        free = list(r5._free)
        if len(set(free)) != len(free):
            out.append(f"{tag}: duplicate tr_ids on the free list")
        overlap = set(free) & set(r5.pending)
        if overlap:
            out.append(f"{tag}: tr_ids both free and pending: "
                       f"{sorted(overlap)[:8]}")
        issued = r5._fresh_next
        if len(r5.pending) + len(free) != issued:
            out.append(
                f"{tag}: {len(r5.pending)} pending + {len(free)} free != "
                f"{issued} ids issued (leaked or double-freed)")
        for tid, block in r5.pending.items():
            if block.tr_id != tid:
                out.append(f"{tag}: pending[{tid}] holds block with "
                           f"tr_id={block.tr_id}")
        # rebuild the src index from pending (launch order == dict order);
        # keys are the scheduler's packed ``(pd << 32) | vpn`` ints
        expect: dict = {}
        for block in r5.pending.values():
            base = block.transfer.pd << 32
            first = block.src_va >> 12
            last = (block.src_va + block.nbytes - 1) >> 12
            for vpn in range(first, last + 1):
                expect.setdefault(base | vpn, []).append(block)
        if expect != r5._src_index:
            missing = set(expect) ^ set(r5._src_index)
            out.append(f"{tag}: src-fault index diverged from pending "
                       f"({len(missing)} keys differ)")
        if fabric.loop.idle:
            if r5.pending:
                out.append(f"{tag}: {len(r5.pending)} blocks still pending "
                           f"after drain")
            if r5._starved:
                out.append(f"{tag}: {len(r5._starved)} deferred launches "
                           f"left after drain")
    return out


def check_npr_consistency(fabric) -> List[str]:
    """NP-RDMA backend invariants, on every node serving NP_RDMA domains:

    * a *fresh* (non-stale) MTT entry never maps a reclaimed or moved
      frame: its page must be RESIDENT with exactly that frame — i.e.
      the page-table invalidation hooks staled every dying translation;
    * no page ever completed through a stale translation
      (``stats.stale_completions == 0`` — the backend's safety property);
    * DMA-pool frame conservation: ``free + reserved + retired`` equals
      the registered capacity and no frame sits in two lifecycle sets;
    * once the fabric drained: no pool reservation outstanding (every
      redirect retired its frames, every superseded one was cancelled).
    """
    out = []
    for node in fabric.nodes:
        eng = node.npr
        if not eng.domains:
            continue
        tag = f"node {node.node_id}"
        for (pd, vpn), e in eng.mtt.entries():
            if e.stale:
                continue
            pt = eng.domains.get(pd)
            if pt is None:
                out.append(f"{tag}: MTT entry for unregistered pd={pd}")
                continue
            pte = pt.lookup(vpn)
            if pte.state.name != "RESIDENT":
                out.append(
                    f"{tag} pd={pd} vpn={vpn:#x}: fresh MTT entry maps a "
                    f"{pte.state.name} page (missed invalidation)")
            elif pte.frame != e.frame:
                out.append(
                    f"{tag} pd={pd} vpn={vpn:#x}: fresh MTT entry frame "
                    f"{e.frame} != page-table frame {pte.frame}")
        if eng.stats.stale_completions:
            out.append(f"{tag}: {eng.stats.stale_completions} pages "
                       f"completed through a stale translation")
        pool = eng.pool
        frames = list(pool.free) + list(pool.retired)
        for held in pool.reserved.values():
            frames.extend(held)
        if len(frames) != pool.capacity:
            out.append(f"{tag}: DMA pool accounts {len(frames)} frames, "
                       f"capacity {pool.capacity}")
        if len(set(frames)) != len(frames):
            out.append(f"{tag}: DMA-pool frame in two lifecycle sets")
        if fabric.loop.idle and pool.reserved:
            out.append(f"{tag}: {len(pool.reserved)} DMA-pool reservations "
                       f"outstanding after drain")
    return out


#: the WCStatus values a failed transfer may carry (kept as strings to
#: match ``Transfer.failed_status`` — the core layer never imports api)
FAILED_STATUSES = {"retry_exc_err", "wr_flush_err", "remote_op_err"}


def check_crash_consistency(fabric) -> List[str]:
    """Crash-fault invariants, safe to run mid-soak or after drain:

    * a crashed node's datapath is *silent*: its arbiter holds no PLDMA
      slot and queues no block, and every tr_id it still leases belongs
      to a DONE (failed) block awaiting lease expiry — a dead machine
      neither launches nor retransmits;
    * a crashed node is fenced off the interconnect: every incident
      directed link is marked down (``fail_node`` left no back door);
    * every failed transfer fail-stopped *cleanly*: its status is one of
      the three crash-fault WC statuses, every block reached DONE and
      left the arbiter queue, and the transfer never also reports
      ``complete`` — i.e. its work request completes exactly once, with
      a non-SUCCESS status, never both ways.
    """
    out = []
    ic = fabric.interconnect
    for node in fabric.nodes:
        tag = f"node {node.node_id}"
        r5 = node.r5
        if node.crashed:
            arb = node.arbiter
            if arb.in_flight:
                out.append(f"{tag}: crashed but {arb.in_flight} blocks "
                           f"still hold PLDMA slots")
            depth = arb.queue_depth()
            if depth:
                out.append(f"{tag}: crashed but {depth} blocks still "
                           f"queued in the arbiter")
            for tid, block in r5.pending.items():
                if block.state.name != "DONE":
                    out.append(f"{tag}: crashed but leased tr_id {tid} "
                               f"holds a {block.state.name} block")
            for nbr in ic.topology.neighbors(node.node_id):
                if (node.node_id, nbr) not in ic.down \
                        or (nbr, node.node_id) not in ic.down:
                    out.append(f"{tag}: crashed but link to {nbr} is "
                               f"not marked down")
        # failed transfers (any node — retry exhaustion and flush happen
        # on live nodes too) must have fail-stopped cleanly
        seen: set = set()
        for block in r5.pending.values():
            t = block.transfer
            if id(t) in seen:
                continue
            seen.add(id(t))
            if t.failed_status is None:
                continue
            if t.failed_status not in FAILED_STATUSES:
                out.append(f"{tag} tid={t.tid}: unknown failed_status "
                           f"{t.failed_status!r}")
            if t.complete:
                out.append(f"{tag} tid={t.tid}: transfer both failed "
                           f"({t.failed_status}) and complete — its WR "
                           f"would complete twice")
            for b in t.blocks:
                if b.state.name != "DONE":
                    out.append(f"{tag} tid={t.tid}: failed transfer "
                               f"holds a {b.state.name} block")
                if b.queued:
                    out.append(f"{tag} tid={t.tid}: failed transfer's "
                               f"block still queued in the arbiter")
    return out


def check_arbiter_consistency(fabric) -> List[str]:
    """Arbiter telemetry and end-state sanity:

    * per-domain :class:`ArbiterStats` sum to the node total on every
      additive field;
    * DRR deficit counters sit inside the fairness bound;
    * once the fabric drained, no block is queued, slotted, or counted
      outstanding (nothing leaked a PLDMA slot).
    """
    out = []
    for node in fabric.nodes:
        arb = node.arbiter
        for field in ArbiterStats.ADDITIVE:
            total = getattr(arb.stats, field)
            per_dom = sum(getattr(s, field)
                          for s in arb.domain_stats.values())
            if total != per_dom:
                out.append(
                    f"node {node.node_id}: arbiter stats field {field!r} "
                    f"total {total} != per-domain sum {per_dom}")
        out.extend(arb.deficit_bound_violations())
        out.extend(arb.depth_counter_violations())
        if fabric.loop.idle:
            if arb.in_flight != 0:
                out.append(f"node {node.node_id}: {arb.in_flight} blocks "
                           f"still hold PLDMA slots after drain")
            depth = arb.queue_depth()
            if depth != 0:
                out.append(f"node {node.node_id}: {depth} blocks still "
                           f"queued after drain")
            for pd in arb.domain_stats:
                n = arb.outstanding(pd)
                if n != 0:
                    out.append(f"node {node.node_id} pd={pd}: {n} blocks "
                               f"still outstanding after drain")
    return out


def check_bank_conservation(fabric) -> List[str]:
    """Tenancy control-plane invariants on every node's BankManager/SMMU:

    * the pd <-> bank binding is a bijection: no two domains share a
      bank, no domain holds two banks, and at most ``capacity`` (16)
      banks are ever bound;
    * the SMMU agrees with the manager: a bound bank's attached page
      table IS the bound domain's page table, and an unbound bank is
      detached;
    * TLB entries exist only for bound banks (a steal's
      ``tlb_invalidate_all`` left nothing behind);
    * the counters obey their accounting identities:
      ``shootdowns == steals`` (every steal shoots down the victim) and
      ``binds >= steals`` (a steal is one kind of bind).
    """
    out = []
    for node in fabric.nodes:
        tag = f"node {node.node_id}"
        mgr = node.tenancy.banks
        bindings = mgr.bindings()               # bank -> pd snapshot
        if len(bindings) > mgr.capacity:
            out.append(f"{tag}: {len(bindings)} banks bound, capacity "
                       f"{mgr.capacity}")
        pds = list(bindings.values())
        if len(set(pds)) != len(pds):
            out.append(f"{tag}: one pd bound to multiple banks")
        for bank, pd in bindings.items():
            if not mgr.registered(pd):
                out.append(f"{tag}: bank {bank} bound to unregistered "
                           f"pd={pd}")
            pt = node.page_tables.get(pd)
            attached = node.smmu.banks[bank].page_table
            if pt is None:
                out.append(f"{tag}: bank {bank} bound to pd={pd} with no "
                           f"page table")
            elif attached is not pt:
                out.append(f"{tag}: bank {bank} SMMU page table is not "
                           f"pd={pd}'s (stale attach after a steal?)")
        for bank in range(mgr.capacity):
            if bank not in bindings \
                    and node.smmu.banks[bank].page_table is not None:
                out.append(f"{tag}: unbound bank {bank} still attached "
                           f"in the SMMU")
        for key in node.smmu._tlb:      # packed (bank << 32) | vpn keys
            bank, vpn = key >> 32, key & 0xFFFF_FFFF
            if bank not in bindings:
                out.append(f"{tag}: TLB entry for unbound bank {bank} "
                           f"vpn={vpn:#x} (missed shootdown)")
        st = mgr.stats
        if st.shootdowns != st.steals:
            out.append(f"{tag}: {st.steals} steals but {st.shootdowns} "
                       f"shootdowns (every steal must invalidate)")
        if st.binds < st.steals:
            out.append(f"{tag}: binds {st.binds} < steals {st.steals}")
    return out


def check_tenant_isolation(fabric) -> List[str]:
    """Cross-tenant isolation after any amount of bank thrash:

    * no physical frame is owned by two (pd, vpn) mappings — the
      FrameAllocator's owner ledger is authoritative and every owning
      page table agrees with it;
    * every TLB entry's cached frame matches the *current* owner's page
      table (a stolen bank's stale walks can never leak another
      tenant's frame);
    * SRQ accounting: held entries never exceed the configured bound
      and, once the fabric drained, every acquired entry was released.
    """
    from repro.npr.pool import POOL_PD
    out = []
    for node in fabric.nodes:
        tag = f"node {node.node_id}"
        for frame, (pd, vpn) in node.allocator.owner.items():
            if pd == POOL_PD:
                continue      # NP-RDMA DMA-pool frames: no page table
            pt = node.page_tables.get(pd)
            if pt is None:
                # domain closed: release_domain should have freed these
                out.append(f"{tag}: frame {frame} owned by closed pd={pd}")
                continue
            pte = pt.entries.get(vpn)
            if pte is None or pte.frame != frame:
                out.append(f"{tag}: allocator says frame {frame} -> "
                           f"(pd={pd}, vpn={vpn:#x}) but the page table "
                           f"disagrees")
        bindings = node.tenancy.banks.bindings()
        for key, frame in node.smmu._tlb.items():
            bank, vpn = key >> 32, key & 0xFFFF_FFFF
            pd = bindings.get(bank)
            if pd is None:
                continue                    # reported by bank conservation
            pt = node.page_tables.get(pd)
            pte = pt.entries.get(vpn) if pt is not None else None
            if pte is None or pte.state.name != "RESIDENT" \
                    or pte.frame != frame:
                out.append(f"{tag}: TLB bank {bank} vpn={vpn:#x} caches "
                           f"frame {frame} not owned by pd={pd} "
                           f"(cross-tenant leak)")
        srq = node.tenancy.srq
        limit = srq.entries
        if limit is not None and srq.held > limit:
            out.append(f"{tag}: SRQ holds {srq.held} > {limit} entries")
        if srq.held < 0:
            out.append(f"{tag}: SRQ held count negative ({srq.held})")
        if fabric.loop.idle and srq.held:
            out.append(f"{tag}: {srq.held} SRQ entries still held after "
                       f"drain (leaked receive credits)")
    return out


def check_stats_accounting(fabric) -> List[str]:
    """Counter-accounting identities on the ``*Stats`` records the soak
    harness regresses on (the ``stats-coverage`` lint rule holds every
    counter to one of these checks or a justified exemption):

    * tr_ID telemetry matches the R5's live structures
      (``allocated == fresh + recycled``, ``fresh`` equals the IDs ever
      issued, ``in_flight == len(pending)``), the high-water mark sits
      between the live count and the ID-space size, and a stall is only
      possible once the whole ID space has been in flight;
    * CQ slot conservation: ``outstanding == posted - drained`` (a
      queued completion still occupies its slot), the queue never beat
      its high-water mark or depth, and polls dominate empty polls;
    * fault-FIFO occupancy equals ``pushes - pops`` and respects the
      recorded high-water mark and the hardware depth;
    * the arbiter backlog never exceeds its own high-water mark;
    * bank counters: every rebind is a bind, every immune steal a steal;
    * SRQ conservation: ``admitted - released == held`` with the peak
      between the live count and total admissions (and under the bound);
    * SMMU TLB hits never exceed translations, page-table unpins never
      exceed pins, and the NP-RDMA capacity counters mirror the live
      pool/MTT (with the reservation peak inside the pool);
    * interconnect totals are exactly the field-wise sum of the per-link
      ledgers (``LinkStats.ADDITIVE``) with ``max_queue_us`` the
      per-link maximum, and no link's worst single wait exceeds its
      summed wait.
    """
    out = []
    for node in fabric.nodes:
        tag = f"node {node.node_id}"
        r5 = node.r5
        st = r5.id_stats
        if st.allocated != st.fresh + st.recycled:
            out.append(f"{tag}: tr_id allocated {st.allocated} != fresh "
                       f"{st.fresh} + recycled {st.recycled}")
        if st.fresh != r5._fresh_next:
            out.append(f"{tag}: tr_id fresh count {st.fresh} != "
                       f"{r5._fresh_next} ids ever issued")
        if st.in_flight != len(r5.pending):
            out.append(f"{tag}: tr_id in_flight {st.in_flight} != "
                       f"{len(r5.pending)} pending blocks")
        if not st.in_flight <= st.max_in_flight <= st.space:
            out.append(f"{tag}: tr_id in_flight {st.in_flight} / "
                       f"high-water {st.max_in_flight} / space {st.space} "
                       f"out of order")
        if st.stalls and st.max_in_flight != st.space:
            out.append(f"{tag}: {st.stalls} stalls but the ID space never "
                       f"filled (max_in_flight {st.max_in_flight} < "
                       f"{st.space})")
        fifo = node.fifo
        fst = fifo.stats
        if fst.pushes - fst.pops != len(fifo):
            out.append(f"{tag}: FIFO holds {len(fifo)} entries, but pushes "
                       f"{fst.pushes} - pops {fst.pops} says "
                       f"{fst.pushes - fst.pops}")
        if not len(fifo) <= fst.max_occupancy <= fifo.depth:
            out.append(f"{tag}: FIFO occupancy {len(fifo)} / high-water "
                       f"{fst.max_occupancy} / depth {fifo.depth} "
                       f"out of order")
        arb = node.arbiter
        if arb.stats.max_queue_depth < arb.queue_depth():
            out.append(f"{tag}: arbiter backlog {arb.queue_depth()} beats "
                       f"its high-water mark {arb.stats.max_queue_depth}")
        bst = node.tenancy.banks.stats
        if bst.immune_steals > bst.steals:
            out.append(f"{tag}: immune_steals {bst.immune_steals} > "
                       f"steals {bst.steals}")
        if bst.rebinds > bst.binds:
            out.append(f"{tag}: rebinds {bst.rebinds} > binds {bst.binds}")
        srq = node.tenancy.srq
        sst = srq.stats
        if sst.admitted - sst.released != srq.held:
            out.append(f"{tag}: SRQ admitted {sst.admitted} - released "
                       f"{sst.released} != held {srq.held}")
        if not srq.held <= sst.peak_held <= sst.admitted:
            out.append(f"{tag}: SRQ held {srq.held} / peak {sst.peak_held} "
                       f"/ admitted {sst.admitted} out of order")
        if srq.entries is not None and sst.peak_held > srq.entries:
            out.append(f"{tag}: SRQ peak {sst.peak_held} > bound "
                       f"{srq.entries}")
        sm = node.smmu.stats
        if sm.tlb_hits > sm.translations:
            out.append(f"{tag}: SMMU tlb_hits {sm.tlb_hits} > "
                       f"{sm.translations} translations")
        for pd, pt in sorted(node.page_tables.items()):
            pst = pt.stats
            if pst.unpins > pst.pins:
                out.append(f"{tag} pd={pd}: unpins {pst.unpins} > pins "
                           f"{pst.pins}")
        eng = node.npr
        if eng.domains:
            nst = eng.stats
            if nst.pool_frames != eng.pool.capacity:
                out.append(f"{tag}: NPR pool_frames {nst.pool_frames} != "
                           f"pool capacity {eng.pool.capacity}")
            if nst.mtt_capacity != eng.mtt.capacity:
                out.append(f"{tag}: NPR mtt_capacity {nst.mtt_capacity} != "
                           f"MTT capacity {eng.mtt.capacity}")
            if nst.pool_reserved_peak > nst.pool_frames:
                out.append(f"{tag}: NPR pool reservation peak "
                           f"{nst.pool_reserved_peak} > {nst.pool_frames} "
                           f"frames")

    for i, cq in enumerate(getattr(fabric, "cqs", ())):
        cst = cq.stats
        drained = cst.completed - len(cq)
        if cq.outstanding != cst.posted - drained:
            out.append(f"cq {i}: {cq.outstanding} outstanding != posted "
                       f"{cst.posted} - drained {drained}")
        if not len(cq) <= cst.max_queued <= cq.depth:
            out.append(f"cq {i}: queued {len(cq)} / high-water "
                       f"{cst.max_queued} / depth {cq.depth} out of order")
        if cst.empty_polls > cst.polls:
            out.append(f"cq {i}: empty_polls {cst.empty_polls} > polls "
                       f"{cst.polls}")

    ic = fabric.interconnect
    fs = ic.stats()
    totals = {f: 0 for f in LinkStats.ADDITIVE}
    worst = 0.0
    for _, link in sorted(ic.links.items()):
        s = link.stats
        if s.max_queue_us > s.queue_us:
            out.append(f"link {link.name}: worst single wait "
                       f"{s.max_queue_us} > summed wait {s.queue_us}")
        if not (s.data_packets or s.ctrl_packets):
            continue                # ic.stats() skips quiet links the same
        for f in LinkStats.ADDITIVE:
            totals[f] += getattr(s, f)
        worst = max(worst, s.max_queue_us)
    totals["busy_us"] = round(totals["busy_us"], 6)
    totals["queue_us"] = round(totals["queue_us"], 6)
    for f in LinkStats.ADDITIVE:
        if getattr(fs, f) != totals[f]:
            out.append(f"net: fabric total {f} {getattr(fs, f)} != "
                       f"per-link sum {totals[f]}")
    if fs.max_queue_us != round(worst, 6):
        out.append(f"net: fabric max_queue_us {fs.max_queue_us} != "
                   f"per-link max {round(worst, 6)}")
    return out


# ------------------------------------------------------------------ vmem
def check_vmem_frame_conservation(pool) -> List[str]:
    """No frame double-owned across the pool's address spaces, and the
    pool's used-frame count equals the resident-page count."""
    out = []
    owner = {}
    resident = 0
    for sp in pool.spaces:
        for vpage in range(sp.n_pages):
            f = int(sp.page_table[vpage])
            if f == NON_RESIDENT:
                continue
            resident += 1
            if f in owner:
                out.append(f"frame {f} owned by both {owner[f]} and "
                           f"({sp.name!r}, {vpage})")
            owner[f] = (sp.name, vpage)
    if resident != pool.frames_used:
        out.append(f"{resident} resident pages but pool reports "
                   f"{pool.frames_used} frames used")
    free = set(pool.free)
    leaked = free & set(owner)
    if leaked:
        out.append(f"frames on the free list while mapped: {sorted(leaked)}")
    return out


def check_vmem_pins(pool) -> List[str]:
    """A pinned page is never evicted: pinned implies resident."""
    out = []
    for sp in pool.spaces:
        for vpage in range(sp.n_pages):
            if sp.pinned[vpage] and \
                    int(sp.page_table[vpage]) == NON_RESIDENT:
                out.append(f"space {sp.name!r} vpage {vpage}: pinned "
                           f"but not resident")
    return out
