"""Deterministic fabric stress/soak harness.

Everything the example-driven tests could not prove lives here: seeded
multi-tenant traffic generation (:mod:`repro.testing.traffic`), invariant
checkers (:mod:`repro.testing.invariants`) and the
:func:`~repro.testing.soak.soak` entry point shared by the stress tests
and ``benchmarks/arbiter_qos.py``.

Determinism contract: two ``soak(seed)`` runs with the same seed produce
**byte-identical** stats dicts (``json.dumps(..., sort_keys=True)``), and
different seeds produce different traffic — guarded by
``tests/test_stress.py``, so the event loop stays free of wall-clock and
iteration-order nondeterminism.
"""

from repro.testing.invariants import (check_arbiter_consistency,
                                      check_bank_conservation,
                                      check_completion_conservation,
                                      check_crash_consistency,
                                      check_link_conservation,
                                      check_pinned_resident,
                                      check_route_sanity,
                                      check_tenant_isolation,
                                      check_stats_accounting,
                                      check_tr_id_lifecycle,
                                      check_vmem_frame_conservation,
                                      check_vmem_pins)
from repro.testing.soak import SoakResult, soak
from repro.testing.traffic import FaultInjection, TenantSpec, scale_mix

__all__ = [
    "FaultInjection", "SoakResult", "TenantSpec",
    "check_arbiter_consistency", "check_bank_conservation",
    "check_completion_conservation", "check_crash_consistency",
    "check_link_conservation", "check_pinned_resident",
    "check_route_sanity", "check_tenant_isolation",
    "check_stats_accounting", "check_tr_id_lifecycle",
    "check_vmem_frame_conservation",
    "check_vmem_pins", "scale_mix", "soak",
]
