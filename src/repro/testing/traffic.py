"""Seeded multi-tenant traffic generation over a verbs fabric.

A :class:`TenantSpec` declares one protection domain's workload (service
class, arrival process, sizes, buffer preparation — i.e. whether its
destinations fault) and a :class:`FaultInjection` declares the background
churn (khugepaged collapses, reclaim/swap-out) the thesis identifies as
the reason even touched buffers keep faulting.  :class:`TenantRun` drives
one tenant entirely in virtual time: posts, CQ drains and retries are
event-loop callbacks, so a run is a pure function of ``(specs, seed)``.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.api.completion import WorkQueueFull
from repro.api.fabric import Fabric, NodeDown
from repro.api.memory import BufferPrep
from repro.api.policy import FaultPolicy
from repro.core import addresses as A
from repro.core.arbiter import ServiceClass
from repro.core.resolver import Strategy
from repro.tenancy import BankManager
from repro.tenancy.slo import SLOClass

SRC_BASE = 0x10_0000_0000
DST_BASE = 0x20_0000_0000
TENANT_STRIDE = 0x1_0000_0000       # 4 GB of VA per tenant
REQUEST_STRIDE = 1 << 20            # 1 MB per request region

#: VA window slots per base: the architecture carries 39-bit virtual
#: addresses (``A.VA_BITS``), so only this many 4 GB tenant windows fit
#: above ``DST_BASE`` — tenants beyond the last slot wrap around and
#: reuse lower windows.  Aliasing across *protection domains* is safe
#: (each pd has its own page table and frames), and pds below the wrap
#: point keep their historical addresses byte-for-byte.  Without the
#: wrap, a faulting tenant with ``pd >= 224`` (va >= 1 TB) overflows
#: the fault FIFO's 28-bit IOVA field (Table 3.1): the driver then
#: resolves a *truncated* VPN forever while the real page stays
#: non-resident — a NACK/RAPF livelock the 1024-node soak tier caught.
VA_SLOTS = ((1 << A.VA_BITS) - DST_BASE) // TENANT_STRIDE       # 96


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's workload over the fabric."""

    pd: int
    name: str = ""
    service_class: Optional[ServiceClass] = None
    strategy: Strategy = Strategy.TOUCH_AHEAD
    arb_weight: int = 1
    max_outstanding_blocks: Optional[int] = None
    # tenant service tier (GOLD / SILVER / BEST_EFFORT): derives the
    # arbiter class/weight and GOLD bank-steal immunity (repro.tenancy)
    slo: Optional[SLOClass] = None
    # arrival process
    mode: str = "closed"            # "closed" (fixed in-flight) | "open"
    inflight: int = 2               # closed-loop concurrency
    arrival_period_us: float = 100.0   # open-loop inter-arrival (uniform
    #                                    jitter of +-50% applied per post)
    n_requests: int = 16
    size_choices: tuple = (4096, 16384, 65536)
    # buffer preparation: FAULTING destinations take the thesis' fault
    # path on every cold page; fresh_dst=True makes EVERY request cold
    src_prep: BufferPrep = BufferPrep.TOUCHED
    dst_prep: BufferPrep = BufferPrep.FAULTING
    fresh_dst: bool = True
    src_node: int = 0
    dst_node: int = 1
    # open the domain only on these nodes (None = every node).  Scoping is
    # what lets a 64-node scale soak run 64+ tenants: SMMU context banks
    # (pd % 16) need only be unique per *node*, not fabric-wide.
    open_on: Optional[tuple] = None
    # cycle requests through this many memory-region slots instead of a
    # region per request (None = per-request regions).  Bounds page-table
    # and frame footprint on million-block soaks; after the first lap
    # every slot is warm, so reused regions stop faulting.
    region_slots: Optional[int] = None

    def label(self) -> str:
        return self.name or f"pd{self.pd}"


@dataclasses.dataclass(frozen=True)
class FaultInjection:
    """Seeded background churn applied while traffic runs.

    * ``khugepaged_period_us`` — every period, one khugepaged pass over a
      random registered region (transiently invalidates its resident,
      unpinned PTEs — §3.1.2.3);
    * ``reclaim_period_us`` — every period, swap out up to
      ``reclaim_pages`` LRU pages of a random domain (major faults on
      next access).

    A period of 0 disables that churn source.

    Crash-fault schedules (machine-failure model) are *deterministic*
    by construction — fixed virtual timestamps rather than sampled
    ones, so a chaos soak is still a pure function of ``(specs, seed)``:

    * ``crashes`` — ``(t_us, node_idx)`` pairs: at ``t_us`` the node
      fail-stops (:meth:`Fabric.crash_node`).  In-flight work toward it
      completes with error statuses, never silently disappears;
    * ``link_flaps`` — ``(t_down_us, t_up_us, u, v)`` tuples: the
      ``u<->v`` link fails at ``t_down_us`` and heals at ``t_up_us``
      (``<= 0`` = stays down), re-pathing routed traffic both ways.
    """

    khugepaged_period_us: float = 0.0
    reclaim_period_us: float = 0.0
    reclaim_pages: int = 8
    # crash-fault schedules: ((t_us, node_idx), ...) and
    # ((t_down_us, t_up_us, u, v), ...)
    crashes: tuple = ()
    link_flaps: tuple = ()


class TenantRun:
    """Drives one TenantSpec through a fabric, all in virtual time."""

    def __init__(self, fabric: Fabric, spec: TenantSpec,
                 rng: random.Random, poll_period_us: float = 200.0,
                 cq_depth: int = 256):
        self.fabric = fabric
        self.spec = spec
        self.rng = rng
        self.poll_period_us = poll_period_us
        self.domain = fabric.open_domain(
            spec.pd,
            policy=FaultPolicy(
                strategy=spec.strategy,
                service_class=spec.service_class,
                arb_weight=spec.arb_weight,
                max_outstanding_blocks=spec.max_outstanding_blocks,
                slo=spec.slo),
            nodes=(list(spec.open_on) if spec.open_on is not None else None))
        self.cq = fabric.create_cq(depth=cq_depth)
        self._mrs: dict[int, tuple] = {}      # request idx -> (src, dst)
        self.regions: list[tuple[int, int, int, int]] = []  # node, pd, vpn, n
        self.posted_ids: list[int] = []
        self.completions: list = []
        self.latencies: list[float] = []
        self.rejected = 0                     # quota/CQ backpressure events
        self.aborted = False                  # posting node crashed mid-run
        self.next_req = 0
        self._pump_scheduled = False

    # ----------------------------------------------------------- lifecycle
    @property
    def done(self) -> bool:
        # a crashed posting node can never reach n_requests; the run is
        # over once everything already posted has drained (with error
        # completions — nothing may hang or leak)
        if self.aborted:
            return self.in_flight == 0
        return len(self.completions) >= self.spec.n_requests

    @property
    def in_flight(self) -> int:
        return len(self.posted_ids) - len(self.completions)

    def start(self) -> None:
        spec = self.spec
        if spec.mode == "closed":
            for _ in range(min(spec.inflight, spec.n_requests)):
                self._try_post()        # rejects retried by the pump
        elif spec.mode == "open":
            t = 0.0
            for _ in range(spec.n_requests):
                jitter = self.rng.uniform(0.5, 1.5)
                t += spec.arrival_period_us * jitter
                self.fabric.loop.schedule(t, self._try_post, True)
        else:
            raise ValueError(f"unknown arrival mode {spec.mode!r}")
        self._schedule_pump()

    # -------------------------------------------------------------- posting
    def _regions_for(self, i: int):
        spec = self.spec
        # with region_slots set, request i reuses slot i % region_slots —
        # the MR pair (and its residency) persists across laps
        key = i if spec.region_slots is None else i % spec.region_slots
        if key in self._mrs:
            return self._mrs[key]
        size = self.rng.choice(spec.size_choices)
        window = (spec.pd % VA_SLOTS) * TENANT_STRIDE
        src_va = SRC_BASE + window + key * REQUEST_STRIDE
        # fresh_dst: a brand-new (cold, faulting) landing region per
        # request; otherwise all requests share one warm region
        slot = key if spec.fresh_dst else 0
        dst_va = DST_BASE + window + slot * REQUEST_STRIDE
        src = self.domain.register_memory(spec.src_node, src_va, size,
                                          prep=spec.src_prep)
        dst = (self._mrs[0][1] if not spec.fresh_dst and self._mrs
               else self.domain.register_memory(spec.dst_node, dst_va,
                                                size, prep=spec.dst_prep))
        self._mrs[key] = (src, dst)
        self.regions.append((spec.src_node, spec.pd, src_va >> 12,
                             A.num_pages(src_va, size)))
        self.regions.append((spec.dst_node, spec.pd, dst_va >> 12,
                             A.num_pages(dst_va, size)))
        return self._mrs[key]

    def _try_post(self, reschedule_on_reject: bool = False) -> None:
        if self.aborted or self.next_req >= self.spec.n_requests:
            return
        i = self.next_req
        src, dst = self._regions_for(i)
        try:
            wr = self.domain.post_write(
                src, dst, cq=self.cq,
                nbytes=min(src.length, dst.length))
        except NodeDown:
            # our posting node fail-stopped: a dead machine posts no new
            # work.  Already-posted WRs still drain (as errors) — the
            # pump keeps polling until in_flight hits zero.
            self.aborted = True
            return
        except WorkQueueFull:
            # quota / CQ backpressure; open-loop arrivals retry
            # themselves, closed-loop posts are retried by the pump
            self.rejected += 1
            if reschedule_on_reject:
                self.fabric.loop.schedule(self.poll_period_us,
                                          self._try_post, True)
            return
        self.next_req += 1
        self.posted_ids.append(wr.wr_id)

    # -------------------------------------------------------------- pumping
    def _schedule_pump(self) -> None:
        if self._pump_scheduled or self.done:
            return
        self._pump_scheduled = True
        self.fabric.loop.schedule(self.poll_period_us, self._pump)

    def _pump(self) -> None:
        self._pump_scheduled = False
        for wc in self.cq.poll(max_entries=self.cq.depth):
            self.completions.append(wc)
            self.latencies.append(wc.latency_us)
        if self.spec.mode == "closed":
            while (not self.done
                   and self.next_req < self.spec.n_requests
                   and self.in_flight < self.spec.inflight):
                before = self.next_req
                self._try_post()
                if self.next_req == before:     # backpressured: retry later
                    break
        self._schedule_pump()

    # ------------------------------------------------------------ reporting
    def stats_dict(self) -> dict:
        """Deterministic, JSON-able per-tenant summary."""
        lat = sorted(self.latencies)

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        agg = {"timeouts": 0, "rapf_retransmits": 0, "retransmissions": 0,
               "src_faults": 0, "dst_faults": 0,
               # NP-RDMA backend (zero for thesis-datapath tenants)
               "mtt_hits": 0, "mtt_misses": 0, "mtt_stale": 0,
               "npr_aborts": 0, "pool_redirect_pages": 0}
        for wc in self.completions:
            for k in agg:
                agg[k] += getattr(wc.stats, k)
        return {
            "tenant": self.spec.label(),
            "pd": self.spec.pd,
            "service_class": (self.spec.service_class.value
                              if self.spec.service_class else "bulk"),
            "posted": len(self.posted_ids),
            "completed": len(self.completions),
            # crash-fault layer: completions that carry an error status
            # (still exactly one completion per posted WR) and whether
            # our posting node fail-stopped mid-run
            "errors": sum(1 for wc in self.completions if not wc.ok),
            "aborted": self.aborted,
            "rejected": self.rejected,
            "latency_mean_us": (round(sum(lat) / len(lat), 6)
                                if lat else 0.0),
            "latency_p50_us": round(pct(0.50), 6),
            "latency_p99_us": round(pct(0.99), 6),
            "latency_max_us": round(lat[-1], 6) if lat else 0.0,
            **agg,
        }


def scale_mix(n_nodes: int,
              total_blocks: int = 1_000_000,
              hot_node: int = 0,
              hot_blocks: int = 2 * A.TR_ID_SPACE + 4096,
              request_bytes: int = 256 * 1024,
              fault_requests: int = 256,
              inflight: int = 4) -> list[TenantSpec]:
    """The scale-soak tenant layout: ``n_nodes`` tenants driving
    ``total_blocks`` 16 KB blocks through the fabric, with ``hot_node``
    concentrated enough to wrap its 14-bit tr_ID space at least twice.

    * one *ring* tenant per node ``k`` (pd ``k``, nodes ``{k, k+1}``):
      closed-loop clean writes over ``region_slots`` reused regions —
      the bulk of the block count, spread across every link;
    * a *hot* clean tenant on ``hot_node`` sized to ``hot_blocks``
      launches (>= 2 wraps plus the ring share), and a *hot faulting*
      tenant (fresh cold destinations, ``fault_requests`` requests) so
      NACK/RAPF/FIFO recovery is exercised before, across and after the
      wrap boundary.

    Bank assignment is delegated to :class:`repro.tenancy.BankManager` —
    the same allocator the SMMU driver uses under overcommit — instead
    of the old hand-rolled ``pd % 16`` juggling.  The layout is
    validated to admit an *eager* (steal-free) binding on every node, so
    the tier's timing baseline stays free of shootdown penalties.
    """
    if n_nodes < 2:
        raise ValueError(f"scale_mix needs >= 2 nodes, got {n_nodes}")
    blocks_per_request = request_bytes // A.BLOCK_SIZE
    specs: list[TenantSpec] = []
    # hot tenants: node hot_node -> hot_node + 8 (several routed hops on
    # a torus).  Ring tenants own pds 0..n_nodes-1; the hot pair simply
    # takes the next two — the BankManager finds them free banks, no
    # modular arithmetic needed.
    hot_pd = n_nodes
    hot_fault_pd = n_nodes + 1
    hot_dst = (hot_node + 8) % n_nodes
    if hot_dst == hot_node:                   # small fabrics: no loopback
        hot_dst = (hot_node + 1) % n_nodes
    fault_blocks = fault_requests * (65536 // A.BLOCK_SIZE)
    hot_clean_requests = max(1, (hot_blocks - fault_blocks)
                             // blocks_per_request)
    specs.append(TenantSpec(
        pd=hot_pd, name="hot-wrap", mode="closed", inflight=inflight,
        n_requests=hot_clean_requests, size_choices=(request_bytes,),
        src_prep=BufferPrep.TOUCHED, dst_prep=BufferPrep.TOUCHED,
        fresh_dst=False, region_slots=4,
        src_node=hot_node, dst_node=hot_dst,
        open_on=(hot_node, hot_dst)))
    specs.append(TenantSpec(
        pd=hot_fault_pd, name="hot-fault", mode="closed", inflight=2,
        n_requests=fault_requests, size_choices=(65536,),
        src_prep=BufferPrep.TOUCHED, dst_prep=BufferPrep.FAULTING,
        fresh_dst=True,
        src_node=hot_node, dst_node=hot_dst,
        open_on=(hot_node, hot_dst)))
    # ring tenants carry the remaining block budget evenly (rounded UP:
    # the tier's contract is ">= total_blocks", never a few short)
    ring_blocks = max(0, total_blocks - hot_blocks)
    ring_requests = -(-ring_blocks // (n_nodes * blocks_per_request))
    for k in range(n_nodes):
        if ring_requests <= 0:
            break
        specs.append(TenantSpec(
            pd=k, name=f"ring{k}", mode="closed", inflight=inflight,
            n_requests=ring_requests, size_choices=(request_bytes,),
            src_prep=BufferPrep.TOUCHED, dst_prep=BufferPrep.TOUCHED,
            fresh_dst=False, region_slots=4,
            src_node=k, dst_node=(k + 1) % n_nodes,
            open_on=(k, (k + 1) % n_nodes)))
    # prove the layout admits an eager, steal-free binding: run every
    # node's tenant set through a scratch BankManager (the allocator the
    # SMMU driver itself uses) — register() rejects duplicate pds and
    # try_bind() returns None once a node's 16 banks are exhausted
    managers: dict[int, BankManager] = {}
    for s in specs:
        for node in dict.fromkeys(s.open_on):
            mgr = managers.setdefault(node, BankManager())
            mgr.register(s.pd)
            if mgr.try_bind(s.pd) is None:
                raise ValueError(
                    f"scale_mix overcommits node {node}: pd {s.pd} is "
                    f"tenant #{mgr.bound_count() + 1} but the SMMU has "
                    f"only {mgr.capacity} context banks — the tier's "
                    f"steal-free baseline would not hold")
    return specs


def schedule_injection(fabric: Fabric, runs: list[TenantRun],
                       inj: FaultInjection, rng: random.Random) -> None:
    """Install the churn schedule as self-rescheduling loop events."""

    def all_done() -> bool:
        return all(r.done for r in runs)

    def regions():
        out = []
        for r in runs:
            out.extend(r.regions)
        return out

    def khugepaged_tick() -> None:
        if all_done():
            return
        regs = regions()
        if regs:
            node_idx, pd, vpn, n = rng.choice(regs)
            pt = fabric.nodes[node_idx].page_tables.get(pd)
            if pt is not None:
                pt.khugepaged_collapse(vpn + rng.randrange(max(1, n)))
        fabric.loop.schedule(inj.khugepaged_period_us, khugepaged_tick)

    def reclaim_tick() -> None:
        if all_done():
            return
        regs = regions()
        if regs:
            node_idx, pd, _, _ = rng.choice(regs)
            pt = fabric.nodes[node_idx].page_tables.get(pd)
            if pt is not None:
                pt.reclaim(inj.reclaim_pages)
        fabric.loop.schedule(inj.reclaim_period_us, reclaim_tick)

    if inj.khugepaged_period_us > 0:
        fabric.loop.schedule(inj.khugepaged_period_us, khugepaged_tick)
    if inj.reclaim_period_us > 0:
        fabric.loop.schedule(inj.reclaim_period_us, reclaim_tick)

    # crash-fault schedules: fixed timestamps, so the chaos run stays a
    # pure function of (specs, seed) — the rng never touches these
    for t_us, node_idx in inj.crashes:
        fabric.loop.schedule(t_us, fabric.crash_node, node_idx)
    for t_down, t_up, u, v in inj.link_flaps:
        fabric.loop.schedule(t_down, fabric.fail_link, u, v)
        if t_up > 0:
            fabric.loop.schedule(t_up, fabric.restore_link, u, v)
