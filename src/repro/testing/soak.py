"""``soak(seed, ...)`` — the deterministic stress/soak entry point.

Builds a fabric, runs a seeded multi-tenant traffic mix (with optional
fault-injection churn) to completion, runs every invariant checker, and
returns a :class:`SoakResult` whose ``stats`` dict is a pure function of
the arguments: same seed -> byte-identical ``json()``, different seed ->
different traffic.  Used by ``tests/test_stress.py`` and
``benchmarks/arbiter_qos.py``.
"""

from __future__ import annotations

import dataclasses
import gc
import json
import random
from typing import Optional, Sequence

from repro.api.config import FabricConfig
from repro.api.fabric import Fabric
from repro.api.memory import BufferPrep
from repro.core.arbiter import ArbiterStats, ServiceClass
from repro.lint.race import RaceCheckLoop
from repro.testing.invariants import (check_arbiter_consistency,
                                      check_bank_conservation,
                                      check_completion_conservation,
                                      check_crash_consistency,
                                      check_link_conservation,
                                      check_npr_consistency,
                                      check_pinned_resident,
                                      check_stats_accounting,
                                      check_tenant_isolation,
                                      check_tr_id_lifecycle)
from repro.testing.traffic import (FaultInjection, TenantRun, TenantSpec,
                                   schedule_injection)

#: hard ceiling on loop events per soak — a run that trips it is reported
#: as a liveness violation instead of hanging the test suite
MAX_SOAK_EVENTS = 5_000_000

#: events stepped between completion checks: testing every tenant's done
#: flag per event made the driver loop O(tenants x events) — at million-
#: block scale the *harness* dominated the simulation.  Overshooting a
#: chunk is harmless: the post-loop drain runs the same tail events the
#: chunk would have, so final stats are identical.
CHECK_INTERVAL = 2048


def default_tenants() -> list[TenantSpec]:
    """A small adversarial mix: one clean LATENCY serving tenant, one
    fault-storming BULK tenant, one pinned open-loop BULK tenant."""
    return [
        TenantSpec(pd=1, name="serving", service_class=ServiceClass.LATENCY,
                   mode="closed", inflight=2, n_requests=12,
                   size_choices=(4096, 16384), dst_prep=BufferPrep.TOUCHED),
        TenantSpec(pd=2, name="bulk-storm", service_class=ServiceClass.BULK,
                   mode="closed", inflight=4, n_requests=10,
                   size_choices=(65536,), dst_prep=BufferPrep.FAULTING,
                   fresh_dst=True, max_outstanding_blocks=8),
        TenantSpec(pd=3, name="pinned-open", service_class=ServiceClass.BULK,
                   mode="open", arrival_period_us=400.0, n_requests=8,
                   size_choices=(16384,), src_prep=BufferPrep.PINNED,
                   dst_prep=BufferPrep.FAULTING),
    ]


@dataclasses.dataclass
class SoakResult:
    stats: dict                      # deterministic, JSON-able
    violations: list[str]
    runs: list[TenantRun]            # live objects for further inspection
    fabric: Fabric

    @property
    def ok(self) -> bool:
        return not self.violations

    def json(self) -> str:
        """Canonical byte form of the stats (the determinism contract)."""
        return json.dumps(self.stats, sort_keys=True)


def soak(seed: int,
         tenants: Optional[Sequence[TenantSpec]] = None,
         config: Optional[FabricConfig] = None,
         injection: Optional[FaultInjection] = None,
         poll_period_us: float = 200.0,
         max_events: int = MAX_SOAK_EVENTS,
         n_nodes: Optional[int] = None,
         max_duration_us: Optional[float] = None) -> SoakResult:
    """Run one seeded soak to completion and check every invariant.

    ``n_nodes`` is a convenience knob for the scale tiers: it builds a
    default :class:`FabricConfig` of that size (mutually exclusive with
    ``config``).  ``max_duration_us`` bounds *virtual* time the way
    ``max_events`` bounds work — exceeding either is reported as a
    liveness violation rather than hanging the harness.
    """
    if n_nodes is not None and config is not None:
        raise ValueError("pass either config= or n_nodes=, not both")
    # Pause the cyclic collector for the duration of the run: the object
    # graph is dominated by *live* Transfer<->Block cycles, so generational
    # passes walk millions of reachable objects over and over and collect
    # nothing until the fabric is torn down — worth ~15% of wall time at
    # the million-block tier.  Purely host-side: virtual results and the
    # byte-identical stats contract are unaffected.
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        return _soak_body(seed, tenants, config, injection, poll_period_us,
                          max_events, n_nodes, max_duration_us)
    finally:
        if gc_was_enabled:
            gc.enable()


def _soak_body(seed, tenants, config, injection, poll_period_us,
               max_events, n_nodes, max_duration_us) -> SoakResult:
    rng = random.Random(seed)
    fabric = Fabric.build(config or FabricConfig(n_nodes=n_nodes or 2))
    specs = list(tenants) if tenants is not None else default_tenants()
    runs = [TenantRun(fabric, spec, rng, poll_period_us=poll_period_us)
            for spec in specs]
    for r in runs:
        r.start()
    if injection is not None:
        schedule_injection(fabric, runs, injection, rng)

    violations: list[str] = []
    loop = fabric.loop
    start_events = loop.events_processed
    while not all(r.done for r in runs):
        if loop.peek_time() is None:
            violations.append(
                "event loop drained before all tenants completed: "
                + ", ".join(f"{r.spec.label()} {len(r.completions)}/"
                            f"{r.spec.n_requests}"
                            for r in runs if not r.done))
            break
        # run a chunk of events between done-checks (harness overhead
        # stays O(chunks), not O(tenants x events) — and the per-event
        # dispatch stays inside the kernel's tight run_batch loop)
        loop.run_batch(CHECK_INTERVAL)
        if loop.events_processed - start_events > max_events:
            violations.append(
                f"soak exceeded {max_events} events without completing "
                f"— livelock or starvation")
            break
        if max_duration_us is not None and fabric.now > max_duration_us:
            violations.append(
                f"soak exceeded {max_duration_us} us of virtual time "
                f"without completing — livelock or starvation")
            break
    if all(r.done for r in runs):
        # drain the tail (stops once the pumps see every tenant done);
        # on the violation paths above the pumps of unfinished tenants
        # would reschedule forever, so the loop is left as-is there
        fabric.progress()

    # ---- invariants -----------------------------------------------------
    for r in runs:
        violations += check_completion_conservation(
            r.posted_ids, [wc.wr_id for wc in r.completions],
            label=r.spec.label())
    violations += check_pinned_resident(fabric)
    violations += check_crash_consistency(fabric)
    violations += check_arbiter_consistency(fabric)
    violations += check_link_conservation(fabric)
    violations += check_tr_id_lifecycle(fabric)
    violations += check_npr_consistency(fabric)
    violations += check_bank_conservation(fabric)
    violations += check_tenant_isolation(fabric)
    violations += check_stats_accounting(fabric)
    if isinstance(loop, RaceCheckLoop):
        loop.flush()                 # close the final same-time group
        violations += loop.reports

    # ---- deterministic report -------------------------------------------
    stats = {
        "seed": seed,
        "tenants": [r.stats_dict() for r in runs],
        "arbiter": _arbiter_dict(fabric),
        "net": fabric.net_stats().as_dict(),
        "r5": {f"node{nid}": s.tr_id.as_dict()
               for nid, s in sorted(fabric.protocol_stats().items())
               if s.tr_id.allocated},
        "npr": {f"node{nid}": s.npr.as_dict()
                for nid, s in sorted(fabric.protocol_stats().items())
                if s.npr.active},
        "tenancy": {f"node{nid}": s.tenancy.as_dict()
                    for nid, s in sorted(fabric.protocol_stats().items())
                    if s.tenancy.tenants or s.tenancy.bank_stats.binds},
        "makespan_us": round(fabric.now, 6),
        "events": fabric.loop.events_processed,
        "violations": sorted(violations),
    }
    return SoakResult(stats=stats, violations=violations, runs=runs,
                      fabric=fabric)


def _arbiter_dict(fabric: Fabric) -> dict:
    out = {}
    for node in fabric.nodes:
        arb = node.arbiter
        node_key = f"node{node.node_id}"
        out[node_key] = {"total": _stats_fields(arb.stats)}
        for pd in sorted(arb.domain_stats):
            out[node_key][f"pd{pd}"] = _stats_fields(arb.domain_stats[pd])
    return out


def _stats_fields(s: ArbiterStats) -> dict:
    return {f: getattr(s, f)
            for f in (*ArbiterStats.ADDITIVE, "max_queue_depth")}
