"""Per-tenant fault-handling policy (the verbs API's "QoS knob").

The seed engine wired ONE global :class:`~repro.core.resolver.Resolver`
into every node, so all tenants of a fabric shared one fault-resolution
strategy.  A :class:`FaultPolicy` is the declarative replacement: it names
a strategy, its lookahead, and the domain's pinnable-memory budget, and is
attached *per protection domain* (or per node, or fabric-wide as the
default) when the fabric is built.  ``Node.resolver_for(pd)`` selects the
right resolver at fault-handling time.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.errors import ConfigError
from repro.core.addresses import PAGES_PER_BLOCK
from repro.core.arbiter import ServiceClass
from repro.core.costmodel import CostModel
from repro.core.resolver import Resolver, Strategy, coerce_strategy
from repro.tenancy.slo import SLOClass, coerce_slo


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """How one protection domain's page faults are resolved — and how its
    DMA traffic is scheduled while they are being resolved.

    * ``strategy`` — the fault-handling datapath: a thesis resolution
      strategy (Touch-A-Page, Touch-Ahead, ...) or ``NP_RDMA`` (the
      ``repro.npr`` no-pinning backend); see
      :class:`~repro.core.resolver.Strategy`.  A member, its name or its
      value is accepted; anything else raises ``ValueError`` naming the
      valid members.
    * ``lookahead`` — pages paged in per fault event for the
      ``TOUCH_AHEAD_N`` / ``STREAM`` strategies.
    * ``pin_limit_bytes`` — the domain's pinnable-memory budget M (the
      Firehose constraint); ``None`` = unlimited.
    * ``service_class`` — DMA-arbiter class of the domain's blocks:
      ``LATENCY`` (strict priority; serving-style small WRs) or ``BULK``
      (DRR bandwidth share; training/offload streams).  ``None`` means
      unspecified and schedules as BULK.
    * ``arb_weight`` — the domain's deficit-round-robin weight within its
      class ring (relative bandwidth share).
    * ``max_outstanding_blocks`` — per-node cap on the domain's launched,
      not-yet-completed blocks; the posting verbs raise
      :class:`~repro.api.completion.DomainQuotaExceeded` beyond it.
      ``None`` = no quota.
    * ``max_retries`` — retry budget for the R5 retransmission timer:
      a block may be retransmitted at most this many times before its
      transfer completes with
      :attr:`~repro.api.completion.WCStatus.RETRY_EXC_ERR`.  ``None``
      (the default) keeps the seed's unbounded retransmission — the
      thesis' 1 ms timer spins until the fault resolves.
    * ``retry_backoff`` — exponential-backoff multiplier applied to the
      R5 timeout per consecutive retransmission of the same block
      (``timeout_us * retry_backoff**retries``, capped).  ``1.0`` (the
      default) keeps the thesis' flat 1 ms timer bit-exact.
    * ``slo`` — the tenant's service tier
      (:class:`~repro.tenancy.SLOClass`: GOLD / SILVER / BEST_EFFORT, a
      member, name or value).  Setting it derives ``service_class`` and
      ``arb_weight`` when those are left at their defaults (GOLD →
      LATENCY weight 4, SILVER → BULK weight 2, BEST_EFFORT → BULK
      weight 1) and makes GOLD domains' SMMU context banks steal-immune
      under bank overcommit.  Explicit ``service_class``/``arb_weight``
      values always win over the derivation.
    """

    strategy: Strategy = Strategy.TOUCH_AHEAD
    lookahead: int = PAGES_PER_BLOCK
    pin_limit_bytes: Optional[int] = None
    service_class: Optional[ServiceClass] = None
    arb_weight: int = 1
    max_outstanding_blocks: Optional[int] = None
    max_retries: Optional[int] = None
    retry_backoff: float = 1.0
    slo: Optional[SLOClass] = None

    def __post_init__(self) -> None:
        # strict: an unknown strategy spelling used to slip through here
        # and surface later as an opaque error deep in resolver dispatch
        object.__setattr__(self, "strategy", coerce_strategy(self.strategy))
        object.__setattr__(self, "slo", coerce_slo(self.slo))
        if self.max_retries is not None and self.max_retries < 0:
            raise ConfigError(
                f"max_retries must be >= 0 (or None = unbounded), got "
                f"{self.max_retries}")
        if self.retry_backoff < 1.0:
            raise ConfigError(
                f"retry_backoff must be >= 1.0 (1.0 = the thesis' flat "
                f"timer), got {self.retry_backoff}")
        if self.slo is not None:
            # the SLO tier implies arbiter parameters unless the caller
            # pinned them explicitly (defaults: None / 1)
            if self.service_class is None:
                object.__setattr__(self, "service_class",
                                   self.slo.service_class)
            if self.arb_weight == 1:
                object.__setattr__(self, "arb_weight", self.slo.arb_weight)

    def make_resolver(self, cost: CostModel) -> Resolver:
        """Instantiate the resolver this policy describes."""
        return Resolver(strategy=self.strategy, cost=cost,
                        lookahead=self.lookahead)


DEFAULT_POLICY = FaultPolicy()
