"""Memory registration: ``MemoryRegion`` handles with owned prep state.

The seed engine passed raw ``(pd, va, nbytes)`` triples around and made
callers track preparation state and prep cost themselves.  Here
``ProtectionDomain.register_memory()`` returns a :class:`MemoryRegion`
that owns both: how the buffer was prepared (faulting / touched / pinned
— the thesis' three comparisons) and the user-side microseconds that
preparation cost (mmap + touch/pin now, unpin + munmap at deregister).

Unlike real verbs, registration does **not** pin by default — that is the
paper's whole point: ``BufferPrep.FAULTING`` regions are valid RDMA
targets whose pages fault in on first access.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, Optional

from repro.core.addresses import pages_spanned

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.api.fabric import ProtectionDomain


class BufferPrep(enum.Enum):
    """How a buffer is prepared before the RDMA (the thesis' comparisons)."""
    FAULTING = "faulting"        # mmap'ed only: every page faults on access
    TOUCHED = "touched"          # pre-touched: resident, unpinned
    PINNED = "pinned"            # pinned (and therefore resident)


@dataclasses.dataclass
class PrepCost:
    """User-side microseconds spent preparing / releasing one buffer."""
    mmap_us: float = 0.0
    prep_us: float = 0.0         # touch or pin
    release_us: float = 0.0      # unpin (pin case)
    munmap_us: float = 0.0

    @property
    def total_us(self) -> float:
        return self.mmap_us + self.prep_us + self.release_us + self.munmap_us


class RegionError(RuntimeError):
    """Operation on a deregistered (or otherwise invalid) memory region."""


class MemoryRegion:
    """A registered buffer on one node of one protection domain.

    Carries the verbs-style remote key (``rkey``) plus the prep state and
    cost accounting the thesis measures.  Work requests reference regions,
    not raw addresses — ``post_write(src=mr_a, dst=mr_b)``.
    """

    __slots__ = ("domain", "node_id", "addr", "length", "prep", "prep_cost",
                 "rkey", "registered")

    def __init__(self, domain: "ProtectionDomain", node_id: int, addr: int,
                 length: int, prep: BufferPrep, prep_cost: PrepCost,
                 rkey: int):
        self.domain = domain
        self.node_id = node_id
        self.addr = addr
        self.length = length
        self.prep = prep
        self.prep_cost = prep_cost
        self.rkey = rkey
        self.registered = True

    # ------------------------------------------------------------- queries
    @property
    def pd(self) -> int:
        return self.domain.pd

    @property
    def pages(self) -> list[int]:
        """Virtual page numbers spanned by the region."""
        return pages_spanned(self.addr, self.length)

    def resident_pages(self) -> int:
        pt = self.domain.fabric.nodes[self.node_id].pt(self.pd)
        return sum(1 for vpn in self.pages if pt.is_resident(vpn))

    def contains(self, va: int, nbytes: int) -> bool:
        return self.addr <= va and va + nbytes <= self.addr + self.length

    # ------------------------------------------------------------ teardown
    def deregister(self) -> PrepCost:
        """munmap the region; completes the prep-cost accounting."""
        if not self.registered:
            raise RegionError(f"region rkey={self.rkey} already deregistered")
        fabric = self.domain.fabric
        node = fabric.nodes[self.node_id]
        node.pt(self.pd).munmap(self.addr, self.length)
        self.prep_cost.munmap_us = fabric.cost.munmap_us(self.length)
        self.registered = False
        return self.prep_cost

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"MemoryRegion(pd={self.pd}, node={self.node_id}, "
                f"addr={self.addr:#x}, len={self.length}, "
                f"prep={self.prep.value}, rkey={self.rkey})")
