"""Declarative fabric configuration (replaces the ``RDMAEngine`` kwargs blob).

A :class:`FabricConfig` fully describes a simulated ExaNeSt fabric:
topology (nodes, hops), hardware behaviour (HUPCF, fault model, frame
pool), the calibrated cost model, and fault-handling policy at three
scopes — fabric-wide default, per node, and (via
:meth:`~repro.api.fabric.Fabric.open_domain`) per protection domain.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.addresses import BLOCK_SIZE
from repro.core.arbiter import DEFAULT_PLDMA_SLOTS
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.fault import FaultModel
from repro.api.policy import FaultPolicy


@dataclasses.dataclass
class FabricConfig:
    """Everything needed to build a :class:`~repro.api.fabric.Fabric`.

    * ``n_nodes`` / ``hops`` — topology: full-duplex links between every
      pair of nodes, ``hops`` network hops apart (loopback is one hop).
    * ``cost`` — the calibrated :class:`~repro.core.costmodel.CostModel`
      (``None`` = thesis defaults).
    * ``hupcf`` — SMMU Hit-Under-Previous-Context-Fault: translate
      resident pages while a fault is outstanding (§3.2.1).
    * ``fault_model`` — TERMINATE (the prototype) or STALL.
    * ``frames_per_node`` — physical frame pool per node.
    * ``default_policy`` — fabric-wide fault policy; per-node overrides in
      ``node_policies`` (node index -> policy); per-domain overrides are
      given to ``Fabric.open_domain``.
    * ``pldma_slots`` — PLDMA occupancy per node: blocks streaming (or
      awaiting their ACK) at once, shared by ALL tenants and arbitrated by
      the fault-aware :class:`~repro.core.arbiter.DMAArbiter` (default 2,
      the hardware's outstanding-block window).
    * ``arb_quantum_bytes`` — deficit-round-robin quantum of that arbiter
      (default one 16 KB block).
    """

    n_nodes: int = 2
    hops: int = 1
    cost: Optional[CostModel] = None
    hupcf: bool = True
    fault_model: FaultModel = FaultModel.TERMINATE
    frames_per_node: int = 1 << 20
    default_policy: FaultPolicy = dataclasses.field(default_factory=FaultPolicy)
    node_policies: dict = dataclasses.field(default_factory=dict)
    pldma_slots: int = DEFAULT_PLDMA_SLOTS
    arb_quantum_bytes: int = BLOCK_SIZE

    def __post_init__(self) -> None:
        if self.n_nodes < 1:
            raise ValueError(f"n_nodes must be >= 1, got {self.n_nodes}")
        if self.pldma_slots < 1:
            raise ValueError(
                f"pldma_slots must be >= 1, got {self.pldma_slots}")
        if self.cost is None:
            self.cost = DEFAULT_COST_MODEL

    def policy_for_node(self, node_idx: int) -> FaultPolicy:
        return self.node_policies.get(node_idx, self.default_policy)
