"""Declarative fabric configuration (replaces the ``RDMAEngine`` kwargs blob).

A :class:`FabricConfig` fully describes a simulated ExaNeSt fabric:
interconnect topology (nodes, :class:`~repro.net.topology.TopologyKind`,
dims), hardware behaviour (HUPCF, fault model, frame pool), the
calibrated cost model, and fault-handling policy at three scopes —
fabric-wide default, per node, and (via
:meth:`~repro.api.fabric.Fabric.open_domain`) per protection domain.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.errors import ConfigError
from repro.core.addresses import BLOCK_SIZE, PAGES_PER_BLOCK, TR_ID_SPACE
from repro.core.arbiter import DEFAULT_PLDMA_SLOTS
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.fault import FaultModel
from repro.net.topology import TopologyKind, coerce_kind
from repro.api.policy import FaultPolicy


@dataclasses.dataclass
class FabricConfig:
    """Everything needed to build a :class:`~repro.api.fabric.Fabric`.

    * ``n_nodes`` / ``topology`` / ``dims`` — the interconnect: a
      :class:`~repro.net.topology.TopologyKind` (or its string name:
      ``"all_to_all"``, ``"ring"``, ``"mesh_2d"``, ``"torus_2d"``,
      ``"dragonfly"``) plus its dimensions (rows × cols for grids,
      n_groups × group_size for dragonfly).  Routed topologies share
      physical links: traffic between different node pairs contends for
      wire time on every common hop of its deterministic dimension-order
      route (:mod:`repro.net`).
    * ``hops`` — **back-compat alias for ALL_TO_ALL only**: the seed's
      flat distance scalar, scaling every dedicated direct link to
      ``hops`` network hops (loopback stays one hop).  Rejected on
      routed topologies, where distance comes from the route.
    * ``link_qos`` — extend the DMA arbiter's service classes to the
      wire: LATENCY-class packets overtake BULK backlogs on congested
      links.  ``None`` (default) = on for routed topologies, off for
      ALL_TO_ALL (preserving the seed's dedicated-link timing exactly).
    * ``cost`` — the calibrated :class:`~repro.core.costmodel.CostModel`
      (``None`` = thesis defaults).
    * ``hupcf`` — SMMU Hit-Under-Previous-Context-Fault: translate
      resident pages while a fault is outstanding (§3.2.1).
    * ``fault_model`` — TERMINATE (the prototype) or STALL.
    * ``frames_per_node`` — physical frame pool per node.
    * ``default_policy`` — fabric-wide fault policy; per-node overrides in
      ``node_policies`` (node index -> policy); per-domain overrides are
      given to ``Fabric.open_domain``.
    * ``pldma_slots`` — PLDMA occupancy per node: blocks streaming (or
      awaiting their ACK) at once, shared by ALL tenants and arbitrated by
      the fault-aware :class:`~repro.core.arbiter.DMAArbiter` (default 2,
      the hardware's outstanding-block window).
    * ``arb_quantum_bytes`` — deficit-round-robin quantum of that arbiter
      (default one 16 KB block).
    * ``tr_id_space`` — size of each node's transaction-ID pool (default
      ``None`` = the hardware's full 2^14, Table 3.2).  A *host-side*
      scale-model knob: shrinking it makes ID exhaustion and recycling
      reachable in seconds for tests, while the wire encoding stays
      bit-exact (every allocated ID still fits the 14-bit field).
    * ``mtt_entries`` / ``dma_pool_frames`` / ``speculation`` — the
      NP-RDMA backend (``repro.npr``, selected per domain via
      ``FaultPolicy(strategy=Strategy.NP_RDMA)``): memory-translation-
      table capacity, pre-registered DMA-able pool frames per node, and
      whether transfers launch speculatively on cached translations
      (``False`` = bounce-buffer mode: every block lands in the pool).
    * ``bank_overcommit`` / ``srq_entries`` / ``srq_gold_reserve`` /
      ``tenants_per_node`` — the tenancy control plane
      (``repro.tenancy``): virtualize the 16 SMMU context banks with
      LRU bank stealing (``False`` restores the seed's hard
      ``BankCollision`` ceiling), bound the per-node shared receive
      queue (``None`` = unbounded; ``srq_gold_reserve`` entries usable
      only by GOLD tenants), and cap tenants admitted per node
      (``Fabric.open_domain`` raises ``TenantQuotaExceeded`` beyond it).
    * ``crash_detect_retries`` — consecutive R5 timeout rounds against a
      dead/unreachable peer before the transfer is declared failed with
      ``WCStatus.REMOTE_OP_ERR`` (crash *detection* is distinct from the
      page-fault retry budget ``FaultPolicy.max_retries``: a live peer
      that keeps faulting exhausts the budget; a dead peer trips this).
    * ``lease_timeout_us`` — tr_id lease on a crashed node: transaction
      IDs orphaned by ``Node.crash()`` (blocks that were in flight *from*
      the dead node) are reclaimed into the free list this long after the
      crash, preserving the PR-5 free-list/generation invariants without
      ever aliasing an ID a late wire packet could still name.
    * ``race_check`` — run the event loop under the same-timestamp race
      sanitizer (:class:`repro.lint.race.RaceCheckLoop`): events firing
      at one virtual timestamp with overlapping read/write footprints
      are reported (their tie order is load-bearing).  Observation only
      — stats stay byte-identical.  Also enabled by the
      ``REPRO_RACE_CHECK`` environment variable.
    * ``shards`` — partition the fabric's nodes into this many per-shard
      event wheels merged under conservative lookahead (= the minimum
      routed link latency); see :mod:`repro.core.shards`.  ``1``
      (default) = the single global wheel.  Results are byte-identical
      either way; sharding bounds per-queue size on 1000+-node fabrics
      and is the scaffold for parallel execution.  Mutually exclusive
      with ``race_check`` (the sanitizer wraps the single-queue loop).
    """

    n_nodes: int = 2
    hops: int = 1
    topology: Union[TopologyKind, str] = TopologyKind.ALL_TO_ALL
    dims: Optional[tuple] = None
    link_qos: Optional[bool] = None
    cost: Optional[CostModel] = None
    hupcf: bool = True
    fault_model: FaultModel = FaultModel.TERMINATE
    frames_per_node: int = 1 << 20
    default_policy: FaultPolicy = dataclasses.field(default_factory=FaultPolicy)
    node_policies: dict = dataclasses.field(default_factory=dict)
    pldma_slots: int = DEFAULT_PLDMA_SLOTS
    arb_quantum_bytes: int = BLOCK_SIZE
    tr_id_space: Optional[int] = None
    mtt_entries: int = 4096
    dma_pool_frames: int = 64
    speculation: bool = True
    bank_overcommit: bool = True
    srq_entries: Optional[int] = None
    srq_gold_reserve: int = 0
    tenants_per_node: Optional[int] = None
    crash_detect_retries: int = 3
    lease_timeout_us: float = 10_000.0
    race_check: bool = False
    shards: int = 1

    def __post_init__(self) -> None:
        if self.n_nodes < 1:
            raise ConfigError(f"n_nodes must be >= 1, got {self.n_nodes}")
        if self.shards < 1:
            raise ConfigError(f"shards must be >= 1, got {self.shards}")
        if self.shards > self.n_nodes:
            raise ConfigError(
                f"shards={self.shards} exceeds n_nodes={self.n_nodes}: "
                f"every shard must own at least one node")
        if self.shards > 1 and self.race_check:
            raise ConfigError(
                "shards > 1 is mutually exclusive with race_check: the "
                "race sanitizer wraps the single-queue event loop")
        if self.pldma_slots < 1:
            raise ConfigError(
                f"pldma_slots must be >= 1, got {self.pldma_slots}")
        if self.tr_id_space is not None \
                and not 1 <= self.tr_id_space <= TR_ID_SPACE:
            raise ConfigError(
                f"tr_id_space must be in [1, {TR_ID_SPACE}] (the 14-bit "
                f"tr_ID wire field), got {self.tr_id_space}")
        if self.mtt_entries < 1:
            raise ConfigError(
                f"mtt_entries must be >= 1, got {self.mtt_entries}")
        if self.dma_pool_frames < PAGES_PER_BLOCK:
            raise ConfigError(
                f"dma_pool_frames must be >= {PAGES_PER_BLOCK} (one 16 KB "
                f"block of 4 KB pages, or a redirected block could never "
                f"reserve its landing frames), got {self.dma_pool_frames}")
        if self.srq_entries is not None and self.srq_entries < 1:
            raise ConfigError(
                f"srq_entries must be >= 1 (or None = unbounded), got "
                f"{self.srq_entries}")
        if self.srq_gold_reserve < 0:
            raise ConfigError(
                f"srq_gold_reserve must be >= 0, got "
                f"{self.srq_gold_reserve}")
        if (self.srq_entries is not None
                and self.srq_gold_reserve > self.srq_entries):
            raise ConfigError(
                f"srq_gold_reserve={self.srq_gold_reserve} exceeds "
                f"srq_entries={self.srq_entries}")
        if self.tenants_per_node is not None and self.tenants_per_node < 1:
            raise ConfigError(
                f"tenants_per_node must be >= 1 (or None = unbounded), "
                f"got {self.tenants_per_node}")
        if self.crash_detect_retries < 1:
            raise ConfigError(
                f"crash_detect_retries must be >= 1, got "
                f"{self.crash_detect_retries}")
        if self.lease_timeout_us <= 0:
            raise ConfigError(
                f"lease_timeout_us must be > 0, got {self.lease_timeout_us}")
        self.topology = coerce_kind(self.topology)
        if self.hops < 1:
            raise ConfigError(f"hops must be >= 1, got {self.hops}")
        if self.hops != 1 and self.topology is not TopologyKind.ALL_TO_ALL:
            raise ConfigError(
                f"hops={self.hops} is the ALL_TO_ALL back-compat alias; "
                f"on topology={self.topology.value} distance comes from "
                f"the routed hop path — drop hops= or choose dims")
        if self.dims is not None:
            self.dims = tuple(self.dims)
        if self.cost is None:
            self.cost = DEFAULT_COST_MODEL

    def policy_for_node(self, node_idx: int) -> FaultPolicy:
        return self.node_policies.get(node_idx, self.default_policy)
