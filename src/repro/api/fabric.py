"""The fabric builder and protection-domain verbs.

``Fabric.build(FabricConfig(...))`` replaces the 9-kwarg ``RDMAEngine``
constructor: it instantiates the event loop, the nodes (A53s + SMMU +
fault FIFO + R5 + PLDMA), and full-duplex links between every pair, then
hands out :class:`ProtectionDomain` handles.  Each domain carries its own
:class:`~repro.api.policy.FaultPolicy`, so two tenants of one fabric can
resolve faults with different strategies — the multi-tenant scenario the
single global resolver of the seed engine could not express.

Data-path verbs live on the domain: ``register_memory`` returns
:class:`~repro.api.memory.MemoryRegion` handles; ``post_write`` /
``post_read`` are asynchronous and deliver completions to a
:class:`~repro.api.completion.CompletionQueue`.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

from repro.core import addresses as A
from repro.core.arbiter import ArbiterStats, ServiceClass
from repro.core.node import (BankCollision, DomainClosed, DomainExists,
                             FabricError, Node, NodeDown, Transfer, TrIdStats)
from repro.core.pagetable import FrameAllocator
from repro.core.simulator import EventLoop, make_event_loop
from repro.errors import ConfigError
from repro.npr.stats import NPRStats
from repro.net.interconnect import FabricStats, Interconnect
from repro.net.router import NetworkPartitioned
from repro.tenancy import SLOClass, TenancyManager, coerce_slo
from repro.api.completion import (MAX_WAIT_EVENTS, CompletionQueue,
                                  DomainQuotaExceeded, TenantQuotaExceeded,
                                  TrIdExhausted, WCStatus, WorkCompletion,
                                  WorkQueueFull, WorkRequest, WROpcode,
                                  _advance_until)
from repro.api.config import FabricConfig
from repro.api.memory import BufferPrep, MemoryRegion, PrepCost, RegionError
from repro.api.policy import FaultPolicy


@dataclasses.dataclass
class ProtocolStats:
    """One node's protocol telemetry, all datapaths side by side:
    the 14-bit tr_ID lifecycle (:class:`~repro.core.node.TrIdStats`),
    the NP-RDMA backend (:class:`~repro.npr.stats.NPRStats`) and the
    tenancy control plane (:class:`~repro.tenancy.TenancyManager` —
    ``.tenancy.bank_stats`` is the node's
    :class:`~repro.tenancy.BankStats`)."""

    tr_id: TrIdStats
    npr: NPRStats
    tenancy: TenancyManager

    def as_dict(self) -> dict:
        return {"tr_id": self.tr_id.as_dict(), "npr": self.npr.as_dict(),
                "tenancy": self.tenancy.as_dict()}


class ProtectionDomain:
    """One tenant: a PDID spanning its nodes, with its own fault policy."""

    def __init__(self, fabric: "Fabric", pd: int, policy: FaultPolicy,
                 node_policies: Optional[dict] = None,
                 slo: Optional[SLOClass] = None):
        self.fabric = fabric
        self.pd = pd
        self.policy = policy
        # tenant SLO tier (repro.tenancy): GOLD rides the SRQ gold
        # reserve and its context banks are steal-immune
        self.slo = slo
        # default arbiter class of this domain's work requests (None ->
        # the class each node registered for the pd); consulted by the
        # posting verbs, so reassigning it retargets subsequent posts
        self.service_class: Optional[ServiceClass] = policy.service_class
        # node index -> the policy actually governing this domain there
        # (per-node FabricConfig overrides when no domain policy was given)
        self._node_policies = node_policies or {}
        # lifecycle: Fabric.close_domain flips this and every posting
        # verb / registration afterwards raises DomainClosed
        self.closed = False
        # regions handed out, so close_domain can deregister them
        self._regions: list[MemoryRegion] = []

    def policy_for(self, node_idx: int) -> FaultPolicy:
        """The effective fault policy of this domain on ``node_idx``."""
        return self._node_policies.get(node_idx, self.policy)

    @property
    def nodes(self) -> list[int]:
        """Node indices this domain is open on."""
        return sorted(self._node_policies)

    # ------------------------------------------------------------- memory
    def register_memory(self, node_idx: int, va: int, nbytes: int,
                        prep: BufferPrep = BufferPrep.FAULTING,
                        charge: bool = True) -> MemoryRegion:
        """mmap (+ touch/pin per ``prep``) a buffer on ``node_idx``.

        Returns a :class:`MemoryRegion` owning the prep state and the
        user-side cost accounting (``charge=False`` zeroes the accounting
        for warm-up registrations, as in the thesis' methodology).
        """
        fabric = self.fabric
        if self.closed:
            raise DomainClosed(f"domain pd={self.pd} is closed")
        if node_idx not in self._node_policies:
            raise RegionError(
                f"domain pd={self.pd} is not open on node {node_idx} "
                f"(open on {self.nodes}); pass it in open_domain(nodes=...)")
        node = fabric.nodes[node_idx]
        pt = node.pt(self.pd)
        pt.mmap(va, nbytes)
        cost = PrepCost(mmap_us=fabric.cost.mmap_us(nbytes))
        if prep is BufferPrep.TOUCHED:
            for vpn in A.pages_spanned(va, nbytes):
                pt.touch(vpn)
            cost.prep_us = fabric.cost.touch_us(nbytes)
        elif prep is BufferPrep.PINNED:
            pt.pin(va, nbytes)
            cost.prep_us = fabric.cost.pin_us(nbytes)
            cost.release_us = fabric.cost.unpin_us(nbytes)
        if not charge:
            cost = PrepCost()
        fabric._rkey_counter += 1
        mr = MemoryRegion(self, node_idx, va, nbytes, prep, cost,
                          rkey=fabric._rkey_counter)
        self._regions.append(mr)
        return mr

    # -------------------------------------------------------------- verbs
    def post_write(self, src: MemoryRegion, dst: MemoryRegion,
                   cq: CompletionQueue, nbytes: Optional[int] = None,
                   src_offset: int = 0, dst_offset: int = 0,
                   wr_id: Optional[int] = None,
                   service_class: Optional[ServiceClass] = None
                   ) -> WorkRequest:
        """Asynchronous remote write ``src -> dst``; completion on ``cq``.

        ``service_class`` overrides the domain's arbiter class for this
        work request only (e.g. a BULK tenant posting one urgent WR).

        Raises :class:`~repro.core.node.NodeDown` when the *posting*
        (source) node has crashed; posting toward a crashed destination
        is accepted and completes with ``WCStatus.REMOTE_OP_ERR``."""
        if self.closed:
            raise DomainClosed(f"domain pd={self.pd} is closed")
        if self.fabric.nodes[src.node_id].crashed:
            raise NodeDown(
                f"cannot post from crashed node {src.node_id}")
        self._check_regions(src, dst)
        nbytes = nbytes if nbytes is not None else min(src.length, dst.length)
        src_va = src.addr + src_offset
        dst_va = dst.addr + dst_offset
        if not src.contains(src_va, nbytes) or not dst.contains(dst_va, nbytes):
            raise RegionError("work request outside its memory regions")
        assert (src_va % A.PAGE_SIZE) == (dst_va % A.PAGE_SIZE), \
            "fabric requires equally page-aligned src/dst (as in the thesis runs)"
        fabric = self.fabric
        self._check_quota(src.node_id)     # blocks launch on the src node
        # SRQ admission: each block consumes one shared receive entry on
        # the destination node for the transfer's lifetime
        n_blocks = len(A.split_blocks(src_va, nbytes))
        self._srq_acquire(dst.node_id, n_blocks)
        try:
            cq.on_post()
        except WorkQueueFull:
            fabric.nodes[dst.node_id].tenancy.srq.release(n_blocks)
            raise
        wr_id = wr_id if wr_id is not None else fabric._next_wr_id()
        t = fabric._start_write(self.pd, src.node_id, src_va,
                                dst.node_id, dst_va, nbytes,
                                service_class=service_class
                                or self.service_class)
        t.srq_held, t.srq_node = n_blocks, dst.node_id
        return fabric._track(wr_id, WROpcode.WRITE, cq, t)

    def post_read(self, target: MemoryRegion, local: MemoryRegion,
                  cq: CompletionQueue, nbytes: Optional[int] = None,
                  target_offset: int = 0, local_offset: int = 0,
                  wr_id: Optional[int] = None,
                  service_class: Optional[ServiceClass] = None
                  ) -> WorkRequest:
        """Asynchronous remote read: request forwarded to the target node,
        whose R5 turns it into a write back to the initiator (§1.3.2.2).

        ``service_class`` overrides the domain's arbiter class for this
        work request only (demand page-ins post LATENCY, prefetch BULK).

        Raises :class:`~repro.core.node.NodeDown` when the *posting*
        (local) node has crashed; reading from a crashed target is
        accepted and completes with ``WCStatus.REMOTE_OP_ERR``."""
        if self.closed:
            raise DomainClosed(f"domain pd={self.pd} is closed")
        if self.fabric.nodes[local.node_id].crashed:
            raise NodeDown(
                f"cannot post from crashed node {local.node_id}")
        self._check_regions(target, local)
        nbytes = nbytes if nbytes is not None else min(target.length,
                                                      local.length)
        target_va = target.addr + target_offset
        local_va = local.addr + local_offset
        if not target.contains(target_va, nbytes) or \
                not local.contains(local_va, nbytes):
            raise RegionError("work request outside its memory regions")
        assert (target_va % A.PAGE_SIZE) == (local_va % A.PAGE_SIZE), \
            "fabric requires equally page-aligned target/local (as in the thesis runs)"
        fabric = self.fabric
        self._check_quota(target.node_id)  # blocks launch on the target node
        # the read's data lands on the LOCAL node: that is where the
        # shared receive entries are consumed
        n_blocks = len(A.split_blocks(target_va, nbytes))
        self._srq_acquire(local.node_id, n_blocks)
        try:
            cq.on_post()
        except WorkQueueFull:
            fabric.nodes[local.node_id].tenancy.srq.release(n_blocks)
            raise
        wr_id = wr_id if wr_id is not None else fabric._next_wr_id()
        t = fabric._start_read(self.pd, target.node_id, target_va,
                               local.node_id, local_va, nbytes,
                               service_class=service_class
                               or self.service_class)
        t.srq_held, t.srq_node = n_blocks, local.node_id
        return fabric._track(wr_id, WROpcode.READ, cq, t)

    def _check_quota(self, sending_node: int) -> None:
        """Per-domain outstanding-block quota backpressure (arbiter)."""
        arb = self.fabric.nodes[sending_node].arbiter
        if arb.over_quota(self.pd):
            arb.note_quota_rejection(self.pd)
            raise DomainQuotaExceeded(
                f"domain pd={self.pd} at its outstanding-block quota on "
                f"node {sending_node} ({arb.outstanding(self.pd)} blocks); "
                f"drain completions first")
        # node-wide protocol backpressure: refuse new work while every
        # 14-bit tr_ID is owned by a pending block (Table 3.2) — the
        # launching R5 would only defer the blocks internally anyway
        r5 = self.fabric.nodes[sending_node].r5
        if r5.tr_ids_free() == 0:
            r5.id_stats.exhausted_posts += 1
            raise TrIdExhausted(
                f"all {r5.tr_id_space} tr_IDs in flight on node "
                f"{sending_node}; drain completions first")

    def _srq_acquire(self, recv_node: int, n_blocks: int) -> None:
        """Claim shared receive entries on the landing node, or raise
        :class:`TenantQuotaExceeded` — GOLD tenants may dip into the
        ``srq_gold_reserve`` slice best-effort traffic cannot touch."""
        srq = self.fabric.nodes[recv_node].tenancy.srq
        if not srq.try_acquire(n_blocks, gold=self.slo is SLOClass.GOLD):
            raise TenantQuotaExceeded(
                f"domain pd={self.pd}: node {recv_node}'s shared receive "
                f"queue cannot grant {n_blocks} entries "
                f"({srq.held}/{srq.entries} held"
                + (f", {srq.gold_reserve} GOLD-reserved"
                   if srq.gold_reserve else "")
                + "); drain completions first")

    def arbiter_stats(self, node_idx: int) -> ArbiterStats:
        """This domain's DMA-arbiter telemetry on ``node_idx``."""
        arb = self.fabric.nodes[node_idx].arbiter
        return arb.domain_stats.setdefault(self.pd, ArbiterStats())

    def _check_regions(self, *regions: MemoryRegion) -> None:
        for mr in regions:
            if not mr.registered:
                raise RegionError(f"region rkey={mr.rkey} is deregistered")
            if mr.domain is not self:
                raise RegionError(
                    f"region rkey={mr.rkey} belongs to pd={mr.pd}, "
                    f"not pd={self.pd}")


class Fabric:
    """A built simulated fabric: nodes, links, domains, CQs."""

    def __init__(self, config: FabricConfig):
        self.config = config
        self.cost = config.cost
        if config.race_check or os.environ.get("REPRO_RACE_CHECK"):
            if config.shards > 1:
                raise ConfigError(
                    "shards > 1 is mutually exclusive with the race "
                    "sanitizer (REPRO_RACE_CHECK)")
            from repro.lint.race import RaceCheckLoop
            self.loop: EventLoop = RaceCheckLoop()
        elif config.shards > 1:
            from repro.core.shards import ShardedEventLoop
            # conservative lookahead = min routed link latency: every
            # cross-node (hence cross-shard) event crosses >= one hop
            self.loop = ShardedEventLoop(
                config.shards, lookahead_us=self.cost.hop_latency_us)
        else:
            self.loop = make_event_loop()
        node_loop = self.loop.handle_for if config.shards > 1 else None
        self.nodes: list[Node] = []
        for i in range(config.n_nodes):
            policy = config.policy_for_node(i)
            node = Node(node_loop(i) if node_loop else self.loop,
                        self.cost, i,
                        policy.make_resolver(self.cost),
                        allocator=FrameAllocator(config.frames_per_node),
                        hupcf=config.hupcf, fault_model=config.fault_model,
                        pldma_slots=config.pldma_slots,
                        arb_quantum_bytes=config.arb_quantum_bytes,
                        tr_id_space=config.tr_id_space,
                        mtt_entries=config.mtt_entries,
                        dma_pool_frames=config.dma_pool_frames,
                        speculation=config.speculation,
                        bank_overcommit=config.bank_overcommit,
                        srq_entries=config.srq_entries,
                        srq_gold_reserve=config.srq_gold_reserve,
                        tenants_per_node=config.tenants_per_node,
                        crash_detect_retries=config.crash_detect_retries,
                        lease_timeout_us=config.lease_timeout_us)
            self.nodes.append(node)
        # the routed interconnect: per-direction links along the physical
        # adjacencies of config.topology (ALL_TO_ALL keeps the seed's
        # dedicated pair links, with hops= as its distance alias), shared
        # by every transmit path — data pages and control packets alike
        self.interconnect = Interconnect(
            self.loop, self.cost, config.topology, n_nodes=config.n_nodes,
            dims=config.dims, qos=config.link_qos,
            legacy_hops=config.hops)
        for a in self.nodes:
            a.interconnect = self.interconnect
            for b in self.nodes:
                a.peer[b.node_id] = b
        self.domains: dict[int, ProtectionDomain] = {}
        self.cqs: list[CompletionQueue] = []
        self._tid = 0
        self._wr_counter = 0
        self._rkey_counter = 0

    @classmethod
    def build(cls, config: Optional[FabricConfig] = None, **overrides) -> "Fabric":
        """Builder entry point: ``Fabric.build(FabricConfig(...))`` or
        ``Fabric.build(n_nodes=4, default_policy=...)``."""
        if config is None:
            config = FabricConfig(**overrides)
        elif overrides:
            raise TypeError("pass either a FabricConfig or keyword "
                            "overrides, not both")
        return cls(config)

    # ------------------------------------------------------------- domains
    def open_domain(self, pd: int,
                    policy: Optional[FaultPolicy] = None,
                    nodes: Optional[list[int]] = None,
                    service_class: Optional[ServiceClass] = None,
                    arb_weight: Optional[int] = None,
                    max_outstanding_blocks: Optional[int] = None,
                    slo: Optional[SLOClass] = None
                    ) -> ProtectionDomain:
        """Create protection domain ``pd`` on ``nodes`` (default: all).

        ``policy`` overrides the per-node / fabric-default fault policy for
        THIS domain: its resolver is threaded into each node's fault
        handlers via ``Node.resolver_for(pd)``.

        ``service_class`` / ``arb_weight`` / ``max_outstanding_blocks``
        override the policy's DMA-arbiter parameters for this domain
        (class of its blocks, DRR bandwidth weight, outstanding-block
        quota enforced by the posting verbs).

        ``slo`` sets the tenant's service tier (GOLD / SILVER /
        BEST_EFFORT — a :class:`~repro.tenancy.SLOClass`, its name or
        value), overriding the policy's ``slo``.  It derives the arbiter
        class/weight unless those are given explicitly, and GOLD makes
        the domain's context banks steal-immune under bank overcommit.

        Raises :class:`DomainExists` for a duplicate pd,
        :class:`BankCollision` for a ``pd % 16`` clash when
        ``FabricConfig(bank_overcommit=False)``, and
        :class:`~repro.api.completion.TenantQuotaExceeded` when a node
        is at its admission cap.
        """
        if pd in self.domains:
            raise DomainExists(f"domain pd={pd} already open")
        slo = coerce_slo(slo)
        if slo is None and policy is not None:
            slo = policy.slo
        if slo is None:
            slo = self.config.default_policy.slo
        if slo is not None:
            if service_class is None and (policy is None
                                          or policy.service_class is None):
                service_class = slo.service_class
            if arb_weight is None and (policy is None
                                       or policy.arb_weight == 1):
                arb_weight = slo.arb_weight
        node_idxs = list(nodes) if nodes is not None \
            else list(range(len(self.nodes)))
        # With overcommit disabled, each domain owns its seed-style bank
        # (pd % NUM_CONTEXT_BANKS) forever: a second pd landing on an
        # in-use bank would overwrite the bank's page table — cross-
        # tenant corruption — so reject it here, across all its nodes,
        # before any node state is created.
        if not self.config.bank_overcommit:
            bank = pd % A.NUM_CONTEXT_BANKS
            for i in node_idxs:
                clash = [q for q in self.nodes[i].page_tables
                         if q % A.NUM_CONTEXT_BANKS == bank]
                if clash:
                    raise BankCollision(
                        f"pd={pd} maps to SMMU context bank {bank}, "
                        f"already claimed by domain pd={clash[0]} on node "
                        f"{i} (bank = pd % {A.NUM_CONTEXT_BANKS})")
        # tenancy admission: check every node before creating on any,
        # so a rejection cannot leave the domain half-open
        for i in node_idxs:
            reason = self.nodes[i].tenancy.admission_error(slo)
            if reason is not None:
                self.nodes[i].tenancy.admission_rejections += 1
                raise TenantQuotaExceeded(
                    f"open_domain(pd={pd}) refused: {reason} (node {i})")
        effective = {i: policy or self.config.policy_for_node(i)
                     for i in node_idxs}
        for i in node_idxs:
            resolver = (policy.make_resolver(self.cost)
                        if policy is not None else None)
            eff = effective[i]
            self.nodes[i].create_domain(
                pd, pin_limit_bytes=eff.pin_limit_bytes,
                resolver=resolver,
                service_class=service_class or eff.service_class,
                arb_weight=(arb_weight if arb_weight is not None
                            else eff.arb_weight),
                max_outstanding_blocks=(
                    max_outstanding_blocks if max_outstanding_blocks
                    is not None else eff.max_outstanding_blocks),
                slo=slo,
                max_retries=eff.max_retries,
                retry_backoff=eff.retry_backoff)
        dom = ProtectionDomain(self, pd,
                               policy or self.config.default_policy,
                               node_policies=effective, slo=slo)
        if service_class is not None:     # explicit override beats policy
            dom.service_class = service_class
        self.domains[pd] = dom
        return dom

    def close_domain(self, pd: int, deadline_us: float = 5e6,
                     max_events: int = MAX_WAIT_EVENTS) -> None:
        """Tear down protection domain ``pd`` (the lifecycle the seed
        never had: domains could only accumulate).

        Semantics, in order:

        1. the domain stops accepting work — posting verbs and
           ``register_memory`` raise :class:`DomainClosed`;
        2. in-flight work requests DRAIN (the loop advances until every
           node's arbiter reports zero outstanding blocks for the pd, up
           to ``deadline_us`` of virtual time — a ``FabricError`` if it
           expires);
        3. every node releases the domain: SMMU bank detached (full TLB
           shootdown), NP-RDMA MTT entries dropped, all frames returned
           to the shared pool, SRQ/QP/admission slots freed;
        4. the domain's memory regions are marked deregistered and the
           pd becomes reusable by a later ``open_domain``.
        """
        dom = self.domains.get(pd)
        if dom is None:
            raise FabricError(f"domain pd={pd} is not open")
        dom.closed = True
        node_idxs = dom.nodes
        # crash-fault flush: a transfer whose destination died (or became
        # permanently unreachable) would otherwise sit out the dead-round
        # detection — or, from a crashed posting node, spin the full
        # drain deadline.  Flush such work NOW with WR_FLUSH_ERR so
        # teardown is prompt.
        self._flush_stranded(pd, node_idxs)

        def drained() -> bool:
            return all(self.nodes[i].arbiter.outstanding(pd) == 0
                       for i in node_idxs)

        if not _advance_until(self.loop, drained, deadline_us, max_events):
            dom.closed = False        # give the caller a retry path
            pending = {i: self.nodes[i].arbiter.outstanding(pd)
                       for i in node_idxs
                       if self.nodes[i].arbiter.outstanding(pd)}
            raise FabricError(
                f"close_domain(pd={pd}): {sum(pending.values())} blocks "
                f"still in flight after {deadline_us} us (per node: "
                f"{pending}); raise deadline_us or drain completions")
        for i in node_idxs:
            self.nodes[i].release_domain(pd)
        for mr in dom._regions:
            # frames were already released wholesale by release_domain;
            # the handle just becomes invalid for future verbs
            mr.registered = False
        del self.domains[pd]

    def _flush_stranded(self, pd: int, node_idxs: list[int]) -> None:
        """Fail (WR_FLUSH_ERR) the domain's transfers that can never
        drain: executing node crashed (transfers already failed there at
        crash time — this catches stragglers submitted since), or the
        destination is crashed / unreachable behind a partition."""
        ic = self.interconnect
        for i in node_idxs:
            r5 = self.nodes[i].r5
            stranded = {b.transfer for b in r5.pending.values()
                        if b.transfer.pd == pd}
            stranded.update(t for t in r5._starved if t.pd == pd)
            for t in sorted(stranded, key=lambda t: t.tid):
                if t.failed_status is not None or t.complete:
                    continue
                peer = t.dst_node
                if (self.nodes[i].crashed or peer.crashed
                        or not ic.reachable(i, peer.node_id)):
                    r5.fail_transfer(t, "wr_flush_err")

    def domain(self, pd: int) -> Optional[ProtectionDomain]:
        return self.domains.get(pd)

    # ----------------------------------------------------------------- CQs
    def create_cq(self, depth: int = 256,
                  max_outstanding: Optional[int] = None) -> CompletionQueue:
        cq = CompletionQueue(self, depth=depth,
                             max_outstanding=max_outstanding)
        self.cqs.append(cq)
        return cq

    # ------------------------------------------------------------ failures
    def crash_node(self, node_idx: int) -> None:
        """Fail-stop crash of one node (idempotent; no un-crash).

        Every incident physical link goes down (surviving traffic
        detours or partitions), the node's datapaths fall silent, and
        all transfers its R5 was executing complete with error statuses
        — ``WR_FLUSH_ERR`` for work posted from the dead node,
        ``REMOTE_OP_ERR`` for remote reads posted against it.  Work
        posted by *survivors* toward the dead node fails after
        ``FabricConfig.crash_detect_retries`` timeout rounds with
        ``REMOTE_OP_ERR``.  tr_IDs orphaned on the dead node return to
        its free list after ``FabricConfig.lease_timeout_us``.
        """
        self.nodes[node_idx].crash()

    def fail_link(self, u: int, v: int) -> None:
        """Take the physical adjacency ``u <-> v`` down (both directions).

        Traffic re-routes deterministically around it; endpoints cut off
        entirely behave like crashed peers (``REMOTE_OP_ERR`` after the
        detection window).  Raises ``KeyError`` for non-adjacent pairs.
        """
        self.interconnect.fail_link(u, v)

    def restore_link(self, u: int, v: int) -> None:
        """Bring a failed physical adjacency back up; with no links left
        down, routes revert bit-exactly to the oblivious minimal paths."""
        self.interconnect.restore_link(u, v)

    # ------------------------------------------------------------- network
    def net_stats(self) -> FabricStats:
        """Interconnect telemetry: per-link utilization/queueing rollup."""
        return self.interconnect.stats()

    def protocol_stats(self) -> dict:
        """Per-node protocol telemetry: ``{node_id: ProtocolStats}``.

        ``.tr_id`` — the tr_ID lifecycle (allocation/recycle/wrap counts,
        exhaustion backpressure, stale-control drops): the surface the
        scale soak and the wraparound regression tests assert against.
        ``.npr`` — the NP-RDMA backend (MTT hit/miss/stale, aborts,
        redirects, pool occupancy), all-zero unless a domain selected
        ``Strategy.NP_RDMA``.  ``.tenancy`` — the tenancy control plane
        (bank binds/steals/shootdowns, SRQ admission, QP multiplexing,
        tenant counts).  All are real fields — no getattr fallbacks — so
        stats consumers fail loudly if a section moves.
        """
        return {n.node_id: ProtocolStats(tr_id=n.r5.id_stats,
                                         npr=n.npr.stats,
                                         tenancy=n.tenancy)
                for n in self.nodes}

    def link_stats(self, src_node: int, dst_node: int):
        """One directed physical link's :class:`~repro.net.link.LinkStats`.

        Raises :class:`FabricError` for non-adjacent pairs — on routed
        topologies only physical neighbours (and loopbacks) have links;
        use :meth:`net_stats` for the fabric-wide rollup.
        """
        try:
            return self.interconnect.link(src_node, dst_node).stats
        except KeyError:
            adj = self.interconnect.topology.neighbors(src_node)
            raise FabricError(
                f"no physical link {src_node}->{dst_node} on topology "
                f"{self.interconnect.topology.kind.value}; node "
                f"{src_node}'s neighbours are {adj}") from None

    # ------------------------------------------------------------ progress
    @property
    def now(self) -> float:
        return self.loop.now

    def progress(self, until: Optional[float] = None) -> None:
        """Run the event loop (to ``until``, or until drained)."""
        self.loop.run(until=until)

    # --------------------------------------------------- transfer internals
    def _next_wr_id(self) -> int:
        self._wr_counter += 1
        return self._wr_counter

    def _start_write(self, pd: int, src_node: int, src_va: int,
                     dst_node: int, dst_va: int, nbytes: int,
                     service_class: Optional[ServiceClass] = None) -> Transfer:
        self._tid += 1
        t = Transfer(self._tid, pd, self.nodes[src_node],
                     self.nodes[dst_node], src_va, dst_va, nbytes,
                     service_class=service_class)
        t.origin_id = src_node
        # count against the domain quota NOW, so a burst of posts sees
        # its own backlog before any simulated delay elapses
        self.nodes[src_node].arbiter.note_submit(t)
        self.nodes[src_node].r5.submit(t)
        return t

    def _start_read(self, pd: int, target_node: int, target_va: int,
                    local_node: int, local_va: int, nbytes: int,
                    service_class: Optional[ServiceClass] = None) -> Transfer:
        self._tid += 1
        t = Transfer(self._tid, pd, self.nodes[target_node],
                     self.nodes[local_node], target_va, local_va, nbytes,
                     service_class=service_class)
        t.origin_id = local_node
        # blocks will launch on the TARGET node: count them against the
        # quota now (not after the request-packet delay), so a burst of
        # posted reads is backpressured like a burst of writes
        self.nodes[target_node].arbiter.note_submit(t)
        # request packet: initiator -> target mailbox over the routed
        # interconnect (the seed charged one hop however far the target)
        req_delay = self.cost.pckzer_to_mbox_us
        if target_node != local_node:
            try:
                req_delay += (self.nodes[local_node]
                              .path_to(target_node).send_ctrl(16))
            except NetworkPartitioned:
                # the request can never reach the target: complete with
                # REMOTE_OP_ERR.  Scheduled (not immediate) so _track
                # attaches the completion callback first.
                self.loop.schedule(req_delay,
                                   self.nodes[target_node].r5.fail_transfer,
                                   t, "remote_op_err")
                return t
        self.loop.schedule(req_delay, self.nodes[target_node].r5.submit, t)
        return t

    def _track(self, wr_id: int, opcode: WROpcode, cq: CompletionQueue,
               transfer: Transfer) -> WorkRequest:
        wr = WorkRequest(wr_id, opcode, cq, transfer, t_posted=self.loop.now)

        def _on_complete(t: Transfer) -> None:
            if t.srq_held:
                # the completion frees the destination's receive entries
                # (error completions too: no WR may leak SRQ capacity)
                self.nodes[t.srq_node].tenancy.srq.release(t.srq_held)
                t.srq_held = 0
            # core stores the terminal error as the WCStatus *value*
            # string (it cannot import repro.api); map it back here
            status = (WCStatus(t.failed_status) if t.failed_status
                      else WCStatus.SUCCESS)
            wc = WorkCompletion(wr_id=wr.wr_id, opcode=wr.opcode,
                                status=status, pd=t.pd,
                                nbytes=t.nbytes, t_posted=wr.t_posted,
                                t_complete=t.stats.t_complete,
                                stats=t.stats)
            wr.completion = wc
            cq.deliver(wc)

        transfer.on_complete = _on_complete
        return wr
