"""Asynchronous completion delivery: work requests and completion queues.

Verbs semantics over the simulated fabric:

* ``post_write()`` / ``post_read()`` return immediately with a
  :class:`WorkRequest` future — nothing blocks on the page-fault handling
  happening inside the fabric.
* When a transfer's last block is ACKed, a :class:`WorkCompletion` is
  delivered to the :class:`CompletionQueue` the request was posted
  against.  Callers either ``cq.poll(max_entries)`` (non-blocking batch
  drain, the CQ-polling hot loop of real RDMA apps) or
  ``cq.wait(n, deadline_us)`` (advance simulated time until ``n``
  completions are available or the deadline passes).
* Each CQ caps its **outstanding** work requests; posting beyond the cap
  raises :class:`WorkQueueFull` — backpressure, instead of the unbounded
  submission the old engine allowed.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import TYPE_CHECKING, Optional

from repro.errors import ConfigError, LivelockError

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.api.fabric import Fabric
    from repro.core.node import Transfer, TransferStats


# livelock backstop for the wait loops, mirroring EventLoop.run()'s budget
MAX_WAIT_EVENTS = 50_000_000


def _advance_until(loop, done, deadline_us: float, max_events: int) -> bool:
    """Step the event loop until ``done()`` holds.

    Returns False if the loop drained or the (virtual-time) deadline passed
    first; raises if the event budget trips (zero-delay livelock).
    """
    deadline = loop.now + deadline_us
    steps = 0
    while not done():
        t_next = loop.peek_time()
        if t_next is None or t_next > deadline:
            return False
        loop.step()
        steps += 1
        if steps >= max_events:
            raise LivelockError("event budget exhausted — livelock?")
    return True


class WorkQueueFull(RuntimeError):
    """Posting would exceed the CQ's outstanding-work-request cap."""


class DomainQuotaExceeded(WorkQueueFull):
    """Posting would exceed the domain's outstanding-block quota.

    Raised by the posting verbs when the sending node's DMA arbiter
    reports the protection domain at its ``max_outstanding_blocks``
    (:class:`~repro.api.policy.FaultPolicy`) — per-tenant backpressure,
    so one tenant's backlog can't grow without bound inside the fabric.
    """


class TenantQuotaExceeded(WorkQueueFull):
    """Posting (or opening a domain) would exceed a node's shared tenancy
    resources (``repro.tenancy``).

    Raised by the posting verbs when the destination node's shared
    receive queue (SRQ) cannot grant the transfer's receive entries —
    ``FabricConfig(srq_entries=...)``, with ``srq_gold_reserve`` entries
    usable only by GOLD tenants — and by ``Fabric.open_domain`` when a
    node is at its ``tenants_per_node`` admission cap (or its GOLD-bank
    ceiling).  Subclasses :class:`WorkQueueFull` so generic backpressure
    handlers retry it like any other quota signal.
    """


class TrIdExhausted(WorkQueueFull):
    """Posting would launch blocks with no free 14-bit transaction ID.

    The wire protocol's ``tr_ID`` field (Table 3.2) bounds a node to 2^14
    blocks in flight; IDs recycle only when blocks complete.  The posting
    verbs raise this *node-wide* backpressure signal — subclassing
    :class:`WorkQueueFull`, so generic backpressure handlers retry it —
    when the launching node's pool is empty.  Work already accepted is
    never lost to exhaustion: launches that race the pool internally are
    deferred inside the R5 and redeemed as completions free IDs (visible
    as ``TrIdStats.stalls``).
    """


class WROpcode(enum.Enum):
    WRITE = "write"
    READ = "read"


class WCStatus(enum.Enum):
    """Terminal status of a work request (the verbs ``wc_status`` field).

    ``SUCCESS`` was the only member before the crash-fault layer: a
    permanently-stuck transfer was observable only as a CQ ``wait``
    deadline expiry.  The error members mirror the ibverbs statuses that
    "The Impact of RDMA on Agreement" identifies as the failure-semantics
    contract RDMA protocols build on:

    * ``RETRY_EXC_ERR`` — the R5 retransmission timer exhausted the
      domain's retry budget (``FaultPolicy.max_retries``) while the peer
      stayed reachable (e.g. a destination page fault that never
      resolves).
    * ``WR_FLUSH_ERR`` — the WR was flushed without ever being attempted
      to completion: its source node crashed mid-flight, or
      ``Fabric.close_domain`` tore down a domain whose in-flight WRs
      target a crashed/unreachable peer.
    * ``REMOTE_OP_ERR`` — the remote end is dead or unreachable: the
      R5 saw ``crash_detect_retries`` consecutive timeout rounds with the
      peer down/partitioned (``FabricConfig.crash_detect_retries``).
    """

    SUCCESS = "success"
    RETRY_EXC_ERR = "retry_exc_err"
    WR_FLUSH_ERR = "wr_flush_err"
    REMOTE_OP_ERR = "remote_op_err"


@dataclasses.dataclass(frozen=True)
class WorkCompletion:
    """One CQ entry: the terminal record of a work request."""
    wr_id: int
    opcode: WROpcode
    status: WCStatus
    pd: int
    nbytes: int
    t_posted: float
    t_complete: float
    stats: "TransferStats"

    @property
    def latency_us(self) -> float:
        return self.t_complete - self.t_posted

    @property
    def ok(self) -> bool:
        return self.status is WCStatus.SUCCESS


class WorkRequest:
    """Future handed back by ``post_write()`` / ``post_read()``."""

    __slots__ = ("wr_id", "opcode", "cq", "transfer", "t_posted",
                 "completion")

    def __init__(self, wr_id: int, opcode: WROpcode, cq: "CompletionQueue",
                 transfer: "Transfer", t_posted: float):
        self.wr_id = wr_id
        self.opcode = opcode
        self.cq = cq
        self.transfer = transfer
        self.t_posted = t_posted
        self.completion: Optional[WorkCompletion] = None

    @property
    def done(self) -> bool:
        return self.completion is not None

    @property
    def stats(self) -> "TransferStats":
        """Live per-transfer statistics (valid during and after flight)."""
        return self.transfer.stats

    def result(self, deadline_us: float = 5e6,
               max_events: int = MAX_WAIT_EVENTS) -> WorkCompletion:
        """Advance simulated time until THIS request completes.

        The completion stays queued on the CQ for ``poll()``/``wait()`` —
        ``result()`` only waits for it, mirroring how a verbs app can watch
        one WR while a poller thread drains the CQ.
        """
        if not _advance_until(self.cq.fabric.loop,
                              lambda: self.completion is not None,
                              deadline_us, max_events):
            raise TimeoutError(
                f"wr_id={self.wr_id} incomplete after {deadline_us} us: "
                f"stats={self.transfer.stats}")
        return self.completion


@dataclasses.dataclass
class CQStats:
    posted: int = 0
    completed: int = 0
    polls: int = 0
    empty_polls: int = 0
    max_queued: int = 0
    rejected_posts: int = 0      # WorkQueueFull backpressure events
    deadline_expiries: int = 0   # wait() returns that hit the deadline


class CompletionQueue:
    """Bounded queue of :class:`WorkCompletion` entries.

    ``max_outstanding`` (default: ``depth``) bounds in-flight work
    requests so the CQ can never overflow: completions occupy at most the
    slots the poster was granted.
    """

    def __init__(self, fabric: "Fabric", depth: int = 256,
                 max_outstanding: Optional[int] = None):
        if max_outstanding is None:
            max_outstanding = depth
        if max_outstanding > depth:
            raise ConfigError(
                f"max_outstanding={max_outstanding} > depth={depth} could "
                f"overflow the CQ")
        self.fabric = fabric
        self.depth = depth
        self.max_outstanding = max_outstanding
        self.outstanding = 0
        self.stats = CQStats()
        self._entries: deque[WorkCompletion] = deque()

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------- posting
    def on_post(self) -> None:
        """Reserve an outstanding slot (called by the posting verbs)."""
        if self.outstanding >= self.max_outstanding:
            self.stats.rejected_posts += 1
            raise WorkQueueFull(
                f"{self.outstanding} work requests outstanding "
                f"(cap {self.max_outstanding}); poll the CQ first")
        self.outstanding += 1
        self.stats.posted += 1

    def deliver(self, wc: WorkCompletion) -> None:
        """Completion arrival (called by the fabric at ACK time).

        The outstanding slot is NOT freed here: a queued completion still
        occupies its CQ slot until the application drains it, which is what
        keeps ``len(cq) <= max_outstanding <= depth`` an invariant.
        """
        self._entries.append(wc)
        self.stats.completed += 1
        self.stats.max_queued = max(self.stats.max_queued,
                                    len(self._entries))

    # ------------------------------------------------------------ draining
    def poll(self, max_entries: int = 16) -> list[WorkCompletion]:
        """Non-blocking batch drain of up to ``max_entries`` completions."""
        self.stats.polls += 1
        if not self._entries:
            self.stats.empty_polls += 1
            return []
        out = []
        while self._entries and len(out) < max_entries:
            out.append(self._entries.popleft())
            self.outstanding -= 1           # drained entry frees its slot
        return out

    def wait(self, n: int = 1, deadline_us: float = 5e6,
             max_events: int = MAX_WAIT_EVENTS) -> list[WorkCompletion]:
        """Advance simulated time until ``n`` completions are queued (or the
        deadline passes), then drain and return up to ``n`` of them.

        May return fewer than ``n`` entries if the deadline expires first —
        callers check ``len()`` (and ``stats.deadline_expiries``), as with
        a timed verbs CQ wait.
        """
        loop = self.fabric.loop
        if not _advance_until(loop, lambda: len(self._entries) >= n,
                              deadline_us, max_events) \
                and loop.peek_time() is not None:
            # events remain past the deadline: a genuine expiry (a
            # drained loop just means no more completions will ever come)
            self.stats.deadline_expiries += 1
        return self.poll(max_entries=n)
