"""Verbs-style asynchronous API for virtual-address RDMA with page faults.

This package is the public face of the reproduction: real-RDMA verbs
semantics (builder, memory registration, asynchronous work requests,
completion queues) over the simulated ExaNeSt fabric, with the thesis'
page-fault handling underneath instead of the usual pinning ceremony.

Thesis concept -> API name
==========================

===============================  ========================================
Thesis / prototype concept        API construct
===============================  ========================================
PDID (protection-domain ID,       ``ProtectionDomain`` — ``Fabric.
SMMU context bank, §1.3.1.4)      open_domain(pd)``; one tenant, one
                                  SMMU context bank per node.
Fault-resolution strategy         ``FaultPolicy`` — per-domain (or
(Touch-A-Page / Touch-Ahead /     per-node / fabric-default) strategy +
Kernel-RAPF, §3.2.1)              lookahead + pin budget; threaded into
                                  ``Node.resolver_for(pd)``.
mmap + touch/pin preparation      ``ProtectionDomain.register_memory()``
(the thesis' three comparisons)   -> ``MemoryRegion`` with ``BufferPrep``
                                  state and ``PrepCost`` accounting.
PLDMA descriptor submission       ``post_write()`` / ``post_read()`` ->
(§1.3.2.1)                        ``WorkRequest`` future.
PLDMA status register polling     ``CompletionQueue.poll(max_entries)``
(completion_poll_us)              and ``cq.wait(n, deadline_us)``.
RAPF (Retransmit After Page       internal: fault FIFO -> driver tasklet
Fault, §3.2.3.3) + fault FIFO     -> resolver -> mailbox; surfaced in
(§3.2.3.1)                        ``WorkCompletion.stats``
                                  (``rapf_retransmits``,
                                  ``fifo_entries_handled``, ...).
R5 retransmission timeout         ``FabricConfig.cost.timeout_us``.
One mechanism for every memory    ``repro.vmem`` — ``AddressSpace`` +
consumer (the thesis' claim:      ``Pager`` (fault → resolve → map) over
faults handled, pinning           pluggable ``FramePool`` backends;
avoided, §2 motivation)           per-tenant ``FaultPolicy`` threading.
Remote paging over the fabric     ``repro.vmem.RemoteFramePool`` — every
(virtual-address RDMA as a        page-in is a ``post_read`` completing
paging backend)                   on a CQ; ``PagingStats`` surfaces
                                  ``rapf_retransmits`` / fault counts.
Pinning limit M / Firehose        ``FaultPolicy.pin_limit_bytes``,
working-set cliff (§2.3)          enforced by ``Pager.pin`` and by
                                  pin-aware eviction
                                  (``repro.vmem.PinAwareLRU``).
"Adjustments to the DMA           ``repro.core.arbiter.DMAArbiter`` —
scheduling logic" so a faulting   per-(domain, class) send queues feeding
transfer pauses without           each node's PLDMA slots by deficit
stalling the engine (§3.2)        round-robin; a block entering
                                  ``PAUSED_SRC``/``PAUSED_DST`` yields
                                  its slot immediately and re-enters at
                                  the back of its queue on RAPF/timeout.
DMA service classes /             ``ServiceClass.LATENCY`` (strict
per-tenant QoS (beyond paper:     priority) vs ``ServiceClass.BULK``
multi-tenant RDMA service)        (weighted share) — per
                                  ``FaultPolicy.service_class`` /
                                  ``open_domain``, overridable per work
                                  request (``post_write(...,
                                  service_class=...)``).
Per-tenant admission control      ``FaultPolicy.max_outstanding_blocks``
(beyond paper)                    — the posting verbs raise
                                  ``DomainQuotaExceeded`` when a domain
                                  is at its outstanding-block quota;
                                  telemetry in ``ArbiterStats``.
ExaNeSt multi-hop fabric          ``repro.net`` — ``TopologyKind``
(QFDB quads over HSS links,       (``FabricConfig(topology=, dims=)``):
§ experimental setup)             ALL_TO_ALL (n_nodes=4 = one fully
                                  connected QFDB quad) / RING / MESH_2D
                                  / TORUS_2D (quads tiled) / DRAGONFLY
                                  (quad-like cliques + global links);
                                  ``hops=`` stays as the ALL_TO_ALL
                                  back-compat distance alias.
Routed RAPF/NACK/ACK delivery     deterministic dimension-order
(control packets cross the real   ``Router``; every control packet
interconnect, §3.2.3.3)           charges — and on shared-link
                                  topologies reserves — wire time per
                                  routed hop (the seed charged one
                                  ``hop_latency_us`` flat).
Shared-link contention /          per-direction ``Link`` resources with
congested fabric QoS (beyond      LATENCY-over-BULK wire arbitration
paper: multi-tenant fabrics)      (``FabricConfig.link_qos``); per-link
                                  utilization/queueing telemetry rolls
                                  up into ``Fabric.net_stats()`` →
                                  ``FabricStats``.
14-bit tr_ID wire field           R5 free-list allocator: fresh IDs
(Table 3.2) — the hardware        first, recycle **only on block
wraps, state must not             completion**, so a paused block is
(ID-lifecycle correctness)        never aliased past 2^14 launches;
                                  ``FabricConfig.tr_id_space`` shrinks
                                  the pool for tests, wire format
                                  bit-exact; telemetry in ``TrIdStats``
                                  (``Fabric.protocol_stats()``).
seq_num / RAPF matching under     host-side *generation* tags (never
ID reuse (§3.2.3.3 firmware       serialized): RAPF matching, FIFO
checks, wrap-robust)              dedup and driver last-2 cache compare
                                  generations, dropping control traffic
                                  addressed to a previous incarnation
                                  (``TrIdStats.stale_rapf_drops``).
R5 descriptor-pool exhaustion     ``TrIdExhausted`` (a
(beyond paper: admission          ``WorkQueueFull``) from the posting
control at protocol limits)       verbs when every tr_ID is in flight;
                                  internal launches defer FIFO until
                                  completions free IDs
                                  (``TrIdStats.stalls``).
NP-RDMA MTT cache (competing      ``repro.npr.MTTCache`` — per-domain
design: NIC-cached VA→PA vs the   VA→PA entries filled host-side and
SMMU's page-table walks +         invalidated by the same munmap /
fault FIFO)                       reclaim / khugepaged hooks that feed
                                  the SMMU path; ``Strategy.NP_RDMA``
                                  + ``FabricConfig.mtt_entries``.
NP-RDMA DMA-able pool             ``repro.npr.DMAPool`` — bounded
(competing design: pre-           pre-registered landing frames
registered landing frames vs      (``FabricConfig.dma_pool_frames``)
RAPF's retransmit-into-the-       with watermark-driven re-registration;
real-buffer)                      sizing is the crossover lever vs RAPF
                                  (pool dry → 1 ms timeout fallback).
NP-RDMA speculate / abort /       ``repro.npr.NPREngine`` — launches on
redirect (competing design: the   cached translations, verifies at the
thesis instead pauses in the      destination, aborts stale rounds and
fault FIFO and RAPF-retransmits)  re-issues through the pool; counters
                                  in ``WorkCompletion.stats`` (``mtt_*``,
                                  ``npr_aborts``) and
                                  ``Fabric.protocol_stats()`` →
                                  ``ProtocolStats.npr``.
SMMU context bank as a            ``repro.tenancy.BankManager`` — the 16
*virtualized* resource (beyond    banks (§1.3.1.4) are overcommitted:
paper: RDMAvisor-style NIC/MMU    domains bind on demand,
virtualization, the "beyond 16    ``Fabric.close_domain`` releases, and
domains" north star)              10k+ tenants/node admit behind
                                  ``tenants_per_node`` /
                                  ``TenantQuotaExceeded``.
Bank steal = TLB shootdown cost   LRU bank stealing evicts a cold
(an SMMU driver rebinding a       domain's bank: ``tlb_invalidate_all``
context bank must shoot down      + page-table rebind, charged as
its cached walks)                 ``CostModel.bank_shootdown_us`` +
                                  ``bank_rebind_us`` on the fault path;
                                  telemetry in ``BankStats``
                                  (``Fabric.protocol_stats()`` →
                                  ``ProtocolStats.tenancy``).
SLO class mapping (beyond         ``repro.tenancy.SLOClass`` — GOLD /
paper: tenant tiers over one      SILVER / BEST_EFFORT maps onto
fault-handling datapath)          ``ServiceClass`` + DRR weight + bank
                                  steal immunity (GOLD) + the SRQ's
                                  ``srq_gold_reserve``; threaded through
                                  ``FaultPolicy.slo`` /
                                  ``open_domain(slo=...)``.
Machine-failure model (beyond     ``Fabric.crash_node`` (fail-stop) /
paper: the thesis assumes live    ``fail_link`` / ``restore_link``;
endpoints — real deployments      in-flight work toward a dead peer
crash mid-transfer)               completes with ``WCStatus.
                                  REMOTE_OP_ERR`` / ``WR_FLUSH_ERR``
                                  instead of retransmitting forever;
                                  posting from a dead node raises
                                  ``NodeDown``; routed traffic re-paths
                                  around down links or fails typed
                                  (``NetworkPartitioned``).
Retry budgets (beyond paper:      ``FaultPolicy.max_retries`` caps a
the R5's unconditional requeue    block's retransmissions (timeout AND
is a livelock against a dead      RAPF paths) — exhaustion completes
or wedged peer)                   the WR with ``WCStatus.
                                  RETRY_EXC_ERR``;
                                  ``FaultPolicy.retry_backoff``
                                  stretches the R5 timeout
                                  exponentially per retry.
tr_ID lease reclamation (crash    a crashed node's in-flight tr_IDs
orphans must not alias the        stay *leased* (unrecyclable) for
free list — PR-5 lifecycle        ``FabricConfig.lease_timeout_us``,
invariants under failures)        then return to the free list;
                                  ``TrIdStats.lease_reclaims``.
Remote-pager failover (beyond     ``RemoteFramePool.build(replica_node
paper: paging over a fabric       =...)`` mirrors write-backs
whose backing node can die)       (``page_out``) to a replica; a
                                  failed page-in re-serves from it
                                  with read-your-writes verification
                                  (``ryw_verified`` /
                                  ``ryw_violations``;
                                  ``PagingStats.failovers``).
===============================  ========================================

**When to use which backend** (``benchmarks/npr_compare.py`` measures
the crossovers): the thesis path (``TOUCH_AHEAD``/``KERNEL_RAPF``) wins
when destination faults dominate and memory is too tight to dedicate a
DMA pool — RAPF retransmits need no reserved frames.  ``NP_RDMA`` wins
when *source* faults occur (host fixup in microseconds vs the thesis'
1 ms timeout-only recovery) and under warm-cache/THP-churn destination
regimes with an adequately-provisioned pool (abort+redirect beats the
retransmit round-trip).  Pinning (``BufferPrep.PINNED``) still wins raw
transfer latency if you can afford the pin cost and the working set.

Quick tour::

    from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                           Strategy)

    fabric = Fabric.build(FabricConfig(n_nodes=2))
    tenant_a = fabric.open_domain(1, policy=FaultPolicy(Strategy.TOUCH_AHEAD))
    tenant_b = fabric.open_domain(2, policy=FaultPolicy(Strategy.KERNEL_RAPF))

    src = tenant_a.register_memory(0, 0x10_0000_0000, 65536,
                                   prep=BufferPrep.TOUCHED)
    dst = tenant_a.register_memory(1, 0x20_0000_0000, 65536)  # faulting!

    cq = fabric.create_cq(depth=64)
    wr = tenant_a.post_write(src, dst, cq=cq)       # returns immediately
    for wc in cq.wait(1):
        print(wc.latency_us, wc.stats.dst_faults, wc.stats.rapf_retransmits)
"""

from repro.api.completion import (CompletionQueue, CQStats,
                                  DomainQuotaExceeded, TenantQuotaExceeded,
                                  TrIdExhausted, WCStatus, WorkCompletion,
                                  WorkQueueFull, WorkRequest, WROpcode)
from repro.api.config import FabricConfig
from repro.api.fabric import Fabric, ProtectionDomain, ProtocolStats
from repro.api.memory import BufferPrep, MemoryRegion, PrepCost, RegionError
from repro.api.policy import DEFAULT_POLICY, FaultPolicy
from repro.core.arbiter import ArbiterStats, DMAArbiter, ServiceClass
from repro.core.node import (BankCollision, DomainClosed, DomainExists,
                             FabricError, NodeDown, TrIdStats)
from repro.errors import AdmissionError, ConfigError, LivelockError
from repro.core.resolver import Strategy, coerce_strategy
from repro.npr.stats import NPRStats
from repro.tenancy import (BankManager, BankStats, SLOClass, TenancyManager,
                           coerce_slo)
from repro.net import (FabricStats, LinkStats, NetworkPartitioned, Router,
                       Topology, TopologyError, TopologyKind, build_topology)

__all__ = [
    "AdmissionError", "ArbiterStats", "BankCollision", "BankManager",
    "BankStats", "BufferPrep", "CompletionQueue", "ConfigError", "CQStats",
    "DEFAULT_POLICY", "DMAArbiter", "DomainClosed", "DomainExists",
    "DomainQuotaExceeded", "Fabric", "FabricConfig", "FabricError",
    "FabricStats", "FaultPolicy", "LinkStats", "LivelockError",
    "MemoryRegion", "NPRStats", "NetworkPartitioned",
    "NodeDown", "PrepCost", "ProtectionDomain", "ProtocolStats",
    "RegionError", "Router", "SLOClass", "ServiceClass", "Strategy",
    "TenancyManager", "TenantQuotaExceeded", "Topology", "TopologyError",
    "TopologyKind", "TrIdExhausted", "TrIdStats", "WCStatus",
    "WorkCompletion", "WorkQueueFull", "WorkRequest", "WROpcode",
    "build_topology", "coerce_slo", "coerce_strategy",
]
