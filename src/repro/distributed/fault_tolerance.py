"""Cluster-level fault tolerance: heartbeats, stragglers, elastic rescale.

Control-plane components (pure Python, virtual-clock testable) that a
1000-node deployment wires to its coordinator:

* :class:`HeartbeatMonitor` — per-node liveness with configurable timeout;
  the same timeout-as-backstop philosophy as the thesis' R5 (an explicit
  failure NACK is faster, the timeout catches silent deaths).
* :class:`StragglerDetector` — per-step duration EWMA + deviation; flags
  nodes whose step times exceed median × threshold so the scheduler can
  rebalance or evict (mirrors the thesis Fig 4.6 insight: explicit early
  signals beat waiting for the worst-case timeout).
* :class:`ElasticPlan` — given dead nodes, pick the largest valid
  (pod, data, model) sub-mesh, keeping 'model' intact (TP groups die with
  any member) and shrinking 'data' — then the checkpointer's elastic
  restore re-slices state for the survivor mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class NodeState:
    last_seen: float = 0.0
    alive: bool = True
    step_ewma: float = 0.0
    steps: int = 0


class HeartbeatMonitor:
    def __init__(self, n_nodes: int, timeout: float = 30.0):
        self.timeout = timeout
        self.nodes = {i: NodeState() for i in range(n_nodes)}

    def beat(self, node: int, now: float) -> None:
        st = self.nodes[node]
        st.last_seen = now
        st.alive = True

    def check(self, now: float) -> list[int]:
        """Returns newly-dead node ids."""
        dead = []
        for i, st in self.nodes.items():
            if st.alive and now - st.last_seen > self.timeout:
                st.alive = False
                dead.append(i)
        return dead

    @property
    def alive_nodes(self) -> list[int]:
        return [i for i, st in self.nodes.items() if st.alive]


class StragglerDetector:
    """Flag nodes whose step time exceeds median × threshold."""

    def __init__(self, n_nodes: int, alpha: float = 0.3,
                 threshold: float = 1.5, min_steps: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.min_steps = min_steps
        self.nodes = {i: NodeState() for i in range(n_nodes)}

    def record(self, node: int, step_time: float) -> None:
        st = self.nodes[node]
        st.step_ewma = (step_time if st.steps == 0
                        else self.alpha * step_time
                        + (1 - self.alpha) * st.step_ewma)
        st.steps += 1

    def stragglers(self) -> list[int]:
        ready = {i: st.step_ewma for i, st in self.nodes.items()
                 if st.steps >= self.min_steps}
        if len(ready) < 2:
            return []
        med = float(np.median(list(ready.values())))
        return [i for i, t in ready.items() if t > self.threshold * med]


@dataclasses.dataclass
class ElasticPlan:
    """New mesh after failures + the data-reshard description."""
    old_shape: tuple
    new_shape: tuple
    surviving_hosts: list
    reshard_data_factor: float     # old_data_size / new_data_size

    @property
    def viable(self) -> bool:
        return all(s >= 1 for s in self.new_shape)


def plan_rescale(mesh_shape: dict, dead_nodes: list[int],
                 nodes_per_host: int = 4) -> ElasticPlan:
    """Shrink the data axis to exclude hosts containing dead nodes.

    Mesh axes: optional 'pod', 'data', 'model'.  'model' (TP) groups
    cannot lose members, so a dead node kills its whole data slice; we
    drop that slice and keep the largest surviving data extent.
    """
    data = mesh_shape.get("data", 1)
    model = mesh_shape.get("model", 1)
    pod = mesh_shape.get("pod", 1)
    total_nodes = pod * data * model
    hosts = {n // nodes_per_host for n in dead_nodes}
    # each data slice spans `model` consecutive nodes (row-major mesh)
    dead_slices = set()
    for n in dead_nodes:
        flat = n
        slice_idx = flat // model          # (pod*data) index
        dead_slices.add(slice_idx)
    surviving = [s for s in range(pod * data) if s not in dead_slices]
    new_data = len(surviving)
    # keep 'pod' if both pods retain equal slices, else fold into data
    old = tuple(v for v in (pod, data, model) if v)
    if pod > 1:
        per_pod = [len([s for s in surviving if s // data == p])
                   for p in range(pod)]
        if len(set(per_pod)) == 1 and per_pod[0] > 0:
            new_shape = (pod, per_pod[0], model)
        else:
            new_shape = (1, new_data, model)
    else:
        new_shape = (new_data, model)
    return ElasticPlan(old_shape=(pod, data, model) if pod > 1
                       else (data, model),
                       new_shape=new_shape,
                       surviving_hosts=sorted(
                           {s for s in surviving}),
                       reshard_data_factor=(pod * data) / max(1, new_data))
