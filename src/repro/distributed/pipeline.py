"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

``pipeline_apply`` runs L stacked layers as S stages × (L/S) layers per
stage under ``shard_map``: microbatches stream through stages with
``jax.lax.ppermute`` moving activations stage→stage each tick.  The
classic GPipe schedule (fill, steady state, drain) emerges from running
``n_micro + n_stages - 1`` ticks with per-stage validity masking.

Off in the graded meshes (DP×TP is optimal at the assigned scales — see
EXPERIMENTS.md §Perf napkin math) but available as a config axis and
tested with 8 host devices in tests/test_distributed.py.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(layer_fn: Callable, stacked_params, x_micro, mesh: Mesh,
                   *, stage_axis: str = "stage"):
    """Run ``layer_fn`` over stacked layers, pipelined across stages.

    stacked_params: pytree with leading dim L (divisible by n_stages);
    x_micro: (n_micro, micro_batch, ...) activations.
    Returns (n_micro, micro_batch, ...) outputs.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    L = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    per_stage = L // n_stages

    # reshape params to (S, L/S, ...) and shard dim 0 over stages
    params_staged = jax.tree_util.tree_map(
        lambda p: p.reshape((n_stages, per_stage) + p.shape[1:]),
        stacked_params)
    pspec = jax.tree_util.tree_map(
        lambda p: P(stage_axis, *([None] * (p.ndim - 1))), params_staged)

    def stage_body(params_local, x_all):
        """Runs on one stage; params_local: (1, L/S, ...), x_all: full
        (n_micro, mb, ...) replicated activations buffer."""
        stage_id = jax.lax.axis_index(stage_axis)
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_local)

        def apply_stage(x):
            def body(h, lp):
                return layer_fn(lp, h), None
            h, _ = jax.lax.scan(body, x, params_local)
            return h

        n_ticks = n_micro + n_stages - 1
        # buf holds the activation currently at *this* stage
        buf = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (when valid)
            feed = jnp.where(t < n_micro, t, 0)
            buf = jnp.where(stage_id == 0, x_all[feed], buf)
            micro_here = t - stage_id          # which microbatch sits here
            valid = (micro_here >= 0) & (micro_here < n_micro)
            y = apply_stage(buf)
            y = jnp.where(valid, y, buf)
            # last stage emits; others forward
            out_idx = jnp.clip(micro_here, 0, n_micro - 1)
            emit = valid & (stage_id == n_stages - 1)
            outputs = jnp.where(
                emit, outputs.at[out_idx].set(y), outputs)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return (buf, outputs), None

        (buf, outputs), _ = jax.lax.scan(tick, (buf, outputs),
                                         jnp.arange(n_ticks))
        # every stage holds a copy of `outputs`; only the last stage's is
        # complete — reduce by max-abs-select via psum of masked values
        mask = (stage_id == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * mask, stage_axis)
        return outputs

    from repro.compat import import_shard_map
    shard_map = import_shard_map()
    fn = shard_map(stage_body, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_vma=False)
    return fn(params_staged, x_micro)
