"""Partition rules: params / inputs / caches → NamedSharding per mesh.

Strategy (DESIGN.md §3):

* batch → ``('pod', 'data')``; vocab/heads/FFN-hidden → ``'model'``;
* MoE experts → ``'model'`` when divisible (EP), else TP inside experts;
* KV pools: pages → ``'data'`` (sequence/page parallelism — this is what
  makes ``long_500k`` shardable at batch 1), head_dim → ``'model'``;
* ZeRO-3 option: params *additionally* sharded over ``('data',)`` on their
  largest divisible dim (gathered per layer by XLA at use);
* every rule is **divisibility-checked** per dim: axes that do not divide
  are dropped (replicated) rather than failing — small KV-head counts
  (starcoder2 kv=2) replicate under TP16 exactly as DESIGN.md prescribes.

Rules are path-regex → dim-axis preferences, resolved against the actual
leaf shapes, so one rule table covers all ten architectures.
"""

from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (path regex, per-dim axis preference from the LAST dim backwards)
# each entry: list over dims (aligned to the *trailing* dims of the leaf)
# of None | axis-name | tuple of axis names.
_RULES: list[tuple[str, list]] = [
    # embeddings / heads
    (r"embed$",                 ["model", None]),          # (V, d): V->model
    (r"pos_dec$",               [None, None]),
    (r"lm_head$",               [None, "model"]),          # (d, V)
    # attention projections
    (r"(wq|wq_b)$",             [None, "model"]),
    (r"(wk|wv|wkv_a|wq_a)$",    [None, "model"]),
    (r"(wo)$",                  ["model", None]),
    (r"(wk_b|wv_b)$",           [None, "model"]),
    (r"(bq|bk|bv)$",            ["model"]),
    # MLP
    (r"(wi|wg)$",               [None, "model"]),
    (r"mlp/wo$",                ["model", None]),
    (r"(bi)$",                  ["model"]),
    (r"(bo)$",                  [None]),
    # MoE experts: (E, d, f) — EP on E if divisible, else TP on f
    (r"moe/(wi|wg)$",           ["model", None, "model"]),
    (r"moe/wo$",                ["model", None, "model"]),
    (r"router$",                [None, None]),
    (r"shared/(wi|wg)$",        [None, "model"]),
    (r"shared/wo$",             ["model", None]),
    # mamba / xlstm
    (r"in_proj$",               [None, "model"]),
    (r"out_proj$",              ["model", None]),
    (r"(up|down|skip|wo_gate)$", [None, "model"]),
    (r"down$",                  ["model", None]),
    (r"(w_if|w_gates)$",        [None, "model"]),
    (r"(ffn_wi)$",              [None, "model"]),
    (r"(ffn_wo)$",              ["model", None]),
    # everything else (norms, scalars, conv, gates): replicated
]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit_spec(shape: tuple, prefs: list, mesh: Mesh,
              stacked: int = 0) -> P:
    """Align dim preferences to trailing dims; drop non-dividing axes."""
    ndims = len(shape)
    spec: list = [None] * ndims
    # prefs align to the trailing len(prefs) dims
    for i, pref in enumerate(prefs):
        dim = ndims - len(prefs) + i
        if dim < stacked:      # never shard the stacked-layer axis
            continue
        if dim < 0 or pref is None:
            continue
        if shape[dim] % _axis_size(mesh, pref) == 0:
            spec[dim] = pref
    return P(*spec)


def _moe_rule_fixup(path: str, shape: tuple, spec: P, mesh: Mesh) -> P:
    """Experts axis: 2-D EP over (model × data) when the expert count
    allows one-or-more experts per chip — expert weights then never need
    a ZeRO gather (the §Perf deepseek iteration); else 1-D EP over
    'model'; else TP on the hidden dim."""
    if re.search(r"moe/(wi|wg|wo)$", path) and len(shape) >= 3:
        e_dim = len(shape) - 3
        model = mesh.shape.get("model", 1)
        data = mesh.shape.get("data", 1)
        new = list(spec)
        if shape[e_dim] % (model * data) == 0:
            new[e_dim] = ("model", "data")   # 2-D expert parallel
            new[e_dim + 1] = None
            new[e_dim + 2] = None
        elif shape[e_dim] % model == 0:
            new[e_dim] = "model"             # expert parallel
            new[e_dim + 1] = None
            new[e_dim + 2] = None
        else:
            new[e_dim] = None                # TP inside experts
            if re.search(r"wo$", path):
                new[e_dim + 1] = "model" if shape[e_dim + 1] % model == 0 \
                    else None
                new[e_dim + 2] = None
            else:
                new[e_dim + 1] = None
                new[e_dim + 2] = "model" if shape[e_dim + 2] % model == 0 \
                    else None
        return P(*new)
    return spec


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def _is_stacked(path_str: str) -> int:
    """Leading stacked-layer axes to skip (scan-over-layers params)."""
    if re.search(r"(dense_layers|moe_layers|tail|seg\d+|dec_layers|"
                 r"enc_layers|mtp)", path_str):
        return 1
    if re.search(r"groups", path_str):
        return 2     # (G, k, ...) double-stacked
    return 0


def param_shardings(params_shapes, mesh: Mesh, *,
                    zero3: bool = False) -> Any:
    """ShapeDtypeStruct/array pytree -> NamedSharding pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        stacked = _is_stacked(ps)
        shape = tuple(leaf.shape)
        spec = P()
        for pat, prefs in _RULES:
            if re.search(pat, ps):
                spec = _fit_spec(shape, prefs, mesh, stacked=stacked)
                break
        else:
            spec = P(*([None] * len(shape)))
        spec = _moe_rule_fixup(ps, shape, spec, mesh)
        if zero3:
            spec = _zero3_augment(spec, shape, mesh, stacked)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _zero3_augment(spec: P, shape: tuple, mesh: Mesh, stacked: int) -> P:
    """Additionally shard the largest un-sharded dim over ('data',)
    [+ ('pod',) if present] — FSDP-style parameter sharding."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    if not axes:
        return spec
    # don't double-use an axis already consumed (2-D EP uses 'data')
    used = set()
    for s in spec:
        for a in (s if isinstance(s, (tuple, list)) else (s,)):
            used.add(a)
    axes = [a for a in axes if a not in used]
    if not axes:
        return spec
    factor = int(np.prod([mesh.shape[a] for a in axes]))
    new = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest free dim that divides
    best, best_dim = 0, -1
    for d in range(stacked, len(shape)):
        if new[d] is None and shape[d] % factor == 0 and shape[d] > best:
            best, best_dim = shape[d], d
    if best_dim >= 0:
        new[best_dim] = tuple(axes) if len(axes) > 1 else axes[0]
    return P(*new)


# ------------------------------------------------------------------ inputs
def batch_spec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0] if axes else None)


def token_sharding(mesh: Mesh, *, shardable_batch: bool = True):
    """(B, S) tokens: batch over ('pod','data') when divisible."""
    if not shardable_batch:
        return NamedSharding(mesh, P(None, None))
    return NamedSharding(mesh, P(batch_spec(mesh)[0], None))


def cache_shardings(cache_shapes, mesh: Mesh, batch: int) -> Any:
    """Decode-cache pytree -> shardings.

    Pools (no batch dim): pages -> 'data', trailing feature dim ->
    'model' when divisible.  Batched state leaves: batch -> ('pod','data')
    when divisible, else replicate (long_500k batch=1 path: pages carry
    the parallelism instead — SP).
    """
    daxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if "pool" in ps:
            # (L, P, page, [KVH,] feat): pages over data axes; heads (or
            # else feat) over model — matching the shard_map decode region
            if len(shape) >= 2 and shape[1] % dsize == 0 and dsize > 1:
                spec[1] = daxes if len(daxes) > 1 else daxes[0]
            msz = mesh.shape.get("model", 1)
            if len(shape) >= 5 and shape[-2] % msz == 0:
                spec[-2] = "model"
            elif len(shape) >= 4 and shape[-1] % msz == 0:
                spec[-1] = "model"
        elif "table" in ps or ps == "lengths":
            pass   # small int arrays: replicated
        else:
            # batched state (L, B, ...) or (B, ...)
            bdim = 1 if (len(shape) > 1 and shape[0] != batch
                         and shape[1] == batch) else 0
            if shape[bdim] == batch and batch % dsize == 0 and dsize > 1:
                spec[bdim] = daxes if len(daxes) > 1 else daxes[0]
            if len(shape) >= 3 and shape[-1] % mesh.shape.get("model", 1) == 0:
                spec[-1] = "model"
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_state_shardings(opt_shapes, param_shardings_tree, mesh: Mesh) -> Any:
    """Optimizer moments follow their parameters; step is replicated."""
    import repro.optim.adamw as adamw

    def like(shapes, shardings):
        return jax.tree_util.tree_map(
            lambda s, sh: sh, shapes, shardings)

    return adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        mu=like(opt_shapes.mu, param_shardings_tree),
        nu=like(opt_shapes.nu, param_shardings_tree),
    )
