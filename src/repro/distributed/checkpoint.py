"""Distributed checkpointing: atomic, sharded, elastically restorable.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, mesh, step
        shard_h000.npz     this host's leaf shards (all leaves, one file)

Properties the fault-tolerance story needs:

* **atomic**: written to ``step_N.tmp`` then renamed — a crash mid-save
  never corrupts the latest checkpoint;
* **paged save** (the thesis' technique on the storage path): leaves are
  written in fixed-size pages so a restore can stream Touch-Ahead style
  and a partial page-in can start compute before the full state arrives;
* **elastic reshard**: the manifest records logical shapes only; restore
  re-slices for whatever mesh the surviving nodes form (D→D′ data shards,
  tested in tests/test_checkpoint.py).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamWState

MANIFEST = "manifest.json"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        names.append("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path))
    return names, [l for _, l in flat], treedef


class Checkpointer:
    def __init__(self, host_id: int = 0, n_hosts: int = 1):
        self.host_id = host_id
        self.n_hosts = n_hosts

    # ------------------------------------------------------------------ save
    def save(self, directory: str, params, opt_state: Optional[AdamWState],
             step: int) -> str:
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        state = {"params": params}
        if opt_state is not None:
            state["opt"] = {"step": opt_state.step, "mu": opt_state.mu,
                            "nu": opt_state.nu}
        names, leaves, _ = _flatten_with_names(state)
        manifest = {
            "step": step,
            "n_hosts": self.n_hosts,
            "leaves": [{"name": n, "shape": list(np.shape(l)),
                        "dtype": str(np.asarray(l).dtype)}
                       for n, l in zip(names, leaves)],
        }
        arrays = {}
        for n, l in zip(names, leaves):
            arr = np.asarray(l)
            # host shard: contiguous split on dim 0 when divisible
            if self.n_hosts > 1 and arr.ndim and \
                    arr.shape[0] % self.n_hosts == 0:
                k = arr.shape[0] // self.n_hosts
                arr = arr[self.host_id * k:(self.host_id + 1) * k]
            arrays[n.replace("/", "::")] = arr
        if os.path.isdir(final):
            # another host already published this step: add our shard
            np.savez(os.path.join(final, f"shard_h{self.host_id:03d}.npz"),
                     **arrays)
            if self.host_id == 0:
                with open(os.path.join(final, MANIFEST), "w") as f:
                    json.dump(manifest, f, indent=1)
            shutil.rmtree(tmp, ignore_errors=True)
            self._gc(directory, keep=3)
            return final
        np.savez(os.path.join(tmp, f"shard_h{self.host_id:03d}.npz"),
                 **arrays)
        if self.host_id == 0 or self.n_hosts == 1:
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f, indent=1)
        try:
            os.replace(tmp, final)     # atomic publish
        except OSError:
            # lost the publish race: merge our shard into the winner
            for fn in os.listdir(tmp):
                os.replace(os.path.join(tmp, fn), os.path.join(final, fn))
            shutil.rmtree(tmp, ignore_errors=True)
        self._gc(directory, keep=3)
        return final

    def _gc(self, directory: str, keep: int) -> None:
        steps = sorted(d for d in os.listdir(directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-keep]:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def latest_step(self, directory: str) -> Optional[int]:
        if not os.path.isdir(directory):
            return None
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        return steps[-1] if steps else None

    def restore(self, directory: str, step: int, params_like,
                opt_like: Optional[AdamWState] = None,
                n_saved_hosts: Optional[int] = None):
        """Restore into the structure of ``params_like`` (elastic: the
        number of restoring hosts may differ from the saving hosts)."""
        path = os.path.join(directory, f"step_{step:08d}")
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        n_saved = n_saved_hosts or manifest["n_hosts"]
        shards = []
        for h in range(n_saved):
            fp = os.path.join(path, f"shard_h{h:03d}.npz")
            if os.path.exists(fp):
                shards.append(np.load(fp))
        by_name: dict[str, np.ndarray] = {}
        for leaf_info in manifest["leaves"]:
            key = leaf_info["name"].replace("/", "::")
            parts = [s[key] for s in shards if key in s]
            full_shape = tuple(leaf_info["shape"])
            if len(parts) == 1 and parts[0].shape == full_shape:
                by_name[leaf_info["name"]] = parts[0]
            else:
                by_name[leaf_info["name"]] = np.concatenate(parts, axis=0)

        state_like = {"params": params_like}
        if opt_like is not None:
            state_like["opt"] = {"step": opt_like.step, "mu": opt_like.mu,
                                 "nu": opt_like.nu}
        names, leaves, treedef = _flatten_with_names(state_like)
        out = []
        for n, l in zip(names, leaves):
            arr = by_name[n]
            out.append(jnp.asarray(arr).astype(np.asarray(l).dtype))
        state = jax.tree_util.tree_unflatten(treedef, out)
        params = state["params"]
        opt = None
        if opt_like is not None:
            opt = AdamWState(step=state["opt"]["step"], mu=state["opt"]["mu"],
                             nu=state["opt"]["nu"])
        return params, opt, manifest["step"]

    def restore_latest(self, directory: str, params_like=None,
                       opt_like=None):
        step = self.latest_step(directory)
        if step is None:
            return None
        return self.restore(directory, step, params_like, opt_like)
