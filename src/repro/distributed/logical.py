"""Logical-axis activation sharding (MaxText-style logical axis rules).

Model code names its activation dims (``constrain(x, "batch", "seq",
"heads", "head_dim")``); the launcher binds logical names to mesh axes per
architecture (e.g. heads→'model' when divisible, else seq→'model' for
context parallelism).  Outside a policy context ``constrain`` is a no-op,
so tests/examples on 1 device pay nothing.

Every binding is divisibility-checked against the actual dim, so one rule
set serves all architectures (starcoder2's 24 heads silently fall back to
whatever the launcher's rules name next).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

_STATE = threading.local()


def _current() -> Optional[tuple]:
    return getattr(_STATE, "policy", None)


@contextlib.contextmanager
def logical_rules(mesh: Mesh, rules: dict):
    """rules: logical-name -> mesh-axis (str) | tuple | None."""
    prev = _current()
    _STATE.policy = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.policy = prev


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def spec_for(shape: tuple, names: tuple) -> Optional[P]:
    pol = _current()
    if pol is None:
        return None
    mesh, rules = pol
    spec = []
    used: set = set()
    for dim, name in zip(shape, names):
        ax = rules.get(name)
        parts = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        if ax is not None and not (used & set(parts)) \
                and dim % _axis_size(mesh, ax) == 0 and dim > 0:
            spec.append(ax)
            used.update(parts)
        else:
            spec.append(None)
    return P(*spec)


def constrain(x, *names: str):
    """Attach a sharding constraint per the active logical rules."""
    if _current() is None:
        return x
    if len(names) != x.ndim:
        return x
    spec = spec_for(x.shape, names)
    if spec is None or all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def current_mesh() -> Optional[Mesh]:
    pol = _current()
    return pol[0] if pol is not None else None


def rule(name: str):
    pol = _current()
    if pol is None:
        return None
    return pol[1].get(name)
