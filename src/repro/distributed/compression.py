"""Gradient compression for the DP all-reduce: error-feedback int8 + top-k.

Both jittable and composable with ``jax.lax.psum``: compress → all-reduce
the compact representation → decompress, with the quantization residual
carried host-side per step (error feedback keeps the compressed SGD
unbiased over time — tested for convergence in tests/test_distributed.py).

At 512 chips the train_4k DP all-reduce is the dominant collective for
the dense archs; int8 cuts those bytes 2× vs bf16 (4× vs f32), which is
one of the §Perf levers for the collective-bound cells.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Int8Compressed(NamedTuple):
    values: Any      # int8 pytree
    scales: Any      # f32 per-leaf scale


def int8_compress(grads, residual=None):
    """Error-feedback int8 quantization.  Returns (compressed, residual)."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        qv = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - qv.astype(jnp.float32) * scale
        return qv, scale, new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    qs, scales, rs = zip(*[q(g, r) for g, r in zip(flat_g, flat_r)])
    return (Int8Compressed(jax.tree_util.tree_unflatten(treedef, list(qs)),
                           jax.tree_util.tree_unflatten(treedef,
                                                        list(scales))),
            jax.tree_util.tree_unflatten(treedef, list(rs)))


def int8_decompress(comp: Int8Compressed):
    return jax.tree_util.tree_map(
        lambda v, s: v.astype(jnp.float32) * s, comp.values, comp.scales)


def topk_compress(grads, k_fraction: float = 0.01, residual=None):
    """Error-feedback top-k sparsification: keep the largest |g| entries."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def s(g, r):
        gf = g.astype(jnp.float32) + r
        flat = gf.reshape(-1)
        k = max(1, int(flat.shape[0] * k_fraction))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = flat[idx]
        kept = jnp.zeros_like(flat).at[idx].set(vals)
        return (idx.astype(jnp.int32), vals), (gf - kept.reshape(gf.shape))

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    comps, rs = zip(*[s(g, r) for g, r in zip(flat_g, flat_r)])
    return (jax.tree_util.tree_unflatten(treedef, list(comps)),
            jax.tree_util.tree_unflatten(treedef, list(rs)))


def topk_decompress(comp, shapes_like):
    def d(c, like):
        idx, vals = c
        flat = jnp.zeros((int(jnp.size(like)),), jnp.float32)
        flat = flat.at[idx].set(vals)
        return flat.reshape(like.shape)
    return jax.tree_util.tree_map(d, comp, shapes_like,
                                  is_leaf=lambda x: isinstance(x, tuple)
                                  and len(x) == 2
                                  and not isinstance(x[0], tuple))


def compressed_bytes(comp) -> int:
    """Wire bytes of a compressed representation (for §Perf accounting)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(comp):
        total += leaf.size * leaf.dtype.itemsize
    return total
