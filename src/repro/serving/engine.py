"""Serving engine: continuous batching over a paged, host-spillable KV pool.

The thesis' runtime loop, applied to inference serving:

* requests arrive with a prompt; **prefill** computes the prompt's KV and
  packs it into pool pages (``page_pack`` semantics);
* **decode** runs in lockstep over the active batch through the compiled
  paged-attention step; the page table handed to XLA names only resident
  frames — the engine (the "driver") resolves residency beforehand;
* when the frame pool is exhausted, pages of *waiting* sequences spill to
  host (swap-out); re-scheduling such a sequence **faults** its pages back
  in with Touch-Ahead block granularity — accounting via the calibrated
  cost model, data movement real.

Pinning baseline: ``pin_all=True`` sizes residency for the worst case and
refuses admission beyond it (the thesis' memory-utilization cost).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.policy import FaultPolicy
from repro.core.arbiter import ServiceClass
from repro.core.resolver import Strategy
from repro.memory.kv_cache import PagedKVManager
from repro.vmem import coerce_policy
from repro.models.config import ModelConfig
from repro.models.registry import model_for
from repro.serving.sampler import SamplerConfig, sample_token


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    generated: Optional[list] = None
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_generated: int = 0
    spill_events: int = 0
    fault_page_ins: int = 0
    simulated_fault_us: float = 0.0


class ServingEngine:
    """Single-host engine over one model; batch size fixed per decode step."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 max_len: int = 256, pool_frames: Optional[int] = None,
                 strategy: Optional[Strategy] = None,
                 policy: Optional[FaultPolicy] = None,
                 pin_all: bool = False,
                 sampler: SamplerConfig = SamplerConfig()):
        self.cfg = cfg
        self.params = params
        self.model = model_for(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        self.sampler = sampler
        self.pin_all = pin_all
        # this engine is one tenant of the KV fabric: its FaultPolicy decides
        # how spilled pages fault back in (legacy ``strategy`` deprecated).
        # Serving is latency-class traffic: unless the caller pinned a
        # class, its fault-back-ins arbitrate ahead of BULK tenants when
        # the KV pool is backed by the fabric (RemoteFramePool).
        self.policy = coerce_policy("ServingEngine", policy, strategy)
        if self.policy.service_class is None:
            self.policy = dataclasses.replace(
                self.policy, service_class=ServiceClass.LATENCY)
        ps = cfg.kv_page_tokens
        pages_per_seq = -(-max_len // ps)
        n_frames = pool_frames or max_batch * pages_per_seq
        self.kv = PagedKVManager(n_frames, ps, pages_per_seq,
                                 policy=self.policy)
        self.stats = EngineStats()
        # accumulation cursors into the shared vmem PagingStats
        self._kv_us_seen = 0.0
        self._kv_spills_seen = 0
        # compiled decode step: fixed (max_batch) shape; cache pools sized
        # to the device pool (shared across the batch via page table)
        self.cache = self.model.init_decode_cache(cfg, max_batch, max_len)
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_step(p, cfg, c, t))
        self.queue: list[Request] = []
        self.active: list[Request] = []
        self.req_counter = 0

    # -------------------------------------------------------------- intake
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        self.req_counter += 1
        r = Request(self.req_counter, np.asarray(prompt, np.int32),
                    max_new_tokens, generated=[])
        self.queue.append(r)
        return r

    # ------------------------------------------------------------- prefill
    def _admit(self) -> None:
        while self.queue and len(self.active) < self.max_batch:
            r = self.queue.pop(0)
            need_pages = -(-(len(r.prompt) + r.max_new_tokens)
                           // self.kv.page_tokens)
            if self.pin_all and self.kv.frames_used + need_pages > \
                    self.kv.n_frames:
                self.queue.insert(0, r)     # admission control: refuse
                break
            self.kv.add_sequence(r.req_id)
            waiting = [q.req_id for q in self.queue
                       if q.req_id in self.kv.seq_spaces]
            self.kv.append_tokens(r.req_id, len(r.prompt),
                                  spill_candidates=waiting)
            self._prefill_sequence(r)
            self.active.append(r)
            self.stats.prefills += 1

    def _prefill_sequence(self, r: Request) -> None:
        """Token-by-token prefill through the decode step (batch slot 0).

        Keeps one compiled program for the whole engine; production TPU
        deployments add a chunked prefill program — see serving docs.
        """
        slot_cache = self.model.init_decode_cache(self.cfg, 1, self.max_len)
        step = jax.jit(
            lambda p, c, t: self.model.decode_step(p, self.cfg, c, t))
        cache = slot_cache
        for t in r.prompt:
            _, cache = step(self.params, cache,
                            jnp.asarray([[t]], jnp.int32))
        self._seq_caches = getattr(self, "_seq_caches", {})
        self._seq_caches[r.req_id] = cache

    # -------------------------------------------------------------- decode
    @staticmethod
    def _path_str(path) -> str:
        return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)

    def _gather_batch_cache(self, batch: list[Request]):
        """Merge per-sequence caches into the fixed-batch decode cache.

        Convention: leaves whose path contains "pool" are frame pools
        (batch slot i owns pages [i·per_seq, (i+1)·per_seq)); "table"
        leaves are per-slot page tables; everything else carries the batch
        on axis 1 ((L, B, ...) stacked states) or axis 0 (lengths).
        """
        caches = [self._seq_caches[r.req_id] for r in batch]
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.cache)
        out = []
        for path, full in flat:
            name = self._path_str(path)
            arr = np.array(full)
            for i in range(len(batch)):
                sub = caches[i]
                for p in path:
                    sub = sub[getattr(p, "key", getattr(p, "idx", None))]
                part = np.asarray(sub)
                if name == "lengths":
                    arr[i] = part[0]
                elif "pool" in name:
                    per_seq = part.shape[1]
                    arr[:, i * per_seq:(i + 1) * per_seq] = part
                elif "table" in name:
                    pass   # identity table already maps slot -> its range
                else:
                    arr[:, i] = part[:, 0]
            out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def step_decode(self) -> int:
        """One lockstep decode over all active sequences."""
        self._admit()
        if not self.active:
            return 0
        batch = self.active[:self.max_batch]
        # residency: fault spilled pages back in before dispatch
        waiting = [q.req_id for q in self.queue
                   if q.req_id in self.kv.seq_spaces]
        for r in batch:
            n = self.kv.ensure_resident(r.req_id, spill_candidates=waiting)
            self.stats.fault_page_ins += n
        # accumulate deltas from the shared PagingStats (the pager keeps
        # the source of truth; EngineStats no longer aliases it); a
        # negative delta means someone reset() the shared stats — the
        # post-reset total IS the delta then
        kv = self.kv.stats
        d_us = kv.simulated_us - self._kv_us_seen
        self.stats.simulated_fault_us += d_us if d_us >= 0 \
            else kv.simulated_us
        self._kv_us_seen = kv.simulated_us
        d_sp = kv.spills - self._kv_spills_seen
        self.stats.spill_events += d_sp if d_sp >= 0 else kv.spills
        self._kv_spills_seen = kv.spills

        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, r in enumerate(batch):
            last = r.generated[-1] if r.generated else r.prompt[-1]
            tokens[i, 0] = last
        cache = self._gather_batch_cache(batch)
        logits, cache = self._decode(self.params, cache,
                                     jnp.asarray(tokens))
        self.stats.decode_steps += 1
        key = jax.random.PRNGKey(self.stats.decode_steps)
        next_tokens = sample_token(logits[:, 0] if logits.ndim == 3
                                   else logits, self.sampler, key)
        # scatter results + updated caches back per sequence
        for i, r in enumerate(batch):
            tok = int(next_tokens[i])
            r.generated.append(tok)
            self.kv.append_tokens(r.req_id, 1)
            self.stats.tokens_generated += 1
            seq_cache = self._seq_caches[r.req_id]
            flat, treedef = jax.tree_util.tree_flatten_with_path(seq_cache)
            out = []
            for path, leaf in flat:
                name = self._path_str(path)
                sub = cache
                for p in path:
                    sub = sub[getattr(p, "key", getattr(p, "idx", None))]
                big = np.asarray(sub)
                if name == "lengths":
                    out.append(leaf + 1)
                elif "pool" in name:
                    per_seq = np.asarray(leaf).shape[1]
                    out.append(jnp.asarray(
                        big[:, i * per_seq:(i + 1) * per_seq]))
                elif "table" in name:
                    out.append(leaf)
                else:
                    arr = np.array(leaf)
                    arr[:, 0] = big[:, i]
                    out.append(jnp.asarray(arr))
            self._seq_caches[r.req_id] = jax.tree_util.tree_unflatten(
                treedef, out)
            if len(r.generated) >= r.max_new_tokens:
                r.done = True
        finished = [r for r in batch if r.done]
        for r in finished:
            self.active.remove(r)
            self.kv.free_sequence(r.req_id)
            self._seq_caches.pop(r.req_id, None)
        return len(batch)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            if self.step_decode() == 0:
                break
            steps += 1
