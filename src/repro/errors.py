"""The typed error hierarchy — one dependency-free leaf module.

Every layer of the reproduction raises *typed* errors rooted here, so
callers can catch semantically (``except FabricError``) instead of
pattern-matching message strings, and so the ``repro.lint`` typed-error
rule can enforce the discipline mechanically: no ``raise ValueError`` /
``raise RuntimeError`` in ``repro.api`` or ``repro.tenancy``.

The module sits *below* every other ``repro`` package (it imports
nothing), which is what lets ``repro.tenancy`` raise the same hierarchy
``repro.core`` defines without a layering cycle (``core.node`` imports
``tenancy``, so tenancy could never import the classes back out of it).
``repro.core.node`` re-exports the classes unchanged for back-compat.

Subclassing contract: :class:`FabricError` IS a ``ValueError`` and
:class:`LivelockError` IS a ``RuntimeError`` — the builtins these typed
errors replaced — so pre-existing ``except ValueError`` /
``pytest.raises(ValueError)`` call sites keep working unchanged.
"""

from __future__ import annotations

__all__ = [
    "AdmissionError", "BankCollision", "ConfigError", "DomainClosed",
    "DomainExists", "FabricError", "LivelockError", "NodeDown",
]


class FabricError(ValueError):
    """A fabric-level configuration or wiring error (e.g. two live
    protection domains colliding on one SMMU context bank)."""


class ConfigError(FabricError):
    """An invalid knob value caught at construction time —
    :class:`~repro.api.config.FabricConfig`,
    :class:`~repro.api.policy.FaultPolicy`, CQ/SRQ bounds, SLO
    spellings.  Raised before any simulated work starts."""


class DomainExists(FabricError):
    """``open_domain``/``create_domain`` for a pd that is already live."""


class BankCollision(FabricError):
    """Two live protection domains map to one SMMU context bank — only
    raised when bank overcommit is disabled
    (``FabricConfig(bank_overcommit=False)``); with the tenancy control
    plane enabled the BankManager multiplexes the banks instead."""


class DomainClosed(FabricError):
    """A verb was posted against a domain after ``Fabric.close_domain``."""


class NodeDown(FabricError):
    """A verb was posted *from* a crashed node (``Node.crash``).

    Only the posting side is checked: posting *toward* a dead peer is
    allowed and surfaces asynchronously as an error completion
    (``WCStatus.REMOTE_OP_ERR``), matching real RDMA semantics where the
    initiator cannot know the target died until retries exhaust."""


class AdmissionError(FabricError):
    """A node refused to admit one more tenant (``tenants_per_node`` or
    the GOLD-bank ceiling).  The fabric-level verbs pre-check admission
    and surface :class:`~repro.api.completion.TenantQuotaExceeded`
    instead; this is the ``TenancyManager``-level backstop for direct
    ``Node``/manager use."""


class LivelockError(RuntimeError):
    """An event-budget backstop tripped: the loop kept producing events
    without the awaited condition becoming true (a zero-delay cycle or a
    starved completion).  Subclasses ``RuntimeError`` because that is
    what the budget checks raised before this class existed."""
