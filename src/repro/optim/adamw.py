"""AdamW in pure JAX: pytree state, bf16-moment option, global-norm clip.

The moment dtype knob is the memory lever for the huge archs (MaxText
convention); combined with ``repro.memory.offload`` the moments can live
host-side in pages and stream through the update block-wise (the thesis'
technique applied to optimizer state).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer memory
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None


def init(cfg: AdamWConfig, params) -> AdamWState:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, state: AdamWState, params, grads):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    lr = cfg.schedule(step) if cfg.schedule is not None else cfg.lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        m_hat = m_new / b1c
        v_hat = v_new / b2c
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, AdamWState(step, new_m, new_v), metrics
