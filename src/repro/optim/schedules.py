"""LR schedules (pure functions of the int32 step)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       min_ratio: float = 0.1):
    def schedule(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(1, warmup_steps)
        prog = jnp.clip((s - warmup_steps)
                        / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return schedule


def constant(lr: float):
    def schedule(step):
        return jnp.full((), lr, jnp.float32)
    return schedule
