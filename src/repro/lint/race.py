"""Same-timestamp race sanitizer — dynamic companion to the linter.

The EventLoop orders same-time events by their schedule sequence
number, so any given binary is deterministic.  But code that *relies*
on that tie order is a trap for the planned event-loop rewrite: change
the tie-break (bucket queues, batch execution) and stats shift with no
test failing loudly.  This instrumentation makes tie-order reliance
visible *now*:

* every fired event gets a **footprint** — sets of resource keys it
  reads and writes;
* events that fire at the same virtual timestamp form a group;
* two events in one group **conflict** if one writes a key the other
  reads or writes — their relative order is load-bearing, which is
  exactly what a tie-order change would scramble.

Footprints resolve in order:

1. a ``__race_footprint__(args) -> (reads, writes)`` attribute on the
   callback (how tests plant known races);
2. a ``FOOTPRINTS[qualname]`` registry entry, same signature;
3. generically, from the arguments: a Block-like argument (has
   ``tr_id``/``round_id``/``transfer``) contributes a write on its
   stable ``(transfer.tid, block.index)`` key, a Transfer-like argument
   (has ``tid``/``blocks``) a write on the WR key.  Note the keys are
   derived from protocol identity, never ``id()`` — the same rule the
   static ``det-id-order`` pass enforces.

Callbacks with no resolvable footprint contribute nothing; they are
tallied in ``unknown_callbacks`` so coverage erosion is observable.

Opt-in via ``FabricConfig(race_check=True)`` or ``REPRO_RACE_CHECK=1``;
``repro.testing.soak`` folds the reports into its violation list.  The
hook only *observes* (footprints are computed before the event body
runs and never touch simulator state), so an instrumented run's stats
stay byte-identical to an uninstrumented one.
"""

from __future__ import annotations

from collections import Counter
from typing import Any, Callable, Dict, FrozenSet, List, Set, Tuple

from repro.core.simulator import Event, EventLoop

Footprint = Tuple[FrozenSet[Any], FrozenSet[Any]]   # (reads, writes)

#: qualname -> footprint fn; extension point for callbacks whose
#: touch-set the generic argument scan cannot see
FOOTPRINTS: Dict[str, Callable[[tuple], Footprint]] = {}

_EMPTY: Footprint = (frozenset(), frozenset())


def _generic_footprint(args: tuple) -> Footprint:
    writes: Set[Any] = set()
    for a in args:
        if hasattr(a, "tr_id") and hasattr(a, "round_id") \
                and hasattr(a, "transfer"):          # Block
            writes.add(("block", a.transfer.tid, a.index))
        elif hasattr(a, "tid") and hasattr(a, "blocks"):   # Transfer
            writes.add(("wr", a.tid))
    return (frozenset(), frozenset(writes))


def footprint_of(fn: Callable, args: tuple) -> Tuple[Footprint, bool]:
    """(footprint, known?) for one event callback."""
    hook = getattr(fn, "__race_footprint__", None)
    if hook is not None:
        return hook(args), True
    qn = getattr(fn, "__qualname__", "")
    reg = FOOTPRINTS.get(qn)
    if reg is not None:
        return reg(args), True
    fp = _generic_footprint(args)
    return fp, bool(fp[0] or fp[1])


class RaceCheckLoop(EventLoop):
    """Drop-in EventLoop that audits same-timestamp event groups."""

    #: cap reports per run — one bad tie pattern repeats thousands of
    #: times in a soak and the first few instances say everything
    MAX_REPORTS = 32

    def __init__(self) -> None:
        super().__init__()
        self.reports: List[str] = []
        self.unknown_callbacks: Counter = Counter()
        self.groups_checked = 0
        self._group_time: float = -1.0
        #: (label, reads, writes) of the current same-time group
        self._group: List[Tuple[str, FrozenSet[Any], FrozenSet[Any]]] = []

    # ------------------------------------------------------- observation
    def _observe(self, ev: Event) -> None:
        if ev.time != self._group_time:
            self.flush()
            self._group_time = ev.time
        (reads, writes), known = footprint_of(ev.fn, ev.args)
        if not known:
            self.unknown_callbacks[
                getattr(ev.fn, "__qualname__", repr(ev.fn))] += 1
        if reads or writes:
            label = getattr(ev.fn, "__qualname__", repr(ev.fn))
            self._group.append((label, reads, writes))

    def flush(self) -> None:
        """Close the current same-time group and report its conflicts."""
        group, t = self._group, self._group_time
        self._group = []
        if len(group) < 2:
            return
        self.groups_checked += 1
        for i, (la, ra, wa) in enumerate(group):
            if len(self.reports) >= self.MAX_REPORTS:
                return
            for lb, rb, wb in group[i + 1:]:
                clash = (wa & wb) | (wa & rb) | (wb & ra)
                if clash:
                    self.reports.append(
                        f"t={t:.3f}us: {la} and {lb} conflict on "
                        f"{sorted(clash)} — same-timestamp order is "
                        f"load-bearing")
                    break

    # ------------------------------------------- instrumented execution
    # run()/run_batch() are verbatim copies of the wheel EventLoop's with
    # the single _observe() hook before each dispatch — the base loop
    # keeps its hot path free of any hook indirection.  (step() comes
    # from the base class: it delegates to run_batch(1).)
    def run(self, until: float | None = None,
            max_events: int = 50_000_000) -> None:
        import heapq
        fired = 0
        heappop = heapq.heappop
        while True:
            active = self._active
            if not active:
                if not self._refill():
                    return
                active = self._active
            entry = heappop(active)
            ev = entry[2]
            if ev.cancelled:
                self._n_queued -= 1
                self._n_cancelled -= 1
                continue
            if until is not None and entry[0] > until:
                heapq.heappush(active, entry)
                return
            if fired >= max_events:
                heapq.heappush(active, entry)
                raise RuntimeError("event budget exhausted — livelock?")
            fired += 1
            self.now = entry[0]
            self.events_processed += 1
            self._n_queued -= 1
            ev.loop = None
            self._observe(ev)
            ev.fn(*ev.args)

    def run_batch(self, limit: int) -> int:
        import heapq
        fired = 0
        heappop = heapq.heappop
        while fired < limit:
            active = self._active
            if not active:
                if not self._refill():
                    break
                active = self._active
            entry = heappop(active)
            ev = entry[2]
            if ev.cancelled:
                self._n_queued -= 1
                self._n_cancelled -= 1
                continue
            self.now = entry[0]
            self.events_processed += 1
            self._n_queued -= 1
            ev.loop = None
            self._observe(ev)
            ev.fn(*ev.args)
            fired += 1
        return fired
