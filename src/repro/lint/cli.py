"""``python -m repro.lint [paths...]`` — the build gate.

Runs every static pass (determinism, typed errors, stats coverage,
protocol conformance, spec model check) over the given paths, applies
``lint: allow(<rule>): <reason>`` comment suppressions, and exits
non-zero on any
unsuppressed finding.  Pure stdlib: CI and pre-commit can run it with
no environment beyond ``PYTHONPATH=src``.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List

from repro.lint import (common, conformance, determinism, model,
                        stats_coverage, typed_errors)
from repro.lint.common import Finding, SourceFile, collect_files

#: passes, in report order; all share the (files) -> findings shape
PASSES = (
    ("determinism", determinism.run),
    ("typed-errors", typed_errors.run),
    ("stats-coverage", stats_coverage.run),
    ("conformance", conformance.run),
)


def lint(files: List[SourceFile], with_model: bool = True) -> List[Finding]:
    """All passes + suppression handling; returns unsuppressed findings."""
    findings: List[Finding] = []
    for _, fn in PASSES:
        findings.extend(fn(files))
    by_rel = {sf.rel: sf for sf in files}
    kept = []
    for f in findings:
        sf = by_rel.get(f.path)
        if sf is not None and sf.is_suppressed(f.rule, f.line):
            continue
        kept.append(f)
    if with_model:
        kept.extend(model.run())                  # specs are not in files
    for sf in files:
        kept.extend(sf.hygiene_findings())
        kept.extend(sf.unused_suppression_findings())
    kept.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return kept


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.lint",
        description="protocol-conformance + determinism static analysis")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (repo-relative)")
    ap.add_argument("--root", default=".",
                    help="repository root (default: cwd)")
    ap.add_argument("--no-model", action="store_true",
                    help="skip the spec model checker")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="findings only, no summary")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()
    files = collect_files(args.paths or ["src"], root)
    if not files:
        print(f"repro.lint: no Python files under {args.paths}",
              file=sys.stderr)
        return 2

    findings = lint(files, with_model=not args.no_model)
    for f in findings:
        print(f.render())

    if not args.quiet:
        suppressed = sum(
            1 for sf in files for sup in sf.suppressions.values()
            if sup.used)
        _, observed = conformance.extract_block_transitions(files)
        mres = None if args.no_model else model.check_model()
        print(f"repro.lint: {len(files)} files, "
              f"{len(common.KNOWN_RULES)} rules, "
              f"{len(findings)} findings, {suppressed} suppressed",
              file=sys.stderr)
        print(f"repro.lint: conformance extracted "
              f"{len(observed)}/{len(conformance.BLOCK.transitions)} block "
              f"transitions across 4 lifecycles"
              + ("" if mres is None else
                 f"; model explored {mres.states_explored} states over "
                 f"{len(model.scenarios())} scenarios"),
              file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":                        # pragma: no cover
    sys.exit(main())
