"""Protocol state-machine specs — the single source of truth.

Four lifecycles from the paper's fault-handling protocol are written
down here as plain data.  ``repro.lint.conformance`` extracts the
*implemented* transitions/mutators from the source AST and fails on any
site outside these tables; ``repro.lint.model`` exhaustively walks a
product state machine over the same tables and fails on deadlocks, lost
completions, and dead spec rows.  The README's lifecycle tables are
prose renderings of exactly these structures — when the protocol
changes, change it HERE first and let the linter point at every stale
site.

The four specs:

``BLOCK``
    Per-block transfer lifecycle (``repro.core.node.BlockState``): the
    R5 scheduler dispatches PENDING blocks, faults park them in
    PAUSED_SRC (local SMMU miss) or PAUSED_DST (responder NACK /
    NP-RDMA pool stall), retries resume them, completion/failure drains
    them to DONE.  DONE is terminal — a block never un-completes.
``WR``
    Work-request → work-completion lifecycle: a posted WR resolves
    exactly once, to success or to exactly one of the paper's three
    error statuses (retry budget exhausted, local machine flush, remote
    machine death).
``TR_ID``
    Transaction-id (tr_id) resource lifecycle on ``R5Scheduler``: ids
    come from a bump allocator (FRESH) or the free list, are OWNED
    while a transfer holds them, become LEASED when the owner's machine
    crashes (held back for the reuse-ambiguity window), and return to
    FREE with a bumped generation.
``BANK``
    Context-bank bind/steal/release lifecycle on
    ``repro.tenancy.banks.BankManager``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class LifecycleSpec:
    """One protocol lifecycle: named states + allowed transitions.

    ``transitions`` maps ``(from_state, to_state) -> reason`` — the
    reason string is documentation rendered into the README tables and
    the conformance error messages.
    """

    name: str
    states: Tuple[str, ...]
    initial: str
    terminal: FrozenSet[str]
    transitions: Mapping[Tuple[str, str], str]

    def allows(self, src: str, dst: str) -> bool:
        return (src, dst) in self.transitions


# --------------------------------------------------------------------- BLOCK
BLOCK = LifecycleSpec(
    name="block",
    states=("PENDING", "IN_FLIGHT", "PAUSED_SRC", "PAUSED_DST", "DONE"),
    initial="PENDING",
    terminal=frozenset({"DONE"}),
    transitions={
        ("PENDING", "IN_FLIGHT"):
            "R5 scheduler dispatches the block (WQE issued)",
        ("IN_FLIGHT", "IN_FLIGHT"):
            "retry re-issues an already-dispatched block (new round_id)",
        ("PAUSED_SRC", "IN_FLIGHT"):
            "local page-fault resolved; fixup path re-issues",
        ("PAUSED_DST", "IN_FLIGHT"):
            "responder-side fault cleared; NACK retry re-issues",
        ("IN_FLIGHT", "PAUSED_SRC"):
            "local SMMU miss mid-transfer parks the block",
        ("IN_FLIGHT", "PAUSED_DST"):
            "responder NACK (dst fault) or NP-RDMA pool stall",
        ("PAUSED_SRC", "PAUSED_DST"):
            "responder NACK lands while the source fixup is pending",
        ("IN_FLIGHT", "DONE"):
            "ACK received, or transfer failed while block in flight",
        ("PENDING", "DONE"):
            "transfer fails before the block was ever dispatched",
        ("PAUSED_SRC", "DONE"):
            "transfer fails (budget/crash) while parked on a src fault",
        ("PAUSED_DST", "DONE"):
            "transfer fails (budget/crash) while parked on a dst fault",
    },
)


# ----------------------------------------------------------------------- WR
#: WC status wire strings (Transfer.failed_status uses the raw strings;
#: repro.api.completion.WCStatus mirrors them as enum values)
WC_SUCCESS = "success"
WC_ERROR_STATUSES = ("retry_exc_err", "wr_flush_err", "remote_op_err")

WR = LifecycleSpec(
    name="wr",
    states=("POSTED", "SUCCESS", "RETRY_EXC_ERR", "WR_FLUSH_ERR",
            "REMOTE_OP_ERR"),
    initial="POSTED",
    terminal=frozenset({"SUCCESS", "RETRY_EXC_ERR", "WR_FLUSH_ERR",
                        "REMOTE_OP_ERR"}),
    transitions={
        ("POSTED", "SUCCESS"):
            "all blocks ACKed; completion posted to the CQ",
        ("POSTED", "RETRY_EXC_ERR"):
            "per-transfer retry budget exhausted (paper §fault-storms)",
        ("POSTED", "WR_FLUSH_ERR"):
            "local machine failed; outstanding WRs flushed",
        ("POSTED", "REMOTE_OP_ERR"):
            "remote machine declared dead (timeout/partition)",
    },
)


# -------------------------------------------------------------------- TR_ID
TR_ID = LifecycleSpec(
    name="tr_id",
    states=("FRESH", "OWNED", "LEASED", "FREE"),
    initial="FRESH",
    terminal=frozenset(),          # ids cycle forever
    transitions={
        ("FRESH", "OWNED"):
            "bump allocator hands out a never-used id",
        ("FREE", "OWNED"):
            "free-list pop recycles an id (generation bumped)",
        ("OWNED", "FREE"):
            "transfer completed/failed locally; id returned",
        ("OWNED", "LEASED"):
            "owner machine crashed; id held for the lease window",
        ("LEASED", "FREE"):
            "lease expired with no late responder traffic",
    },
)

#: R5Scheduler fields that embody tr_id state, and the methods allowed
#: to mutate each (``__init__`` is implicitly allowed everywhere).
#: conformance.check_mutators fails on any OTHER method touching these.
TR_ID_FIELDS: Dict[str, FrozenSet[str]] = {
    "pending": frozenset({"_launch_next", "_fail_block", "on_ack",
                          "_reclaim_leases"}),
    "_free": frozenset({"_alloc_tr_id", "_free_tr_id"}),
    "_fresh_next": frozenset({"_alloc_tr_id"}),
    "_gen": frozenset({"_alloc_tr_id"}),
    "_starved": frozenset({"_launch_next", "on_ack", "fail_transfer",
                           "on_local_crash"}),
}

#: BankManager fields embodying bank state → allowed mutator methods.
BANK_FIELDS: Dict[str, FrozenSet[str]] = {
    "_domains": frozenset({"register", "release"}),
    "_bank_owner": frozenset({"release", "_attach", "bind"}),
    "bank": frozenset({"_attach", "bind"}),    # _Domain.bank slot
}

BANK = LifecycleSpec(
    name="bank",
    states=("UNBOUND", "BOUND"),
    initial="UNBOUND",
    terminal=frozenset(),
    transitions={
        ("UNBOUND", "BOUND"):
            "bind()/_attach(): free bank claimed or victim stolen",
        ("BOUND", "BOUND"):
            "rebind after shootdown (steal immunity window respected)",
        ("BOUND", "UNBOUND"):
            "release(): domain closed, bank returned to the free pool",
    },
)

#: every lifecycle, for spec round-trip tests and the CLI summary
ALL_SPECS: Tuple[LifecycleSpec, ...] = (BLOCK, WR, TR_ID, BANK)


# ----------------------------------------------------------- stats coverage
#: *Stats counter fields that no invariant checker reads, each with the
#: reason it is telemetry-only.  ``stats_coverage`` fails on (a) a
#: counter neither checked nor listed here, (b) a row naming a field
#: that no longer exists, (c) a row for a field an invariant DOES read
#: (stale exemption).  Format: {ClassName: {field: reason}}; the field
#: ``"*"`` exempts every not-otherwise-checked counter of the class
#: with one reason (for pure-telemetry classes).
STATS_EXEMPT: Dict[str, Dict[str, str]] = {
    "TrIdStats": {
        "exhausted_posts":
            "post-refusal event count; asserted by tests/test_tr_id wraps",
        "stale_rapf_drops":
            "incarnation-safety event count; asserted by targeted tests",
        "stale_fifo_entries":
            "incarnation-safety event count; asserted by targeted tests",
        "stale_npr_aborts":
            "incarnation-safety event count; asserted by targeted tests",
        "lease_reclaims":
            "crash-path event count; asserted by the crash-fault tests",
    },
    "CQStats": {
        "rejected_posts":
            "backpressure event count; no conservation identity",
        "deadline_expiries":
            "wait()-timeout event count; no conservation identity",
    },
    "SRQStats": {
        "rejected": "backpressure event count; no conservation identity",
    },
    "BankStats": {
        "hits": "bind-lookup fast-path count; no conservation identity",
    },
    "FIFOStats": {
        "dedup_skips":
            "hardware consecutive-dedup event count; tests/test_fault_fifo",
        "overflow_drops":
            "hardware overflow event count; tests/test_fault_fifo",
    },
    "FabricStats": {
        "elapsed_us": "snapshot timestamp, not a counter",
    },
    "NPRStats": {
        "*": "NP-RDMA datapath event telemetry; the safety counter "
             "(stale_completions) and pool/MTT capacities ARE checked — "
             "the rest is asserted by tests/test_npr*",
    },
    "SMMUStats": {
        "*": "TLB/fault event telemetry; tlb_hits<=translations IS "
             "checked — the rest is asserted by tests/test_fault*",
    },
    "PageTableStats": {
        "*": "page-walk churn telemetry; pin conservation IS checked — "
             "the rest is asserted by tests/test_pagetable*",
    },
    "TransferStats": {
        "*": "per-transfer sample record (one per WR), aggregated by the "
             "benchmarks; fabric-level conservation is checked on the "
             "node/arbiter/tr_id counters instead",
    },
    "PagingStats": {
        "*": "vmem pager telemetry outside the fabric invariant surface; "
             "asserted by tests/test_vmem* and tests/test_paging*",
    },
    "EngineStats": {
        "*": "serving-layer telemetry outside the fabric invariant "
             "surface; asserted by tests/test_serving*",
    },
}
