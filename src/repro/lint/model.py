"""Exhaustive model checker over the product of the four lifecycles.

The AST conformance pass proves every *implemented* transition is in
spec; this module proves the *spec itself* is sound: starting from
(block=PENDING, wr=POSTED, tr_id=FRESH, bank=UNBOUND), it enumerates
every scenario in fault × retry-budget × crash × bank-steal and walks
the full reachable product state space under the protocol's event rules.

Checked properties (rule ``conf-model``):

* **no deadlock / no lost completion** — from every reachable state
  with the WR still POSTED, some path reaches a terminal WC status;
* **resources drain** — in every rest state (no event enabled) the WR
  is terminal, the tr_id is FREE (or still FRESH if never allocated),
  and the bank is released;
* **no unreachable spec state** — every declared state of every
  lifecycle is visited in some scenario;
* **no dead spec rows, no off-spec rows** — the union of transitions
  the model takes per lifecycle equals the spec table *exactly*.

The state space is tiny (hundreds of states per scenario), so the walk
is plain BFS — determinism of the linter itself matters (it gates CI),
hence the sorted iteration everywhere.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, Dict, FrozenSet, List, Optional, Set, Tuple

from repro.lint.common import Finding
from repro.lint.specs import ALL_SPECS, BANK, BLOCK, TR_ID, WR

#: product state: block, wr, tr_id, bank, gen (0 = the id may still be
#: recycled into a follow-up transfer, to exercise FREE -> OWNED)
State = Tuple[str, str, str, str, int]


@dataclasses.dataclass(frozen=True)
class Scenario:
    fault: str      # none | src | dst | both
    budget: str     # unbounded | bounded
    crash: str      # none | src | dst
    steal: bool

    def label(self) -> str:
        return (f"fault={self.fault},budget={self.budget},"
                f"crash={self.crash},steal={self.steal}")


def scenarios() -> List[Scenario]:
    return [Scenario(f, b, c, s)
            for f, b, c, s in itertools.product(
                ("none", "src", "dst", "both"),
                ("unbounded", "bounded"),
                ("none", "src", "dst"),
                (False, True))]


#: an event: guard(scenario, state) -> bool, apply(state) -> state, and
#: which lifecycles it *acts on* (only those record transitions — a bank
#: bind does not "transition" the untouched block machine, and the
#: recycle event starts a NEW transfer rather than resurrecting a DONE
#: block)
@dataclasses.dataclass(frozen=True)
class Event:
    name: str
    guard: Callable[[Scenario, State], bool]
    apply: Callable[[State], State]
    acts_on: FrozenSet[str]


def _terminal_wr(wr: str) -> bool:
    return wr in WR.terminal


EVENTS: List[Event] = [
    Event("alloc",
          lambda sc, s: s[0] == "PENDING" and s[2] == "FRESH",
          lambda s: (s[0], s[1], "OWNED", s[3], s[4]),
          frozenset({"tr_id"})),
    Event("recycle",                       # a follow-up transfer reuses
          lambda sc, s: (sc.crash == "none" and s[4] == 0
                         and _terminal_wr(s[1]) and s[2] == "FREE"),
          lambda s: ("PENDING", "POSTED", "OWNED", s[3], 1),
          frozenset({"tr_id"})),
    Event("dispatch",
          lambda sc, s: (s[0] == "PENDING" and s[2] == "OWNED"
                         and s[1] == "POSTED"),
          lambda s: ("IN_FLIGHT",) + s[1:],
          frozenset({"block"})),
    Event("src_fault",
          lambda sc, s: sc.fault in ("src", "both")
          and s[0] == "IN_FLIGHT",
          lambda s: ("PAUSED_SRC",) + s[1:],
          frozenset({"block"})),
    Event("src_resolve",
          lambda sc, s: s[0] == "PAUSED_SRC",
          lambda s: ("IN_FLIGHT",) + s[1:],
          frozenset({"block"})),
    Event("nack",
          lambda sc, s: sc.fault in ("dst", "both")
          and s[0] in ("IN_FLIGHT", "PAUSED_SRC"),
          lambda s: ("PAUSED_DST",) + s[1:],
          frozenset({"block"})),
    Event("nack_retry",
          lambda sc, s: s[0] == "PAUSED_DST",
          lambda s: ("IN_FLIGHT",) + s[1:],
          frozenset({"block"})),
    Event("timeout_retry",                 # same state, new round_id
          lambda sc, s: s[0] == "IN_FLIGHT",
          lambda s: s,
          frozenset({"block"})),
    Event("ack",
          lambda sc, s: s[0] == "IN_FLIGHT" and s[1] == "POSTED",
          lambda s: ("DONE", "SUCCESS", "FREE", s[3], s[4]),
          frozenset({"block", "wr", "tr_id"})),
    Event("retry_exhaust",
          lambda sc, s: (sc.budget == "bounded" and s[1] == "POSTED"
                         and s[0] in ("IN_FLIGHT", "PAUSED_SRC",
                                      "PAUSED_DST")),
          lambda s: ("DONE", "RETRY_EXC_ERR", "FREE", s[3], s[4]),
          frozenset({"block", "wr", "tr_id"})),
    Event("crash_src",                     # local machine fails: flush
          lambda sc, s: sc.crash == "src" and s[1] == "POSTED",
          lambda s: ("DONE", "WR_FLUSH_ERR",
                     "LEASED" if s[2] == "OWNED" else s[2], s[3], s[4]),
          frozenset({"block", "wr", "tr_id"})),
    Event("lease_expiry",
          lambda sc, s: s[2] == "LEASED",
          lambda s: (s[0], s[1], "FREE", s[3], s[4]),
          frozenset({"tr_id"})),
    Event("dead_peer",                     # remote machine declared dead
          lambda sc, s: sc.crash == "dst" and s[1] == "POSTED",
          lambda s: ("DONE", "REMOTE_OP_ERR",
                     "FREE" if s[2] == "OWNED" else s[2], s[3], s[4]),
          frozenset({"block", "wr", "tr_id"})),
    Event("bind",
          lambda sc, s: s[3] == "UNBOUND" and s[1] == "POSTED",
          lambda s: s[:3] + ("BOUND", s[4]),
          frozenset({"bank"})),
    Event("steal",                         # another tenant evicts us
          lambda sc, s: sc.steal and s[3] == "BOUND" and s[1] == "POSTED",
          lambda s: s[:3] + ("UNBOUND", s[4]),
          frozenset({"bank"})),
    Event("rebind",                        # shootdown + immediate rebind
          lambda sc, s: sc.steal and s[3] == "BOUND" and s[1] == "POSTED",
          lambda s: s,
          frozenset({"bank"})),
    Event("release",                       # domain teardown at the end
          lambda sc, s: s[3] == "BOUND" and _terminal_wr(s[1]),
          lambda s: s[:3] + ("UNBOUND", s[4]),
          frozenset({"bank"})),
]

_COMPONENT = {"block": 0, "wr": 1, "tr_id": 2, "bank": 3}
_SPEC_OF = {"block": BLOCK, "wr": WR, "tr_id": TR_ID, "bank": BANK}

#: (event, lifecycle) pairs whose *unchanged* state is itself a spec'd
#: self-loop transition (a retry re-issues the same IN_FLIGHT block; a
#: shootdown+rebind keeps the domain BOUND).  Every other unchanged
#: component is simply untouched — e.g. crash_src leaves a FRESH tr_id
#: FRESH, which is no transition at all.
_SELF_LOOPS = {("timeout_retry", "block"), ("rebind", "bank")}

INITIAL: State = ("PENDING", "POSTED", "FRESH", "UNBOUND", 0)


@dataclasses.dataclass
class ModelResult:
    findings: List[Finding]
    states_explored: int
    taken: Dict[str, Set[Tuple[str, str]]]   # lifecycle -> transitions
    visited: Dict[str, Set[str]]             # lifecycle -> states seen


def _enabled(sc: Scenario, s: State) -> List[Event]:
    return [e for e in EVENTS if e.guard(sc, s)]


def check_model(path: str = "src/repro/lint/specs.py") -> ModelResult:
    """Walk every scenario; findings carry rule ``conf-model`` and
    anchor to the spec module (the spec is what's being judged)."""
    findings: List[Finding] = []
    taken: Dict[str, Set[Tuple[str, str]]] = {
        k: set() for k in _COMPONENT}
    visited: Dict[str, Set[str]] = {k: set() for k in _COMPONENT}
    total = 0

    for sc in scenarios():
        seen: Set[State] = {INITIAL}
        frontier = deque([INITIAL])
        edges: Dict[State, List[State]] = {}
        while frontier:
            s = frontier.popleft()
            for name, idx in _COMPONENT.items():
                visited[name].add(s[idx])
            succs: List[State] = []
            for ev in _enabled(sc, s):
                s2 = ev.apply(s)
                for name in sorted(ev.acts_on):
                    idx = _COMPONENT[name]
                    pair = (s[idx], s2[idx])
                    if pair[0] == pair[1] \
                            and (ev.name, name) not in _SELF_LOOPS:
                        continue
                    taken[name].add(pair)
                    if pair not in _SPEC_OF[name].transitions:
                        findings.append(Finding(
                            "conf-model", path, 1,
                            f"[{sc.label()}] event {ev.name} takes "
                            f"{name} through {pair[0]} -> {pair[1]}, "
                            f"which is not a spec row"))
                succs.append(s2)
                if s2 not in seen:
                    seen.add(s2)
                    frontier.append(s2)
            edges[s] = succs
        total += len(seen)

        # ---- rest states: WR terminal, resources returned
        rest = [s for s in sorted(seen) if not edges[s]]
        for s in rest:
            if s[1] == "POSTED":
                findings.append(Finding(
                    "conf-model", path, 1,
                    f"[{sc.label()}] deadlock: no event enabled in "
                    f"{s} but the WR never completed"))
            if s[2] not in ("FREE", "FRESH"):
                findings.append(Finding(
                    "conf-model", path, 1,
                    f"[{sc.label()}] tr_id stuck {s[2]} at rest in {s}"))
            if s[3] != "UNBOUND":
                findings.append(Finding(
                    "conf-model", path, 1,
                    f"[{sc.label()}] bank never released at rest in {s}"))

        # ---- liveness: every POSTED state can still reach a terminal WR
        can_finish: Set[State] = {s for s in seen if _terminal_wr(s[1])}
        changed = True
        while changed:
            changed = False
            for s in seen:
                if s in can_finish:
                    continue
                if any(s2 in can_finish for s2 in edges[s]):
                    can_finish.add(s)
                    changed = True
        lost = sorted(s for s in seen if s not in can_finish)
        if lost:
            findings.append(Finding(
                "conf-model", path, 1,
                f"[{sc.label()}] {len(lost)} states cannot reach any WC "
                f"status (first: {lost[0]}) — lost completion"))

    # ---- spec-table exactness, across all scenarios
    for spec in ALL_SPECS:
        name = spec.name
        got = taken[name]
        want = set(spec.transitions)
        for pair in sorted(want - got):
            findings.append(Finding(
                "conf-model", path, 1,
                f"spec row {name}: {pair[0]} -> {pair[1]} is taken by no "
                f"model event — dead spec row (or missing event rule)"))
        missing_states = set(spec.states) - visited[name]
        for st in sorted(missing_states):
            findings.append(Finding(
                "conf-model", path, 1,
                f"spec state {name}.{st} is unreachable in every "
                f"scenario"))

    return ModelResult(findings=findings, states_explored=total,
                       taken=taken, visited=visited)


def run(files: object = None) -> List[Finding]:
    return check_model().findings
