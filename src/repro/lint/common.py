"""Shared plumbing for the ``repro.lint`` passes.

A *pass* is a function ``(files: Sequence[SourceFile]) -> list[Finding]``.
Passes never print and never consult suppressions — the CLI applies the
``# lint: allow(<rule>)`` comments afterwards, so the same pass code
serves both the build gate and the fixture tests in ``tests/test_lint.py``.

Suppression syntax (same line as the finding, or the line above)::

    foo = time.time()   # lint: allow(<rule-id>): host telemetry only

The justification after the ``:``/``—`` is mandatory: an allow() with no
stated reason is itself a finding (``lint-suppression``), and so is an
allow() naming an unknown rule or one that suppresses nothing
(``lint-unused-suppression``) — suppressions cannot rot silently.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: every rule id a suppression may name (passes register theirs here)
KNOWN_RULES = (
    "det-set-iter",
    "det-dict-iter",
    "det-wallclock",
    "det-unseeded-random",
    "det-id-order",
    "det-heap-tiebreak",
    "typed-raise",
    "stats-coverage",
    "conf-transition",
    "conf-state-name",
    "conf-mutator",
    "conf-status",
    "conf-model",
    "lint-suppression",
    "lint-unused-suppression",
)

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*allow\(\s*([A-Za-z0-9_,\- ]+?)\s*\)\s*(?:[:—-]\s*(.*))?$")

#: directories (under src/repro) on the deterministic event path — the
#: modules whose iteration order feeds simulated time and soak stats
EVENT_PATH_DIRS = ("core", "net", "npr", "tenancy", "vmem", "api")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: rule id, location, human-readable message."""

    rule: str
    path: str          # repo-relative, forward slashes
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Suppression:
    line: int
    rules: Tuple[str, ...]
    justification: str
    used: bool = False


class SourceFile:
    """One parsed Python file plus its suppression comments."""

    def __init__(self, rel: str, text: str) -> None:
        self.rel = rel.replace("\\", "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel)
        self.suppressions: Dict[int, Suppression] = {}
        for i, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if m is None:
                continue
            rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
            just = (m.group(2) or "").strip()
            self.suppressions[i] = Suppression(i, rules, just)

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        rel = str(path.relative_to(root))
        return cls(rel, path.read_text(encoding="utf-8"))

    # ------------------------------------------------------------- scoping
    @property
    def in_repro(self) -> bool:
        return self.rel.startswith("src/repro/")

    @property
    def in_event_path(self) -> bool:
        return any(self.rel.startswith(f"src/repro/{d}/")
                   for d in EVENT_PATH_DIRS)

    # -------------------------------------------------------- suppressions
    def is_suppressed(self, rule: str, line: int) -> bool:
        """True (and marks the comment used) if an allow() covers
        ``rule`` on ``line`` or on the line above it."""
        for cand in (line, line - 1):
            sup = self.suppressions.get(cand)
            if sup is not None and rule in sup.rules:
                sup.used = True
                return True
        return False

    def hygiene_findings(self) -> List[Finding]:
        """Malformed suppressions: unknown rule ids, missing reasons."""
        out = []
        for sup in self.suppressions.values():
            for rule in sup.rules:
                if rule not in KNOWN_RULES:
                    out.append(Finding(
                        "lint-suppression", self.rel, sup.line,
                        f"allow() names unknown rule {rule!r}"))
            if not sup.justification:
                out.append(Finding(
                    "lint-suppression", self.rel, sup.line,
                    "allow() without a justification — state why the "
                    "finding is deliberate after a ':'"))
        return out

    def unused_suppression_findings(self) -> List[Finding]:
        """Call after every pass ran + suppressions were applied."""
        return [Finding("lint-unused-suppression", self.rel, sup.line,
                        f"allow({', '.join(sup.rules)}) suppresses nothing "
                        f"on this line — remove it")
                for sup in self.suppressions.values() if not sup.used]


def collect_files(paths: Sequence[str], root: Path) -> List[SourceFile]:
    """Every ``.py`` file under the given repo-relative paths, sorted."""
    seen: Dict[str, SourceFile] = {}
    for arg in paths:
        p = (root / arg).resolve()
        candidates: Iterable[Path]
        if p.is_file() and p.suffix == ".py":
            candidates = [p]
        elif p.is_dir():
            candidates = sorted(q for q in p.rglob("*.py")
                                if "__pycache__" not in q.parts)
        else:
            continue
        for q in candidates:
            sf = SourceFile.load(q, root)
            seen.setdefault(sf.rel, sf)
    return [seen[k] for k in sorted(seen)]


# --------------------------------------------------------------- AST utils
def add_parents(tree: ast.AST) -> None:
    """Annotate every node with ``.lint_parent`` (None at the root)."""
    tree.lint_parent = None                    # type: ignore[attr-defined]
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.lint_parent = node           # type: ignore[attr-defined]


def parent(node: ast.AST) -> Optional[ast.AST]:
    return getattr(node, "lint_parent", None)


def enclosing_function(node: ast.AST) -> Optional[ast.AST]:
    cur = parent(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parent(cur)
    return None


def qualname_of(node: ast.AST) -> str:
    """``Class.method`` / ``function`` for the scope containing ``node``."""
    names: List[str] = []
    cur: Optional[ast.AST] = node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            names.append(cur.name)
        cur = parent(cur)
    return ".".join(reversed(names)) or "<module>"


def call_name(node: ast.Call) -> str:
    """Dotted name of a call target, best effort (``heapq.heappush``)."""
    return dotted_name(node.func)


def dotted_name(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""
