"""``typed-raise``: no bare builtin exceptions at the API surface.

``repro.api`` and ``repro.tenancy`` are what callers program against,
and callers discriminate failures by type (``except DomainClosed``,
``pytest.raises(ConfigError)``).  A bare ``raise ValueError(...)`` there
forces string matching on the caller.  The typed hierarchy lives in
``repro.errors``; every class subclasses ``ValueError`` or
``RuntimeError`` so legacy ``except ValueError`` call sites keep
working — which is also why this rule exists: nothing else would stop
a bare raise from creeping back in.

``TypeError`` stays allowed — passing the wrong *kind* of object is a
programming error, and the stdlib idiom is correct for it.  Re-raises
(``raise`` with no operand) and raises of locally-caught names are
out of scope.
"""

from __future__ import annotations

import ast
from typing import List, Sequence

from repro.lint.common import Finding, SourceFile

BANNED = ("ValueError", "RuntimeError", "Exception")

SCOPE = ("src/repro/api/", "src/repro/tenancy/")


def run(files: Sequence[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    for sf in files:
        if not sf.rel.startswith(SCOPE):
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Raise) or node.exc is None:
                continue
            exc = node.exc
            name = ""
            if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
                name = exc.func.id
            elif isinstance(exc, ast.Name):
                name = exc.id
            if name in BANNED:
                out.append(Finding(
                    "typed-raise", sf.rel, node.lineno,
                    f"bare {name} raised at the API surface — raise a "
                    f"typed error from repro.errors (they subclass "
                    f"{name if name != 'Exception' else 'ValueError'}, "
                    f"so existing handlers keep working)"))
    return out
