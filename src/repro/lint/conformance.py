"""Protocol conformance: implementation AST vs the specs in ``specs.py``.

Four checks, one rule id each:

``conf-transition``
    Every ``<x>.state = BlockState.Y`` assignment is analysed for its
    possible *from*-states (intraprocedural guard analysis, below) and
    each resulting ``(from, to)`` pair must be a row of
    :data:`~repro.lint.specs.BLOCK`.  An unguarded write that could move
    a DONE block, or any transition the paper's protocol doesn't have,
    fails the build.
``conf-state-name``
    String comparisons against ``<x>.state.name`` must name a member of
    some declared enum — catches the ``"DNOE"`` typo class that a
    ``is BlockState.DONE`` comparison can't have.
``conf-mutator``
    The tr_id and bank lifecycles aren't enum fields; their state *is*
    the containers (``R5Scheduler.pending``/``_free``/..., the
    ``BankManager`` tables).  Each watched container may be mutated only
    by the methods :data:`~repro.lint.specs.TR_ID_FIELDS` /
    :data:`~repro.lint.specs.BANK_FIELDS` sanction (plus ``__init__``),
    and never from outside the owning class.
``conf-status``
    WC statuses: ``fail_transfer`` call sites pass a spec'd error
    literal (or the ``_crash_status`` chooser), ``_crash_status``
    returns only spec'd literals, the ``WCStatus`` enum and
    ``invariants.FAILED_STATUSES`` mirror the spec exactly, and
    ``fail_transfer``'s first statement is the exactly-once guard.

Guard analysis (``conf-transition``): statements of the enclosing
function are walked in order, tracking the set of states the target
could be in — ``if <state test>: return`` prunes by the test's
negation, an earlier ``.state = X`` assignment narrows to ``{X}``,
``assert``/``if`` tests restrict their scope, and loop bodies feed back
only those states whose suite can reach the back edge.  The analysis is
deliberately *pessimistic*: anything it can't see leaves the from-set
wide, so the fix for a false positive is an explicit guard or assert —
which is exactly the self-documenting code the pass exists to force.
"""

from __future__ import annotations

import ast
from typing import (Dict, FrozenSet, Iterable, List, Optional, Sequence, Set,
                    Tuple)

from repro.lint.common import (Finding, SourceFile, add_parents, call_name,
                               dotted_name, enclosing_function, parent,
                               qualname_of)
from repro.lint.specs import (BANK_FIELDS, BLOCK, TR_ID_FIELDS,
                              WC_ERROR_STATUSES, WC_SUCCESS)

_STATES: FrozenSet[str] = frozenset(BLOCK.states)

_MUTATING_METHODS = {"append", "appendleft", "pop", "popleft", "clear",
                     "remove", "add", "update", "setdefault", "extend",
                     "insert", "discard", "popitem"}

_TERMINAL = (ast.Return, ast.Raise, ast.Break, ast.Continue)


# ------------------------------------------------------------ AST helpers
def _state_literal(node: ast.AST) -> Optional[str]:
    """``BlockState.X`` -> ``"X"``."""
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "BlockState" and node.attr in _STATES:
        return node.attr
    return None


def _is_state_lvalue(node: ast.AST) -> Optional[str]:
    """``<name>.state`` -> the base name, else None."""
    if isinstance(node, ast.Attribute) and node.attr == "state" \
            and isinstance(node.value, ast.Name):
        return node.value.id
    return None


def _reads_state_of(node: ast.AST, var: str) -> bool:
    return _is_state_lvalue(node) == var


def _restriction(test: ast.AST, var: str) -> Optional[FrozenSet[str]]:
    """States of ``var`` for which ``test`` holds, or None (no info)."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        inner = _restriction(test.operand, var)
        return None if inner is None else _STATES - inner
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        parts = [_restriction(v, var) for v in test.values]
        known = [p for p in parts if p is not None]
        if not known:
            return None
        out = _STATES
        for p in known:
            out &= p
        return out
    if not (isinstance(test, ast.Compare) and len(test.ops) == 1):
        return None
    left, op, right = test.left, test.ops[0], test.comparators[0]
    # `<var>.state is/== BlockState.X`  (and the .name string form)
    lit: Optional[Set[str]] = None
    if _reads_state_of(left, var):
        one = _state_literal(right)
        if one is not None:
            lit = {one}
        elif isinstance(right, (ast.Tuple, ast.List, ast.Set)):
            members = [_state_literal(e) for e in right.elts]
            if all(m is not None for m in members):
                lit = set(members)           # type: ignore[arg-type]
    elif isinstance(left, ast.Attribute) and left.attr == "name" \
            and _is_state_lvalue(left.value) == var \
            and isinstance(right, ast.Constant) \
            and isinstance(right.value, str) and right.value in _STATES:
        lit = {right.value}
    if lit is None:
        return None
    if isinstance(op, (ast.Is, ast.Eq, ast.In)):
        return frozenset(lit)
    if isinstance(op, (ast.IsNot, ast.NotEq, ast.NotIn)):
        return _STATES - lit
    return None


def _negation(test: ast.AST, var: str) -> Optional[FrozenSet[str]]:
    """States for which ``test`` is false — handles ``A or B`` guards
    (fallthrough of ``if A or B: return`` implies both false)."""
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.Or):
        out = _STATES
        for v in test.values:
            r = _restriction(v, var)
            if r is not None:
                out &= _STATES - r
        return out if out != _STATES else None
    r = _restriction(test, var)
    return None if r is None else _STATES - r


def _suite_terminal(suite: Sequence[ast.stmt], after: ast.stmt) -> bool:
    """Does ``suite`` unconditionally leave the loop/function after the
    statement ``after`` (so a loop-body assignment can't feed back)?"""
    seen = False
    for stmt in suite:
        if stmt is after:
            seen = True
            continue
        if seen and isinstance(stmt, _TERMINAL):
            return True
    return seen and isinstance(suite[-1], _TERMINAL)


def _loop_feedback(body: Sequence[ast.stmt], var: str) -> Set[str]:
    """States assigned to ``var.state`` inside a loop body that can
    survive to the back edge (their suite doesn't end terminally)."""
    out: Set[str] = set()
    for stmt in body:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Assign):
                continue
            tgt = node.targets[0] if len(node.targets) == 1 else None
            if tgt is None or _is_state_lvalue(tgt) != var:
                continue
            lit = _state_literal(node.value)
            if lit is None:
                out |= set(_STATES)
                continue
            up = parent(node)
            suite = None
            if up is not None:
                for field in ("body", "orelse", "finalbody"):
                    cand = getattr(up, field, None)
                    if isinstance(cand, list) and node in cand:
                        suite = cand
                        break
            if suite is None or not _suite_terminal(suite, node):
                out.add(lit)
    return out


# ------------------------------------------------- from-state computation
def _scan(stmts: Sequence[ast.stmt], possible: FrozenSet[str],
          site: ast.Assign, var: str
          ) -> Tuple[str, FrozenSet[str]]:
    """Walk a suite; returns ('found', states-at-site),
    ('term', _) if the suite always leaves, or ('fall', states-after)."""
    for stmt in stmts:
        if stmt is site:
            return "found", possible
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and _is_state_lvalue(stmt.targets[0]) == var:
            lit = _state_literal(stmt.value)
            possible = frozenset({lit}) if lit is not None else _STATES
            continue
        if isinstance(stmt, ast.Assert):
            r = _restriction(stmt.test, var)
            if r is not None:
                possible &= r
            continue
        if isinstance(stmt, _TERMINAL):
            return "term", possible
        if isinstance(stmt, ast.If):
            r = _restriction(stmt.test, var)
            body_p = possible & r if r is not None else possible
            st, p = _scan(stmt.body, body_p, site, var)
            if st == "found":
                return st, p
            n = _negation(stmt.test, var)
            else_p = possible & n if n is not None else possible
            st2, p2 = ("fall", else_p)
            if stmt.orelse:
                st2, p2 = _scan(stmt.orelse, else_p, site, var)
                if st2 == "found":
                    return st2, p2
            after: FrozenSet[str] = frozenset()
            if st == "fall":
                after |= p
            if st2 == "fall":
                after |= p2
            if not after:
                return "term", possible
            possible = after
            continue
        if isinstance(stmt, (ast.For, ast.While)):
            widened = possible | _loop_feedback(stmt.body, var)
            st, p = _scan(stmt.body, widened, site, var)
            if st == "found":
                return st, p
            if stmt.orelse:
                st2, p2 = _scan(stmt.orelse, widened, site, var)
                if st2 == "found":
                    return st2, p2
            possible = widened
            continue
        if isinstance(stmt, ast.Try):
            st, p = _scan(stmt.body, possible, site, var)
            if st == "found":
                return st, p
            after = p if st == "fall" else frozenset()
            for handler in stmt.handlers:
                st2, p2 = _scan(handler.body, possible, site, var)
                if st2 == "found":
                    return st2, p2
                if st2 == "fall":
                    after |= p2
            if stmt.finalbody:
                st3, p3 = _scan(stmt.finalbody, after or possible, site, var)
                if st3 == "found":
                    return st3, p3
                if st3 == "term":
                    return "term", possible
            if not after:
                return "term", possible
            possible = after
            continue
        if isinstance(stmt, ast.With):
            st, p = _scan(stmt.body, possible, site, var)
            if st != "fall":
                return st, p
            possible = p
            continue
        # plain statements can't contain a statement-level Assign
    return "fall", possible


def _from_states(func: ast.AST, site: ast.Assign, var: str) -> FrozenSet[str]:
    body = getattr(func, "body", None)
    if body is None:
        return _STATES
    st, p = _scan(body, _STATES, site, var)
    return p if st == "found" else _STATES


# ------------------------------------------------------- the four checks
def extract_block_transitions(
        files: Sequence[SourceFile]
) -> Tuple[List[Finding], Set[Tuple[str, str]]]:
    """(findings, observed (from, to) pairs) for the block lifecycle."""
    findings: List[Finding] = []
    observed: Set[Tuple[str, str]] = set()
    for sf in files:
        if not sf.in_repro:
            continue
        add_parents(sf.tree)
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
                continue
            var = _is_state_lvalue(node.targets[0])
            dst = _state_literal(node.value)
            if var is None or dst is None:
                continue
            func = enclosing_function(node)
            qn = qualname_of(node)
            if func is not None and getattr(func, "name", "") == "__init__" \
                    and var == "self":
                if dst != BLOCK.initial:
                    findings.append(Finding(
                        "conf-transition", sf.rel, node.lineno,
                        f"{qn}: lifecycle starts in {dst}, spec initial "
                        f"state is {BLOCK.initial}"))
                continue
            srcs = _from_states(func, node, var) if func is not None \
                else _STATES
            for src in sorted(srcs):
                observed.add((src, dst))
                if not BLOCK.allows(src, dst):
                    findings.append(Finding(
                        "conf-transition", sf.rel, node.lineno,
                        f"{qn}: possible transition {src} -> {dst} is not "
                        f"in the block lifecycle spec — guard the write "
                        f"(or extend specs.BLOCK if the protocol changed)"))
    return findings, observed


def _enum_members(files: Sequence[SourceFile]) -> Set[str]:
    members: Set[str] = set()
    for sf in files:
        if not sf.in_repro:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not any("Enum" in dotted_name(b) for b in node.bases):
                continue
            for stmt in node.body:
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            members.add(t.id)
    return members


def check_state_names(files: Sequence[SourceFile]) -> List[Finding]:
    universe = _enum_members(files)
    out: List[Finding] = []
    for sf in files:
        if not sf.in_repro:
            continue
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Compare) and len(node.ops) == 1):
                continue
            left = node.left
            if not (isinstance(left, ast.Attribute) and left.attr == "name"
                    and isinstance(left.value, ast.Attribute)
                    and left.value.attr == "state"):
                continue
            right = node.comparators[0]
            literals: Iterable[ast.AST] = (
                right.elts if isinstance(right, (ast.Tuple, ast.List,
                                                 ast.Set)) else [right])
            for lit in literals:
                if isinstance(lit, ast.Constant) \
                        and isinstance(lit.value, str) \
                        and lit.value not in universe:
                    out.append(Finding(
                        "conf-state-name", sf.rel, node.lineno,
                        f".state.name compared against {lit.value!r}, "
                        f"which names no member of any declared enum"))
    return out


def _mutated_field(node: ast.AST) -> Optional[Tuple[str, str, int]]:
    """If ``node`` mutates ``<base>.<field>`` return (base-dotted-name,
    field, line): assignment, augmented assignment, del, subscript
    store, or a mutating method call."""
    def owner_of(attr: ast.AST) -> Optional[Tuple[str, str, int]]:
        if isinstance(attr, ast.Attribute):
            return dotted_name(attr.value), attr.attr, attr.lineno
        return None

    if isinstance(node, (ast.Assign, ast.AugAssign)):
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if isinstance(t, ast.Subscript):
                t = t.value
            got = owner_of(t)
            if got:
                return got
    elif isinstance(node, ast.Delete):
        for t in node.targets:
            if isinstance(t, ast.Subscript):
                t = t.value
            got = owner_of(t)
            if got:
                return got
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in _MUTATING_METHODS:
        return owner_of(node.func.value)
    return None


def _check_class_mutators(sf: SourceFile, cls_name: str,
                          fields: Dict[str, FrozenSet[str]],
                          lifecycle: str) -> List[Finding]:
    out: List[Finding] = []
    cls = next((n for n in ast.walk(sf.tree)
                if isinstance(n, ast.ClassDef) and n.name == cls_name), None)
    if cls is None:
        return [Finding("conf-mutator", sf.rel, 1,
                        f"class {cls_name} not found — the {lifecycle} "
                        f"mutator spec no longer matches the code")]
    for node in ast.walk(cls):
        got = _mutated_field(node)
        if got is None:
            continue
        base, field, line = got
        if field not in fields:
            continue
        # `self.<field>` inside the class, or a `<dom>.bank`-style slot
        # write (base is a local holding the owned record)
        func = enclosing_function(node)
        method = getattr(func, "name", "<module>") if func is not None \
            else "<module>"
        if method == "__init__" or method in fields[field]:
            continue
        out.append(Finding(
            "conf-mutator", sf.rel, line,
            f"{cls_name}.{method} mutates {lifecycle} state "
            f"{base}.{field} — only "
            f"{', '.join(sorted(fields[field]))} (and __init__) may"))
    return out


def _check_foreign_mutations(files: Sequence[SourceFile], owner_rel: str,
                             hint: str, fields: Dict[str, FrozenSet[str]],
                             lifecycle: str) -> List[Finding]:
    """No file other than the owner may mutate ``*.<hint>.<field>``."""
    out: List[Finding] = []
    for sf in files:
        if not sf.in_repro or sf.rel == owner_rel \
                or sf.rel.startswith("src/repro/lint/"):
            continue
        for node in ast.walk(sf.tree):
            got = _mutated_field(node)
            if got is None:
                continue
            base, field, line = got
            if field in fields and (base == hint
                                    or base.endswith("." + hint)):
                out.append(Finding(
                    "conf-mutator", sf.rel, line,
                    f"{lifecycle} state {base}.{field} mutated outside "
                    f"{owner_rel} — route through the owning scheduler"))
    return out


def check_mutators(files: Sequence[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    for sf in files:
        if sf.rel == "src/repro/core/node.py":
            out += _check_class_mutators(sf, "R5Scheduler", TR_ID_FIELDS,
                                         "tr_id")
        elif sf.rel == "src/repro/tenancy/banks.py":
            out += _check_class_mutators(sf, "BankManager", BANK_FIELDS,
                                         "bank")
    out += _check_foreign_mutations(files, "src/repro/core/node.py", "r5",
                                    TR_ID_FIELDS, "tr_id")
    out += _check_foreign_mutations(files, "src/repro/tenancy/banks.py",
                                    "banks", BANK_FIELDS, "bank")
    return out


def check_statuses(files: Sequence[SourceFile]) -> List[Finding]:
    out: List[Finding] = []
    errors = set(WC_ERROR_STATUSES)
    for sf in files:
        if not sf.in_repro:
            continue
        add_parents(sf.tree)
        for node in ast.walk(sf.tree):
            # ---- fail_transfer(transfer, <status>) call sites
            if isinstance(node, ast.Call) \
                    and call_name(node).endswith("fail_transfer") \
                    and not isinstance(parent(node), ast.FunctionDef):
                status = node.args[1] if len(node.args) > 1 else next(
                    (k.value for k in node.keywords if k.arg == "status"),
                    None)
                if status is None:
                    continue
                if isinstance(status, ast.Constant):
                    if status.value not in errors:
                        out.append(Finding(
                            "conf-status", sf.rel, node.lineno,
                            f"fail_transfer called with status "
                            f"{status.value!r} — spec allows "
                            f"{sorted(errors)}"))
                elif not (isinstance(status, ast.Call)
                          and call_name(status).endswith("_crash_status")):
                    out.append(Finding(
                        "conf-status", sf.rel, node.lineno,
                        "fail_transfer status is neither a spec'd "
                        "literal nor _crash_status(...) — the checker "
                        "cannot prove it is a legal WC status"))
            # ---- _crash_status return literals
            if isinstance(node, ast.FunctionDef) \
                    and node.name == "_crash_status":
                for ret in ast.walk(node):
                    if isinstance(ret, ast.Return) and ret.value is not None:
                        for c in ast.walk(ret.value):
                            if isinstance(c, ast.Constant) \
                                    and isinstance(c.value, str) \
                                    and c.value not in errors:
                                out.append(Finding(
                                    "conf-status", sf.rel, ret.lineno,
                                    f"_crash_status can return "
                                    f"{c.value!r}, not a spec'd WC error "
                                    f"status"))
        # ---- WCStatus enum mirrors the spec
        if sf.rel == "src/repro/api/completion.py":
            out += _check_wcstatus_enum(sf)
        # ---- invariants.FAILED_STATUSES mirrors the spec
        if sf.rel == "src/repro/testing/invariants.py":
            out += _check_failed_statuses(sf)
        # ---- fail_transfer leads with the exactly-once guard
        if sf.rel == "src/repro/core/node.py":
            out += _check_exactly_once_guard(sf)
    return out


def _check_wcstatus_enum(sf: SourceFile) -> List[Finding]:
    want = {WC_SUCCESS} | set(WC_ERROR_STATUSES)
    cls = next((n for n in ast.walk(sf.tree)
                if isinstance(n, ast.ClassDef) and n.name == "WCStatus"),
               None)
    if cls is None:
        return [Finding("conf-status", sf.rel, 1,
                        "WCStatus enum not found in api/completion.py")]
    got = {}
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name) \
                and isinstance(stmt.value, ast.Constant) \
                and isinstance(stmt.value.value, str):
            got[stmt.targets[0].id] = stmt.value.value
    out = []
    if set(got.values()) != want:
        out.append(Finding(
            "conf-status", sf.rel, cls.lineno,
            f"WCStatus values {sorted(got.values())} != spec "
            f"{sorted(want)}"))
    for name, value in sorted(got.items()):
        if name != value.upper():
            out.append(Finding(
                "conf-status", sf.rel, cls.lineno,
                f"WCStatus.{name} = {value!r}: member name must be the "
                f"uppercased wire string"))
    return out


def _check_failed_statuses(sf: SourceFile) -> List[Finding]:
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "FAILED_STATUSES":
            if isinstance(node.value, ast.Set):
                got = {e.value for e in node.value.elts
                       if isinstance(e, ast.Constant)}
                if got != set(WC_ERROR_STATUSES):
                    return [Finding(
                        "conf-status", sf.rel, node.lineno,
                        f"invariants.FAILED_STATUSES {sorted(got)} != "
                        f"spec {sorted(WC_ERROR_STATUSES)}")]
            return []
    return [Finding("conf-status", sf.rel, 1,
                    "invariants.FAILED_STATUSES not found")]


def _check_exactly_once_guard(sf: SourceFile) -> List[Finding]:
    fn = next((n for n in ast.walk(sf.tree)
               if isinstance(n, ast.FunctionDef)
               and n.name == "fail_transfer"), None)
    if fn is None:
        return [Finding("conf-status", sf.rel, 1,
                        "R5Scheduler.fail_transfer not found")]
    body = [s for s in fn.body
            if not (isinstance(s, ast.Expr)
                    and isinstance(s.value, ast.Constant))]   # skip docstring
    ok = False
    if body and isinstance(body[0], ast.If) \
            and body[0].body and isinstance(body[0].body[0], ast.Return):
        names = {n.attr for n in ast.walk(body[0].test)
                 if isinstance(n, ast.Attribute)}
        ok = {"failed_status", "complete"} <= names
    if not ok:
        return [Finding(
            "conf-status", sf.rel, fn.lineno,
            "fail_transfer must START with the exactly-once guard "
            "(return if failed_status is set or the transfer completed) "
            "— anything before it can run twice")]
    return []


def run(files: Sequence[SourceFile]) -> List[Finding]:
    findings, _ = extract_block_transitions(files)
    findings += check_state_names(files)
    findings += check_mutators(files)
    findings += check_statuses(files)
    return findings
