"""``stats-coverage``: every telemetry counter is checked or exempted.

The soak harness regresses on ``*Stats`` counters, so a counter that
silently stops moving (or double-counts) is a bug the test suite can't
see unless some invariant reads it.  This pass cross-references:

* **counters** — class-level ``field: int``/``float`` annotations on
  every class named ``*Stats`` under ``src/repro/``;
* **checked** — attribute names read anywhere in
  ``src/repro/testing/invariants.py``, plus the members of a class's
  ``ADDITIVE`` tuple when the invariants access ``Cls.ADDITIVE``
  (the additive-sum checkers iterate it with ``getattr``);
* **exempt** — :data:`repro.lint.specs.STATS_EXEMPT` rows, each with a
  stated reason (``"*"`` covers a whole telemetry-only class).

Coverage is by *field name*, not by class: a name read by any checker
counts everywhere it appears.  That coarseness only ever errs toward
silence, and the stale/redundant-exemption findings below keep the
exemption table from absorbing the slack:

* a row naming a field that no longer exists → the table rotted;
* a row naming a field the invariants DO read → the row is dead weight.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Sequence, Set, Tuple

from repro.lint.common import Finding, SourceFile
from repro.lint.specs import STATS_EXEMPT

INVARIANTS_PATH = "src/repro/testing/invariants.py"


def _counter_fields(sf: SourceFile) -> List[Tuple[str, str, int]]:
    """(class, field, line) for every int/float counter annotation."""
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.ClassDef)
                and node.name.endswith("Stats")):
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and isinstance(stmt.annotation, ast.Name) \
                    and stmt.annotation.id in ("int", "float"):
                out.append((node.name, stmt.target.id, stmt.lineno))
    return out


def _additive_members(sf: SourceFile) -> Dict[str, Set[str]]:
    """Class name -> members of its ``ADDITIVE`` tuple, if any."""
    out: Dict[str, Set[str]] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "ADDITIVE"
                            for t in stmt.targets) \
                    and isinstance(stmt.value, ast.Tuple):
                out[node.name] = {
                    e.value for e in stmt.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                }
    return out


def _checked_names(inv: SourceFile,
                   additive: Dict[str, Set[str]]) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(inv.tree):
        if isinstance(node, ast.Attribute):
            names.add(node.attr)
            # `Cls.ADDITIVE` access pulls in that class's members
            if node.attr == "ADDITIVE" and isinstance(node.value, ast.Name):
                names |= additive.get(node.value.id, set())
    return names


def run(files: Sequence[SourceFile]) -> List[Finding]:
    inv = next((sf for sf in files if sf.rel == INVARIANTS_PATH), None)
    counters: List[Tuple[SourceFile, str, str, int]] = []
    additive: Dict[str, Set[str]] = {}
    for sf in files:
        if not sf.in_repro or sf.rel.startswith("src/repro/lint/"):
            continue
        for cls, field, line in _counter_fields(sf):
            counters.append((sf, cls, field, line))
        additive.update(_additive_members(sf))
    if inv is None:
        # the CLI always passes src/; fixture runs may scope narrower
        return [] if not counters else [Finding(
            "stats-coverage", counters[0][0].rel, counters[0][3],
            f"{INVARIANTS_PATH} not in the scanned set — cannot prove "
            f"any counter is checked")]
    checked = _checked_names(inv, additive)

    out: List[Finding] = []
    by_class: Dict[str, Dict[str, int]] = {}
    for sf, cls, field, line in counters:
        by_class.setdefault(cls, {})[field] = line
        if field in checked:
            continue
        row = STATS_EXEMPT.get(cls, {})
        if field in row or "*" in row:
            continue
        out.append(Finding(
            "stats-coverage", sf.rel, line,
            f"{cls}.{field} is read by no invariant checker and carries "
            f"no exemption — add a check to testing/invariants.py or a "
            f"justified row to lint/specs.py:STATS_EXEMPT"))

    # exemption-table hygiene (findings anchor to the specs module)
    specs_rel = "src/repro/lint/specs.py"
    for cls, rows in sorted(STATS_EXEMPT.items()):
        fields = by_class.get(cls)
        if fields is None:
            out.append(Finding(
                "stats-coverage", specs_rel, 1,
                f"STATS_EXEMPT names unknown stats class {cls!r}"))
            continue
        for field in sorted(rows):
            if field == "*":
                if all(f in checked for f in fields):
                    out.append(Finding(
                        "stats-coverage", specs_rel, 1,
                        f"STATS_EXEMPT[{cls!r}] wildcard is redundant — "
                        f"every field is checked by the invariants"))
                continue
            if field not in fields:
                out.append(Finding(
                    "stats-coverage", specs_rel, 1,
                    f"STATS_EXEMPT[{cls!r}] names missing field "
                    f"{field!r} — stale exemption"))
            elif field in checked:
                out.append(Finding(
                    "stats-coverage", specs_rel, 1,
                    f"STATS_EXEMPT[{cls!r}][{field!r}] is redundant — "
                    f"the invariants read this field"))
    return out
