"""``repro.lint`` — protocol conformance + determinism static analysis.

Three layers guard the simulator's two core contracts (byte-identical
determinism per seed, and protocol behaviour that matches the paper's
fault-handling state machines):

* **static passes** (``determinism``, ``typed_errors``,
  ``stats_coverage``, ``conformance``) — pure-stdlib AST analysis run
  by ``python -m repro.lint`` as a blocking CI gate;
* **spec model checker** (``model``) — exhaustively walks the product
  of the four lifecycle specs in ``specs.py`` across every fault ×
  budget × crash × steal scenario;
* **race sanitizer** (``race``) — opt-in EventLoop instrumentation
  (``FabricConfig(race_check=True)``) that reports same-timestamp
  event pairs whose relative order is load-bearing.

The specs in :mod:`repro.lint.specs` are the single source of truth;
the README lifecycle tables render them and the conformance pass holds
the implementation to them.
"""

from repro.lint.common import (KNOWN_RULES, Finding, SourceFile,
                               collect_files)
from repro.lint.specs import ALL_SPECS, BANK, BLOCK, TR_ID, WR

__all__ = [
    "ALL_SPECS", "BANK", "BLOCK", "Finding", "KNOWN_RULES", "SourceFile",
    "TR_ID", "WR", "collect_files",
]
