"""Determinism linter: AST rules over ``src/repro/``.

The simulator's contract is byte-identical soak stats per seed
(``SoakResult.json()``).  Anything that lets *incidental* order — hash
randomization, wall-clock, object addresses, heap ties — leak into event
order or stats breaks that contract, usually long after the commit that
planted it.  These rules flag the known hazard shapes:

``det-set-iter``
    Iterating a set (literal, comprehension, ``set()``/``frozenset()``
    call, set algebra) in an order-sensitive position.  String hashes are
    randomized per process; object hashes are addresses.
``det-dict-iter``
    Iterating ``.keys()``/``.values()``/``.items()`` in an
    order-sensitive position in an event-path module.  Insertion order
    *is* deterministic, which is exactly why unsorted dict iteration
    passes every test until a refactor reorders the insertions — the
    rule enforces ``sorted(...)`` (or an order-insensitive consumer) so
    the event path never depends on insertion history.
``det-wallclock``
    ``time.time``/``monotonic``/``perf_counter``, ``datetime.now`` etc.
``det-unseeded-random``
    Module-level ``random.*`` / ``numpy.random.*`` (the process-global,
    implicitly-seeded generators).  Seeded ``random.Random(seed)``
    instances and key-passing ``jax.random`` are fine.
``det-id-order``
    ``id(...)`` used as a key/ordering token in an event-path module.
    CPython reuses addresses after GC, so two live-at-different-times
    objects can compare equal.  Equality-only dedup against a set of
    live objects is exempt.
``det-heap-tiebreak``
    ``heapq.heappush`` of a key that can compare equal without a unique
    tiebreaker (the loop's ``(time, seq, event)`` shape is the good
    example: ``seq`` is unique, so ties never reach the event compare).

Order-*insensitive* consumers are exempt everywhere: ``sorted``, ``min``,
``max``, ``sum``, ``len``, ``any``, ``all``, ``set``, ``frozenset``,
membership tests, and set-building comprehensions.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Sequence, Set, Tuple

from repro.lint.common import (Finding, SourceFile, add_parents, call_name,
                               dotted_name, parent)

#: callables whose result does not depend on argument iteration order
ORDER_INSENSITIVE_CALLS = {
    "sorted", "min", "max", "sum", "len", "any", "all", "set", "frozenset",
}

_WALLCLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "datetime.now",
    "datetime.utcnow", "datetime.today", "date.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}

_GLOBAL_RANDOM = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "getrandbits",
    "betavariate", "expovariate", "triangular", "vonmisesvariate",
}

_SET_ALGEBRA_METHODS = {"union", "intersection", "difference",
                        "symmetric_difference"}

_MUTATOR_EXEMPT_METHODS = {"add", "discard", "remove", "update"}


def _is_setlike(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in ("set", "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _SET_ALGEBRA_METHODS:
            return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_setlike(node.left) or _is_setlike(node.right)
    return False


def _is_dict_view(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("keys", "values", "items")
            and not node.args and not node.keywords)


def _consumer_is_order_insensitive(node: ast.AST) -> bool:
    """Walk outward from an iterable expression: is everything between it
    and its consumer order-insensitive?"""
    cur, up = node, parent(node)
    while up is not None:
        if isinstance(up, ast.Call) and cur in up.args:
            name = call_name(up)
            if name in ORDER_INSENSITIVE_CALLS:
                return True
            if isinstance(up.func, ast.Attribute) \
                    and up.func.attr in ("update", "union", "intersection",
                                         "difference", "issubset",
                                         "issuperset", "isdisjoint"):
                # set/dict .update() and set algebra are order-insensitive
                # (dict.update is insertion-order preserving — the callee
                # dict's determinism is its own iteration's concern)
                return True
            return False
        if isinstance(up, ast.Compare) and cur in up.comparators \
                and all(isinstance(op, (ast.In, ast.NotIn)) for op in up.ops):
            return True                      # membership test
        if isinstance(up, ast.comprehension):
            # ``cur`` is the .iter of a comprehension clause; the consumer
            # of the produced elements is the comprehension expression
            comp = parent(up)
            if isinstance(comp, (ast.SetComp, ast.DictComp)):
                # building a set/dict: the *result* is order-free (sets)
                # or will face this rule at ITS consumption site (dicts
                # rebuilt key-by-value keep determinism questions local)
                return True
            cur, up = comp, parent(comp)     # genexp/listcomp: its consumer
            continue
        if isinstance(up, (ast.SetComp, ast.DictComp)):
            return True
        if isinstance(up, (ast.GeneratorExp, ast.ListComp)):
            cur, up = up, parent(up)         # look at the lazy consumer
            continue
        if isinstance(up, ast.For):
            return False                     # plain ordered loop
        if isinstance(up, ast.Starred):
            cur, up = up, parent(up)
            continue
        return False
    return False


def _iteration_sites(tree: ast.AST) -> "Iterator[Tuple[ast.expr, int]]":
    """Yield (iter_expr, line) for every ordered-iteration position."""
    for node in ast.walk(tree):
        if isinstance(node, ast.For):
            yield node.iter, node.lineno
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for comp in node.generators:
                yield comp.iter, getattr(comp.iter, "lineno", node.lineno)
        elif isinstance(node, ast.Call) and call_name(node) in (
                "list", "tuple", "enumerate", "reversed"):
            for arg in node.args[:1]:
                yield arg, getattr(arg, "lineno", node.lineno)


def _check_set_and_dict_iter(sf: SourceFile) -> List[Finding]:
    out = []
    for expr, line in _iteration_sites(sf.tree):
        if _is_setlike(expr):
            target = expr
        elif sf.in_event_path and _is_dict_view(expr):
            target = expr
        else:
            continue
        if _consumer_is_order_insensitive(target):
            continue
        rule = "det-set-iter" if _is_setlike(target) else "det-dict-iter"
        what = ("a set" if rule == "det-set-iter"
                else f"dict .{target.func.attr}()")       # type: ignore
        out.append(Finding(
            rule, sf.rel, line,
            f"iteration over {what} in an order-sensitive position — "
            f"wrap in sorted(...) or consume order-insensitively"))
    return out


def _check_wallclock(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Attribute):
            name = dotted_name(node)
            if name in _WALLCLOCK:
                out.append(Finding(
                    "det-wallclock", sf.rel, node.lineno,
                    f"wall-clock read {name}() — simulated components "
                    f"must use EventLoop.now (virtual time)"))
    return out


def _check_unseeded_random(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Attribute):
            continue
        name = dotted_name(node)
        base, _, attr = name.rpartition(".")
        if base == "random" and attr in _GLOBAL_RANDOM:
            out.append(Finding(
                "det-unseeded-random", sf.rel, node.lineno,
                f"module-level random.{attr} uses the process-global "
                f"generator — pass a seeded random.Random instance"))
        elif base in ("np.random", "numpy.random") \
                and attr not in ("default_rng", "Generator", "SeedSequence"):
            out.append(Finding(
                "det-unseeded-random", sf.rel, node.lineno,
                f"global numpy random {name} — use "
                f"np.random.default_rng(seed)"))
    return out


def _check_id_order(sf: SourceFile) -> List[Finding]:
    out = []
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "id" and len(node.args) == 1):
            continue
        up = parent(node)
        # equality-only dedup is safe while the objects stay live: id()
        # membership tests and set.add/discard/remove never order anything
        if isinstance(up, ast.Compare) and all(
                isinstance(op, (ast.Eq, ast.NotEq, ast.In, ast.NotIn, ast.Is,
                                ast.IsNot))
                for op in up.ops):
            continue
        if isinstance(up, ast.Call) and isinstance(up.func, ast.Attribute) \
                and up.func.attr in _MUTATOR_EXEMPT_METHODS:
            continue
        out.append(Finding(
            "det-id-order", sf.rel, node.lineno,
            "id(...) used as a key/ordering token — CPython reuses "
            "addresses after GC; derive a stable key from the object's "
            "own identity (tid, index, node_id, ...)"))
    return out


def _names_assigned_from(func: Optional[ast.AST],
                         callees: Sequence[str]) -> Set[str]:
    """Names bound (anywhere in ``func``) from a call to one of
    ``callees`` — e.g. ``seq = next(...)``, ``entry = heapq.heappop(h)``."""
    if func is None:
        return set()
    names: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and call_name(node.value) in callees:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
    return names


def _has_unique_tiebreak(item: ast.AST, func: Optional[ast.AST]) -> bool:
    if isinstance(item, ast.Name) and item.id in _names_assigned_from(
            func, ("heapq.heappop", "heappop")):
        return True        # re-pushing an entry that was already well-formed
    if not isinstance(item, ast.Tuple):
        return False
    next_names = _names_assigned_from(func, ("next",))
    for el in item.elts:
        if isinstance(el, ast.Call) and call_name(el) == "next":
            return True
        name = el.id if isinstance(el, ast.Name) else (
            el.attr if isinstance(el, ast.Attribute) else "")
        if name in next_names or "seq" in name or "counter" in name:
            return True
    return False


def _check_heap_tiebreak(sf: SourceFile) -> List[Finding]:
    out = []
    from repro.lint.common import enclosing_function
    for node in ast.walk(sf.tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) in ("heapq.heappush", "heappush")
                and len(node.args) == 2):
            continue
        item = node.args[1]
        if _has_unique_tiebreak(item, enclosing_function(node)):
            continue
        out.append(Finding(
            "det-heap-tiebreak", sf.rel, node.lineno,
            "heap push without a unique tiebreaker — equal keys fall "
            "back to object comparison (or raise); push "
            "(key, next(counter), payload) tuples"))
    return out


def run(files: Sequence[SourceFile]) -> List[Finding]:
    """All determinism rules over every ``src/repro/`` file given."""
    out: List[Finding] = []
    for sf in files:
        if not sf.in_repro:
            continue
        add_parents(sf.tree)
        out += _check_set_and_dict_iter(sf)
        out += _check_wallclock(sf)
        out += _check_unseeded_random(sf)
        if sf.in_event_path:
            out += _check_id_order(sf)
        out += _check_heap_tiebreak(sf)
    return out
