import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before ANY other import: jax locks the
# device count at first init, and the dry-run needs 512 placeholder host
# devices to build the production meshes.  (Tests/benches import other
# modules and correctly see 1 device.)

import argparse            # noqa: E402
import dataclasses         # noqa: E402
import json                # noqa: E402
import time                # noqa: E402
import traceback           # noqa: E402
from typing import Optional  # noqa: E402

import jax                 # noqa: E402
import jax.numpy as jnp    # noqa: E402
import numpy as np         # noqa: E402

from repro.analysis.hlo import analyze_hlo                     # noqa: E402
from repro.compat import cost_analysis_dict                    # noqa: E402
from repro.configs import ARCH_IDS, get_config                 # noqa: E402
from repro.configs.shapes import SHAPES, shapes_for, skip_reason  # noqa: E402
from repro.distributed.logical import logical_rules                 # noqa: E402
from repro.distributed.sharding import (cache_shardings,       # noqa: E402
                                        param_shardings,
                                        token_sharding)
from repro.launch.mesh import (HBM_PER_CHIP, HBM_BW, ICI_BW_PER_LINK,  # noqa: E402
                               PEAK_FLOPS_BF16, make_production_mesh)
from repro.launch.specs import input_specs, params_specs       # noqa: E402
from repro.models.registry import model_for                    # noqa: E402
from repro.optim import adamw                                  # noqa: E402
from repro.optim.adamw import AdamWConfig                      # noqa: E402
from repro.training.trainer import TrainConfig, make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Per-arch training knobs (activation-memory napkin math in EXPERIMENTS.md
# §Dry-run): microbatch counts keep layer-boundary residuals under HBM.
MICROBATCHES = {
    "chameleon_34b": 16, "codeqwen15_7b": 4, "qwen3_14b": 2,
    "starcoder2_3b": 2, "h2o_danube_1_8b": 4, "mixtral_8x7b": 4,
    "deepseek_v3_671b": 16, "zamba2_7b": 4, "xlstm_125m": 1,
    "whisper_medium": 8,
}
# FSDP/ZeRO-3 param+moment sharding for the larger archs
ZERO3 = {"chameleon_34b", "codeqwen15_7b", "qwen3_14b", "mixtral_8x7b",
         "deepseek_v3_671b", "zamba2_7b"}
# bf16 moments for the biggest (MaxText convention)
BF16_MOMENTS = {"deepseek_v3_671b", "chameleon_34b"}


def _train_config(arch: str) -> TrainConfig:
    return TrainConfig(
        microbatches=MICROBATCHES.get(arch, 1),
        remat=True,
        optimizer=AdamWConfig(
            moment_dtype="bfloat16" if arch in BF16_MOMENTS else "float32"))


def logical_rules_for(cfg, mesh) -> dict:
    """Bind logical activation axes to mesh axes per arch (DESIGN.md §3).

    heads→'model' when the head count divides TP; otherwise the query
    sequence is context-parallel over 'model' (starcoder2's 24 heads,
    qwen3's 40 heads).  KV stays replicated in that case (cheap: GQA).
    """
    d = [a for a in ("pod", "data") if a in mesh.shape]
    batch_ax = tuple(d) if len(d) > 1 else (d[0] if d else None)
    m = mesh.shape["model"]
    rules = {"batch": batch_ax, "ff": "model", "moe_ff": "model"}
    data = mesh.shape.get("data", 1)
    if cfg.n_experts and cfg.n_experts % (m * data) == 0:
        rules["experts"] = ("model", "data")   # matches 2-D EP weights
    elif cfg.n_experts and cfg.n_experts % m == 0:
        rules["experts"] = "model"
    if cfg.n_heads % m == 0:
        rules["heads"] = "model"
        if cfg.n_kv_heads % m == 0:
            rules["kv_heads"] = "model"
    else:
        rules["q_seq"] = "model"
    return rules


def build_lowerable(arch: str, shape_name: str, mesh):
    """Returns (fn, arg_specs, in_shardings, donate_argnums)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = model_for(cfg)
    bundle = input_specs(cfg, shape)
    p_specs = params_specs(cfg)
    p_sh = param_shardings(p_specs, mesh, zero3=arch in ZERO3)
    dsize = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                         if a in mesh.shape]))
    batch_shardable = shape.global_batch % dsize == 0
    tok_sh = token_sharding(mesh, shardable_batch=batch_shardable)

    if shape.kind == "train":
        tcfg = _train_config(arch)
        # per-microbatch batch must still divide the data axes
        m = tcfg.microbatches
        while m > 1 and (shape.global_batch // m) % max(1, dsize) != 0:
            m //= 2
        if m != tcfg.microbatches:
            tcfg = dataclasses.replace(tcfg, microbatches=m)
        step = make_train_step(cfg, tcfg)
        opt_specs = jax.eval_shape(
            lambda: adamw.init(tcfg.optimizer, p_specs))
        opt_sh = adamw.AdamWState(
            step=jax.sharding.NamedSharding(mesh,
                                            jax.sharding.PartitionSpec()),
            mu=jax.tree_util.tree_map(lambda s, sh: sh, opt_specs.mu, p_sh),
            nu=jax.tree_util.tree_map(lambda s, sh: sh, opt_specs.nu, p_sh))
        args = (p_specs, opt_specs) + bundle.args
        in_sh = (p_sh, opt_sh) + (tok_sh,) * 2
        if cfg.is_encdec:
            in_sh = in_sh + (jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(
                    tok_sh.spec[0], None, None)),)
        fn = step
        out_sh = (p_sh, opt_sh, None)
        return fn, args, in_sh, out_sh, (0, 1)

    if shape.kind == "prefill":
        def fn(params, tokens, *extra):
            kw = {}
            if cfg.is_encdec:
                kw["frame_embeddings"] = extra[0]
            logits, _ = model.forward(params, cfg, tokens, **kw)
            return logits
        args = (p_specs,) + bundle.args
        in_sh = (p_sh, tok_sh)
        if cfg.is_encdec:
            in_sh = in_sh + (jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(tok_sh.spec[0], None,
                                                 None)),)
        return fn, args, in_sh, None, ()

    # decode
    def fn(params, cache, tokens):
        return model.decode_step(params, cfg, cache, tokens)
    c_sh = cache_shardings(bundle.cache, mesh, shape.global_batch)
    args = (p_specs, bundle.cache) + bundle.args
    in_sh = (p_sh, c_sh, tok_sh)
    out_sh = (None, c_sh)
    return fn, args, in_sh, out_sh, (1,)


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    out = {}
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind}
    if reason:
        rec["status"] = "skip"
        rec["skip_reason"] = reason
        _save(rec, save)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    # lint: allow(det-wallclock): host compile timing, never sim state
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate = build_lowerable(arch, shape_name,
                                                          mesh)
        rules = logical_rules_for(cfg, mesh)
        rec["logical_rules"] = {k: str(v) for k, v in rules.items()}
        with mesh, logical_rules(mesh, rules):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            # lint: allow(det-wallclock): host compile timing
            t_lower = time.time() - t0
            compiled = lowered.compile()
            # lint: allow(det-wallclock): host compile timing
            t_compile = time.time() - t0 - t_lower
        ca = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        ana = analyze_hlo(hlo)
        mem = _memory_dict(compiled)

        per_dev_bytes = sum(mem.get(k, 0) for k in
                            ("argument_size_in_bytes", "temp_size_in_bytes",
                             "output_size_in_bytes")) \
            - mem.get("alias_size_in_bytes", 0)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        n_active = cfg.active_param_count()
        model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens

        flops_dev = ana.dot_flops
        compute_term = flops_dev / PEAK_FLOPS_BF16
        memory_term = ana.hbm_bytes / HBM_BW
        collective_term = ana.collective_bytes / ICI_BW_PER_LINK
        terms = {"compute_s": compute_term, "memory_s": memory_term,
                 "collective_s": collective_term}
        dominant = max(terms, key=terms.get)

        rec.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost_analysis_flops": float(ca.get("flops", -1.0)),
            "cost_analysis_bytes": float(ca.get("bytes accessed", -1.0)),
            "hlo_dot_flops_per_dev": flops_dev,
            "hlo_hbm_bytes_per_dev": ana.hbm_bytes,
            "hlo_collective_bytes_per_dev": ana.collective_bytes,
            "collective_breakdown": ana.collective_breakdown,
            "memory_analysis": mem,
            "per_device_bytes": int(per_dev_bytes),
            "fits_hbm": bool(per_dev_bytes <= HBM_PER_CHIP),
            "model_flops_total": float(model_flops),
            "useful_flops_ratio": float(model_flops
                                        / max(1.0, flops_dev * n_dev)),
            "roofline_terms_s": terms,
            "dominant_term": dominant,
        })
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool) -> None:
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in shapes_for(cfg)]
                  + [s for s in SHAPES
                     if skip_reason(cfg, SHAPES[s])])
        for shape_name in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape_name, multi)
                status = rec["status"]
                mesh_name = rec["mesh"]
                if status == "ok":
                    mem = rec["per_device_bytes"] / 2**30
                    print(f"[OK]   {arch:18s} {shape_name:12s} {mesh_name:10s}"
                          f" compile={rec['compile_s']:.1f}s"
                          f" mem/dev={mem:.2f}GiB fits={rec['fits_hbm']}"
                          f" dom={rec['dominant_term']}")
                elif status == "skip":
                    print(f"[SKIP] {arch:18s} {shape_name:12s} {mesh_name:10s}"
                          f" ({rec['skip_reason'][:60]})")
                else:
                    failures += 1
                    print(f"[FAIL] {arch:18s} {shape_name:12s} {mesh_name:10s}"
                          f" {rec['error'][:140]}")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
