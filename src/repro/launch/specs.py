"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  For training that is {tokens, labels} (+ stub frame
embeddings for the [audio] arch); for decode it is the token batch plus
the full decode-cache pytree obtained via ``jax.eval_shape`` over
``init_decode_cache`` (still no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import Shape
from repro.models.config import ModelConfig
from repro.models.registry import model_for


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


@dataclasses.dataclass
class SpecBundle:
    kind: str                      # train | prefill | decode
    args: tuple                    # positional arg specs for the step fn
    cache: Any = None              # decode-cache spec pytree (decode only)


def train_specs(cfg: ModelConfig, shape: Shape) -> SpecBundle:
    B, S = shape.global_batch, shape.seq_len
    args = [_sds((B, S), jnp.int32), _sds((B, S), jnp.int32)]
    if cfg.is_encdec:
        args.append(_sds((B, cfg.max_source_positions, cfg.d_model),
                         jnp.bfloat16 if cfg.dtype == "bfloat16"
                         else jnp.float32))
    return SpecBundle("train", tuple(args))


def prefill_specs(cfg: ModelConfig, shape: Shape) -> SpecBundle:
    B, S = shape.global_batch, shape.seq_len
    args = [_sds((B, S), jnp.int32)]
    if cfg.is_encdec:
        args.append(_sds((B, cfg.max_source_positions, cfg.d_model),
                         jnp.bfloat16 if cfg.dtype == "bfloat16"
                         else jnp.float32))
    return SpecBundle("prefill", tuple(args))


def decode_specs(cfg: ModelConfig, shape: Shape) -> SpecBundle:
    B, S = shape.global_batch, shape.seq_len
    model = model_for(cfg)
    cache_spec = jax.eval_shape(
        lambda: model.init_decode_cache(cfg, B, S))
    tokens = _sds((B, 1), jnp.int32)
    return SpecBundle("decode", (tokens,), cache=cache_spec)


def input_specs(cfg: ModelConfig, shape: Shape) -> SpecBundle:
    if shape.kind == "train":
        return train_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


def params_specs(cfg: ModelConfig):
    model = model_for(cfg)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: model.init_params(cfg, key))
