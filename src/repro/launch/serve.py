"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching engine over a reduced config with a paged,
host-spillable KV pool — exercising the thesis mechanism end to end:
admission, prefill, pool exhaustion → spill, re-activation → Touch-Ahead
page-in, decode through the page table.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.api import FaultPolicy, Strategy
from repro.configs import ARCH_IDS, get_config
from repro.models.config import reduced
from repro.models.registry import model_for
from repro.serving.engine import ServingEngine
from repro.serving.sampler import SamplerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--pool-frames", type=int, default=0,
                    help="undersize to force spills (0 = exact fit)")
    ap.add_argument("--strategy", default="touch_ahead",
                    choices=[s.value for s in Strategy])
    ap.add_argument("--lookahead", type=int, default=4,
                    help="pages per fault event (TOUCH_AHEAD_N / STREAM)")
    ap.add_argument("--pin-all", action="store_true",
                    help="pinning baseline: admission-controlled residency")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = model_for(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    policy = FaultPolicy(strategy=Strategy(args.strategy),
                         lookahead=args.lookahead)
    eng = ServingEngine(
        cfg, params, max_batch=args.max_batch, max_len=args.max_len,
        pool_frames=args.pool_frames or None,
        policy=policy, pin_all=args.pin_all,
        sampler=SamplerConfig(temperature=args.temperature))

    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=rng.integers(3, 9)),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    eng.run_until_done()
    for r in reqs:
        print(f"req {r.req_id}: prompt[{len(r.prompt)}] -> {r.generated}")
    s = eng.stats
    print(f"\nstats: prefills={s.prefills} decode_steps={s.decode_steps} "
          f"tokens={s.tokens_generated} spills={s.spill_events} "
          f"fault_page_ins={s.fault_page_ins} "
          f"sim_fault_us={s.simulated_fault_us:.1f}")


if __name__ == "__main__":
    main()
