"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.
"""

from __future__ import annotations

import jax

from repro.compat import axis_types_kwargs

__all__ = ["axis_types_kwargs", "make_local_mesh", "make_production_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kwargs(len(axes)))


def make_local_mesh(model: int = 1):
    """Whatever this process has (tests/examples: 1 CPU device)."""
    n = jax.device_count()
    return jax.make_mesh(
        (n // model, model), ("data", "model"), **axis_types_kwargs(2))


# TPU v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~)
HBM_PER_CHIP = 16 * 1024**3      # bytes
