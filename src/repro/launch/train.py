"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains a reduced config for real (loss curves,
checkpoints); on a TPU slice the same entry point builds the production
mesh, applies the sharding rules, and runs the full config — the dry-run
(launch/dryrun.py) is exactly this path lowered with ShapeDtypeStructs.
"""

from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import ShardInfo, SyntheticLM
from repro.distributed.checkpoint import Checkpointer
from repro.models.config import reduced
from repro.models.registry import model_for
from repro.optim.adamw import AdamWConfig
from repro.optim.schedules import cosine_with_warmup
from repro.training.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced config (CPU default)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, n_layers=4, d_model=128, d_ff=256 if cfg.d_ff else 0,
                      vocab_size=512)
    model = model_for(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} family={cfg.family} params={n_params:,}")

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        optimizer=AdamWConfig(
            lr=args.lr,
            schedule=cosine_with_warmup(args.lr, 20, args.steps)))
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                     ShardInfo(0, 1), seed=args.seed)
    ckpt = Checkpointer() if args.checkpoint_dir else None
    tr = Trainer(cfg, tcfg, params, ds, checkpoint_dir=args.checkpoint_dir,
                 checkpoint_every=args.checkpoint_every, checkpointer=ckpt)
    if args.resume and ckpt is not None:
        restored = ckpt.restore_latest(args.checkpoint_dir, tr.params,
                                       tr.opt_state)
        if restored is not None:
            tr.params, tr.opt_state, tr.step = restored
            print(f"resumed from step {tr.step}")
    hist = tr.run(args.steps, log_every=10)
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(start {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
