"""Reusable experiment drivers mirroring the thesis' microbenchmarks.

Each function runs one configuration of the Chapter-4 methodology:

* "Real" measurements (Listing 4.2) — per-iteration timing including the
  page-fault handling on the critical path;
* buffers prepared per :class:`~repro.core.engine.BufferPrep`
  (pre-touched / pinned / left faulting at source, destination, or both);
* intra-node transfers (one FPGA), matching the thesis setup, unless
  ``n_nodes``/``hops`` say otherwise.

The simulator is deterministic, so one iteration per configuration is
exact; ``iterations`` exists for THP/randomized variants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import addresses as A
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL, cost_model_with_timeout
from repro.core.engine import BufferPrep, RDMAEngine
from repro.core.node import TransferStats
from repro.core.resolver import Strategy

# the thesis' transfer-size sweep (Chapter 4)
SIZES = (16, 64, 256, 1024, 4096, 16384, 32768, 65536)

SRC_BASE = 0x10_0000_0000
DST_BASE = 0x20_0000_0000


@dataclasses.dataclass
class RunResult:
    size: int
    latency_us: float            # transfer-only latency (Listing 4.2 style)
    prep_us: float               # buffer prep cost, reported separately
    stats: TransferStats


def run_remote_write(size: int,
                     src_prep: BufferPrep,
                     dst_prep: BufferPrep,
                     strategy: Strategy = Strategy.TOUCH_AHEAD,
                     timeout_us: Optional[float] = None,
                     cost: Optional[CostModel] = None,
                     n_nodes: int = 1,
                     lookahead: int = A.PAGES_PER_BLOCK,
                     hupcf: bool = True) -> RunResult:
    """One remote write with the given buffer preparation, to completion."""
    if cost is None:
        cost = (cost_model_with_timeout(timeout_us) if timeout_us is not None
                else DEFAULT_COST_MODEL)
    eng = RDMAEngine(n_nodes=max(1, n_nodes), strategy=strategy, cost=cost,
                     lookahead=lookahead, hupcf=hupcf)
    dst_node = 0 if n_nodes <= 1 else 1
    pd = 1
    prep_src = eng.map_buffer(0, pd, SRC_BASE, size, prep=src_prep)
    prep_dst = eng.map_buffer(dst_node, pd, DST_BASE, size, prep=dst_prep)
    t0 = eng.loop.now
    t = eng.remote_write(pd, 0, SRC_BASE, dst_node, DST_BASE, size)
    stats = eng.run_transfer(t)
    return RunResult(size=size, latency_us=stats.t_complete - t0,
                     prep_us=prep_src.total_us + prep_dst.total_us,
                     stats=stats)


def fault_sweep(where: str, strategy: Strategy,
                timeout_us: float = A.DEFAULT_TIMEOUT_US,
                sizes=SIZES, **kw) -> list[RunResult]:
    """The Fig 4.2/4.3/4.4 experiments: faults at dst / src / both."""
    src_prep = BufferPrep.FAULTING if where in ("src", "both") else BufferPrep.TOUCHED
    dst_prep = BufferPrep.FAULTING if where in ("dst", "both") else BufferPrep.TOUCHED
    return [run_remote_write(s, src_prep, dst_prep, strategy=strategy,
                             timeout_us=timeout_us, **kw) for s in sizes]


def ideal_sweep(prep: BufferPrep = BufferPrep.TOUCHED, sizes=SIZES,
                **kw) -> list[RunResult]:
    """Fig 4.1: no faults during the RDMA (pre-touched or pinned buffers)."""
    return [run_remote_write(s, prep, prep, **kw) for s in sizes]
