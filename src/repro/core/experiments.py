"""Reusable experiment drivers mirroring the thesis' microbenchmarks.

Each function runs one configuration of the Chapter-4 methodology:

* "Real" measurements (Listing 4.2) — per-iteration timing including the
  page-fault handling on the critical path;
* buffers prepared per :class:`~repro.core.engine.BufferPrep`
  (pre-touched / pinned / left faulting at source, destination, or both);
* intra-node transfers (one FPGA), matching the thesis setup, unless
  ``n_nodes``/``hops`` say otherwise.

The simulator is deterministic, so one iteration per configuration is
exact; ``iterations`` exists for THP/randomized variants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import addresses as A
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL, cost_model_with_timeout
from repro.core.node import TransferStats
from repro.core.resolver import Strategy
from repro.api import BufferPrep, Fabric, FabricConfig, FaultPolicy

# the thesis' transfer-size sweep (Chapter 4)
SIZES = (16, 64, 256, 1024, 4096, 16384, 32768, 65536)

SRC_BASE = 0x10_0000_0000
DST_BASE = 0x20_0000_0000

#: fault-handling backends a sweep can be replayed under (``--backend``):
#: * ``rapf``      — the thesis datapath with whatever strategy the sweep
#:                   configured (SMMU faults + RAPF/timeout retransmission);
#: * ``np_rdma``   — the ``repro.npr`` no-pinning backend (MTT speculation
#:                   + DMA-pool abort-and-redirect);
#: * ``pin``       — pin every buffer up front (no faults; pin cost charged);
#: * ``pre_fault`` — pre-touch every buffer (no faults; touch cost charged).
BACKENDS = ("rapf", "np_rdma", "pin", "pre_fault")

_default_backend = "rapf"


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend every sweep inherits (the
    ``--backend`` flag of ``benchmarks/run.py``; per-file edits stay
    unnecessary because :func:`run_remote_write` consults this)."""
    global _default_backend
    if name not in BACKENDS:
        raise ValueError(
            f"unknown backend {name!r}; valid backends: {', '.join(BACKENDS)}")
    _default_backend = name


def default_backend() -> str:
    return _default_backend


def _apply_backend(backend: Optional[str], src_prep: BufferPrep,
                   dst_prep: BufferPrep, strategy: Strategy):
    """Resolve a backend name into (src_prep, dst_prep, strategy)."""
    backend = backend or _default_backend
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; valid backends: "
            f"{', '.join(BACKENDS)}")
    if backend == "np_rdma":
        strategy = Strategy.NP_RDMA
    elif backend == "pin":
        src_prep = dst_prep = BufferPrep.PINNED
    elif backend == "pre_fault":
        src_prep = dst_prep = BufferPrep.TOUCHED
    return src_prep, dst_prep, strategy


@dataclasses.dataclass
class RunResult:
    size: int
    latency_us: float            # transfer-only latency (Listing 4.2 style)
    prep_us: float               # buffer prep cost, reported separately
    stats: TransferStats


def run_remote_write(size: int,
                     src_prep: BufferPrep,
                     dst_prep: BufferPrep,
                     strategy: Strategy = Strategy.TOUCH_AHEAD,
                     timeout_us: Optional[float] = None,
                     cost: Optional[CostModel] = None,
                     n_nodes: int = 1,
                     lookahead: int = A.PAGES_PER_BLOCK,
                     hupcf: bool = True,
                     backend: Optional[str] = None,
                     config_overrides: Optional[dict] = None) -> RunResult:
    """One remote write with the given buffer preparation, to completion.

    ``backend`` (default: the process-wide :func:`default_backend`)
    replays the run under a different fault-handling datapath — see
    :data:`BACKENDS`.  ``config_overrides`` merges extra
    :class:`FabricConfig` kwargs (e.g. ``dma_pool_frames``,
    ``speculation``) for backend-sizing studies.
    """
    if cost is None:
        cost = (cost_model_with_timeout(timeout_us) if timeout_us is not None
                else DEFAULT_COST_MODEL)
    src_prep, dst_prep, strategy = _apply_backend(
        backend, src_prep, dst_prep, strategy)
    cfg_kw = dict(
        n_nodes=max(1, n_nodes), cost=cost, hupcf=hupcf,
        default_policy=FaultPolicy(strategy=strategy, lookahead=lookahead))
    cfg_kw.update(config_overrides or {})
    fabric = Fabric.build(FabricConfig(**cfg_kw))
    dst_node = 0 if n_nodes <= 1 else 1
    dom = fabric.open_domain(1)
    src = dom.register_memory(0, SRC_BASE, size, prep=src_prep)
    dst = dom.register_memory(dst_node, DST_BASE, size, prep=dst_prep)
    cq = fabric.create_cq(depth=4)
    t0 = fabric.now
    wr = dom.post_write(src, dst, cq=cq)
    wc = wr.result()
    fabric.progress()           # drain trailing driver/library-thread work
    return RunResult(size=size, latency_us=wc.t_complete - t0,
                     prep_us=src.prep_cost.total_us + dst.prep_cost.total_us,
                     stats=wr.stats)


def fault_sweep(where: str, strategy: Strategy,
                timeout_us: float = A.DEFAULT_TIMEOUT_US,
                sizes=SIZES, **kw) -> list[RunResult]:
    """The Fig 4.2/4.3/4.4 experiments: faults at dst / src / both."""
    src_prep = BufferPrep.FAULTING if where in ("src", "both") else BufferPrep.TOUCHED
    dst_prep = BufferPrep.FAULTING if where in ("dst", "both") else BufferPrep.TOUCHED
    return [run_remote_write(s, src_prep, dst_prep, strategy=strategy,
                             timeout_us=timeout_us, **kw) for s in sizes]


def ideal_sweep(prep: BufferPrep = BufferPrep.TOUCHED, sizes=SIZES,
                **kw) -> list[RunResult]:
    """Fig 4.1: no faults during the RDMA (pre-touched or pinned buffers)."""
    return [run_remote_write(s, prep, prep, **kw) for s in sizes]
