"""Page-fault resolution strategies (thesis §3.2.1) + beyond-paper variants.

* **TOUCH_A_PAGE** — the Netlink path: the driver sends one
  :class:`~repro.core.addresses.NetlinkMessage` per fault; a user-space
  library thread wakes, touches the *one* faulty page (CPU-MMU minor fault
  does the paging-in), and — for destination faults — fires the RAPF
  retransmit request through the packetizer.
* **TOUCH_AHEAD** — the ``get_user_pages()`` path: the driver pages in up to
  **4 pages** (the faulty one + the rest of its 16 KB block) entirely in
  kernel space.  Per the thesis, the RAPF *still* needs the user-space hop
  (the packetizer is only reachable from user space in the prototype).
* **TOUCH_AHEAD_N** *(beyond paper)* — generalized lookahead.
* **KERNEL_RAPF** *(beyond paper — the thesis' future-work item #1)* —
  Touch-Ahead plus a kernel-space packetizer: no user-space hop at all.
* **STREAM** *(beyond paper)* — sequential-stream prediction: on a fault at
  page *p* of a transfer, also page in the first page of the *next* block so
  the following block's fault never happens on the critical path.
* **NP_RDMA** *(beyond paper — NP-RDMA, arXiv 2310.11062)* — selects the
  ``repro.npr`` no-pinning backend: speculative VA→PA translation through a
  host-managed :class:`~repro.npr.mtt.MTTCache` with abort-and-redirect
  through a :class:`~repro.npr.pool.DMAPool` of pre-registered frames.  The
  datapath bypasses the SMMU fault FIFO entirely; this resolver is only the
  defensive fallback for stray SMMU faults in an NP_RDMA domain (it behaves
  like KERNEL_RAPF so such a fault still resolves).
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque

from repro.core.addresses import PAGES_PER_BLOCK
from repro.core.costmodel import CostModel
from repro.core.pagetable import PageTable, SegmentationFault


class DriverDedupCache:
    """The driver's last-two-transactions cache (§3.2.3.2 / Fig 4.2).

    The ``pf_rcv_tasklet`` skips FIFO entries it has just handled — the
    window that absorbs the interleaving duplicates the hardware's
    consecutive-dedup lets through.  Keys are the wire identity
    ``(src_ID, tr_ID, seq_num, vpage)`` *plus* the host-side generation
    tag of the tr_ID: once a node has launched 2^14 blocks and tr_IDs
    recycle, the wire identity alone aliases across incarnations, and an
    un-tagged cache would skip a *fresh* fault because a previous life of
    the same tr_ID faulted on the same page.  Membership tests are O(1)
    in the (constant, =2) depth — this cache is on the critical path of
    every FIFO entry drained.
    """

    __slots__ = ("_entries",)

    def __init__(self, depth: int = 2):
        self._entries: deque[tuple] = deque(maxlen=depth)

    def seen(self, key: tuple) -> bool:
        return key in self._entries

    def note(self, key: tuple) -> None:
        self._entries.append(key)


class Strategy(enum.Enum):
    TOUCH_A_PAGE = "touch_a_page"
    TOUCH_AHEAD = "touch_ahead"
    TOUCH_AHEAD_N = "touch_ahead_n"
    KERNEL_RAPF = "kernel_rapf"
    STREAM = "stream"
    NP_RDMA = "np_rdma"


def coerce_strategy(value) -> Strategy:
    """Resolve ``value`` into a :class:`Strategy` member, strictly.

    Accepts a member, its name (``"NP_RDMA"``) or its value
    (``"np_rdma"``), case-insensitively.  Anything else raises a typed
    ``ValueError`` naming every valid member — the seed accepted
    arbitrary spellings loosely and failed later with an opaque
    ``raise ValueError(s)`` deep in the resolver dispatch.
    """
    if isinstance(value, Strategy):
        return value
    if isinstance(value, str):
        try:
            return Strategy[value.upper()]
        except KeyError:
            try:
                return Strategy(value.lower())
            except ValueError:
                pass
    valid = ", ".join(f"{m.name} ({m.value!r})" for m in Strategy)
    raise ValueError(
        f"unknown fault-handling strategy {value!r}; valid Strategy "
        f"members: {valid}")


@dataclasses.dataclass
class Resolution:
    """Outcome + cost split of resolving one fault entry."""
    pages_resolved: int
    kernel_us: float          # time on the driver CPU (tasklet)
    user_us: float            # time on the user CPU (library thread)
    rapf_from_kernel: bool    # RAPF sent without the user-space hop
    segfault_recovered: bool = False
    major: bool = False


@dataclasses.dataclass
class Resolver:
    strategy: Strategy
    cost: CostModel
    lookahead: int = PAGES_PER_BLOCK     # for TOUCH_AHEAD_N / STREAM

    def resolve(self, pt: PageTable, vpn: int, *, is_dst: bool,
                block_pages_remaining: int) -> Resolution:
        """Resolve the fault at ``vpn``; page in per the strategy.

        ``block_pages_remaining`` bounds Touch-Ahead to the faulty page's
        block (the thesis touches "the one that was faulty and the next
        three after it", i.e. to the end of the 16 KB block).
        """
        s = self.strategy
        if s is Strategy.TOUCH_A_PAGE:
            return self._touch_a_page(pt, vpn, is_dst)
        if s is Strategy.TOUCH_AHEAD:
            return self._touch_ahead(pt, vpn, is_dst,
                                     min(PAGES_PER_BLOCK, block_pages_remaining),
                                     kernel_rapf=False, stream=False)
        if s is Strategy.TOUCH_AHEAD_N:
            return self._touch_ahead(pt, vpn, is_dst, self.lookahead,
                                     kernel_rapf=False, stream=False)
        if s is Strategy.KERNEL_RAPF:
            return self._touch_ahead(pt, vpn, is_dst,
                                     min(PAGES_PER_BLOCK, block_pages_remaining),
                                     kernel_rapf=True, stream=False)
        if s is Strategy.STREAM:
            return self._touch_ahead(pt, vpn, is_dst, self.lookahead,
                                     kernel_rapf=True, stream=True)
        if s is Strategy.NP_RDMA:
            # NP_RDMA traffic normally never reaches the SMMU fault path
            # (repro.npr verifies translations host-side); a stray fault
            # resolves like KERNEL_RAPF so the domain cannot wedge
            return self._touch_ahead(pt, vpn, is_dst,
                                     min(PAGES_PER_BLOCK, block_pages_remaining),
                                     kernel_rapf=True, stream=False)
        raise ValueError(s)

    # ------------------------------------------------------------------
    def _touch_a_page(self, pt: PageTable, vpn: int, is_dst: bool) -> Resolution:
        c = self.cost
        kernel = c.netlink_send_us
        user = c.wakeup_us
        seg = False
        major = False
        try:
            major, _ = pt.touch(vpn)
            user += c.touch_page_us + (c.major_fault_extra_us if major else 0.0)
        except SegmentationFault:
            # The Fig-3.2 scenario: the page left the address space between
            # the fault and the touch; the library's sig_handler absorbs it.
            user += c.sigsegv_recover_us
            seg = True
        if is_dst:
            user += c.pckzer_to_mbox_us
        return Resolution(pages_resolved=0 if seg else 1, kernel_us=kernel,
                          user_us=user, rapf_from_kernel=False,
                          segfault_recovered=seg, major=major)

    def _touch_ahead(self, pt: PageTable, vpn: int, is_dst: bool,
                     lookahead: int, *, kernel_rapf: bool,
                     stream: bool) -> Resolution:
        c = self.cost
        n = pt.get_user_pages(vpn, max(1, lookahead), write=True)
        kernel = c.gup_us(max(1, n))
        user = 0.0
        if stream and n:
            # predictively warm the first page of the next block
            extra = pt.get_user_pages(vpn + n, 1, write=True)
            if extra:
                kernel += c.gup_per_page_us
                n += extra
        if is_dst:
            if kernel_rapf:
                kernel += c.pckzer_to_mbox_us
            else:
                # prototype constraint: packetizer reachable from user space
                kernel += c.netlink_send_us
                user += c.wakeup_us + c.pckzer_to_mbox_us
        return Resolution(pages_resolved=n, kernel_us=kernel, user_us=user,
                          rapf_from_kernel=kernel_rapf)
