"""ARM SMMU (MMU-500) model: context banks, fault registers, TLB, HUPCF.

Faithful to §1.3.1.4 / §3.2.1 of the thesis:

* 16 context banks, one per protection domain; each points at one
  :class:`~repro.core.pagetable.PageTable` (its TTBR0).
* Per-bank fault registers: ``FSR`` (TF / PF / MULTI bits), ``FAR`` +
  ``FAR_HIGH`` (faulting 39-bit IOVA), ``FSYNR`` (``WNR`` bit — write =
  destination-buffer fault, read = source-buffer fault).
* ``SCTLR`` controls: ``CFIE`` (raise interrupt), ``CFRE`` (return abort),
  ``HUPCF`` (process transactions *under* an outstanding fault — without it,
  translations of perfectly-resident pages terminate while another fault is
  live, the phenomenon §3.2.1 describes), ``CFCFG`` (Terminate vs Stall).
* Only the **first** fault's details are captured; later faults while FSR is
  non-zero just set ``MULTI`` (the thesis' multiple-simultaneous-faults
  discussion).
* A micro-TLB per bank, invalidated by page-table invalidation hooks (the
  paper's invalidation flow) — a stale TLB entry after THP collapse is
  exactly the surprise fault the mechanism must absorb.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional

from repro.core.addresses import NUM_CONTEXT_BANKS
from repro.core.pagetable import PageState, PageTable

# FSR bits (subset used by the driver)
FSR_TF = 1 << 1       # translation fault
FSR_PF = 1 << 3       # permission fault
FSR_MULTI = 1 << 31   # multiple outstanding faults recorded

# SCTLR bits (subset; §3.2.1 lists the defaults)
SCTLR_M = 1 << 0
SCTLR_TRE = 1 << 1
SCTLR_AFE = 1 << 2
SCTLR_CFRE = 1 << 5
SCTLR_CFIE = 1 << 6
SCTLR_HUPCF = 1 << 8
SCTLR_CFCFG = 1 << 7  # 0 = Terminate, 1 = Stall


class FaultModel(enum.Enum):
    TERMINATE = 0
    STALL = 1


class Access(enum.Enum):
    READ = 0    # RDMA source-buffer translation
    WRITE = 1   # RDMA destination-buffer translation


class Disposition(enum.Enum):
    OK = 0
    TERMINATED = 1   # AXI slave error returned to the master (NACK)
    STALLED = 2      # transaction held; resume/terminate via CBn_RESUME


@dataclasses.dataclass(slots=True)
class TranslationResult:
    disposition: Disposition
    frame: int = -1
    fault_recorded: bool = False    # this translation wrote FSR/FAR/FSYNR
    collateral: bool = False        # terminated only because HUPCF == 0
    tlb_hit: bool = False


@dataclasses.dataclass(slots=True)
class ContextBank:
    index: int
    page_table: Optional[PageTable] = None
    sctlr: int = SCTLR_M | SCTLR_TRE | SCTLR_AFE | SCTLR_CFRE | SCTLR_CFIE
    fsr: int = 0
    far: int = 0          # low 32 bits of faulting IOVA
    far_high: int = 0     # high bits
    fsynr: int = 0        # bit 4 = WNR
    stalled_vpn: int = -1
    # the TLB-invalidation hook attach_domain registered on the bank's
    # page table, kept so detach_domain can unhook it on a bank steal
    invalidation_hook: Optional[Callable[[int], None]] = None

    @property
    def hupcf(self) -> bool:
        return bool(self.sctlr & SCTLR_HUPCF)

    @property
    def fault_model(self) -> FaultModel:
        return FaultModel.STALL if self.sctlr & SCTLR_CFCFG else FaultModel.TERMINATE

    @property
    def fault_active(self) -> bool:
        return self.fsr != 0

    def faulting_iova(self) -> int:
        return (self.far_high << 32) | self.far


@dataclasses.dataclass(slots=True)
class SMMUStats:
    translations: int = 0
    tlb_hits: int = 0
    faults_recorded: int = 0
    multi_faults: int = 0
    collateral_terminations: int = 0
    interrupts: int = 0
    tlb_invalidations: int = 0


class SMMU:
    """One node's System MMU with ``NUM_CONTEXT_BANKS`` context banks.

    ``interrupt_handler`` is the driver's ``arm_smmu_context_fault``; the
    simulator wires it to :class:`repro.core.driver` logic with the proper
    latencies.  It is invoked with the bank index whenever a fault is
    recorded and CFIE is set.
    """

    def __init__(self, node_id: int = 0,
                 interrupt_handler: Optional[Callable[[int], None]] = None):
        self.node_id = node_id
        self.banks = [ContextBank(i) for i in range(NUM_CONTEXT_BANKS)]
        self.interrupt_handler = interrupt_handler
        self.stats = SMMUStats()
        # micro-TLB keyed by packed ``(bank << 32) | vpn`` ints — int
        # hashing beats tuple hashing on the per-page translate path, and
        # vpns are 27-bit (39-bit IOVA space), so packing never collides
        self._tlb: dict[int, int] = {}

    # -------------------------------------------------------------- config
    def attach_domain(self, bank_index: int, page_table: PageTable,
                      hupcf: bool = True,
                      fault_model: FaultModel = FaultModel.TERMINATE) -> None:
        bank = self.banks[bank_index]
        bank.page_table = page_table
        if hupcf:
            bank.sctlr |= SCTLR_HUPCF
        else:
            bank.sctlr &= ~SCTLR_HUPCF
        if fault_model is FaultModel.STALL:
            bank.sctlr |= SCTLR_CFCFG
        else:
            bank.sctlr &= ~SCTLR_CFCFG
        hook = lambda vpn, b=bank_index: self.tlb_invalidate(b, vpn)
        page_table.invalidation_hooks.append(hook)
        bank.invalidation_hook = hook

    def detach_domain(self, bank_index: int) -> None:
        """Unbind a bank (bank steal / close_domain): full TLB shootdown,
        fault registers cleared, invalidation hook unhooked."""
        bank = self.banks[bank_index]
        if bank.page_table is not None and bank.invalidation_hook is not None:
            try:
                bank.page_table.invalidation_hooks.remove(
                    bank.invalidation_hook)
            except ValueError:
                pass
        bank.invalidation_hook = None
        self.tlb_invalidate_all(bank_index)
        self.clear_fault(bank_index)
        bank.stalled_vpn = -1
        bank.page_table = None

    # ----------------------------------------------------------------- TLB
    def tlb_invalidate(self, bank_index: int, vpn: int) -> None:
        if self._tlb.pop((bank_index << 32) | vpn, None) is not None:
            self.stats.tlb_invalidations += 1

    def tlb_invalidate_all(self, bank_index: int) -> None:
        for key in [k for k in self._tlb if k >> 32 == bank_index]:
            del self._tlb[key]
            self.stats.tlb_invalidations += 1

    # ----------------------------------------------------------- translate
    def translate(self, bank_index: int, vpn: int,
                  access: Access) -> TranslationResult:
        """Full translation record (driver-facing callers, tests)."""
        bank = self.banks[bank_index]
        pt = bank.page_table
        assert pt is not None, f"context bank {bank_index} not attached"
        self.stats.translations += 1

        # Hit-under-previous-fault: if a fault is outstanding and HUPCF is
        # clear, *every* subsequent transaction terminates, resident or not.
        if bank.fsr and not bank.sctlr & SCTLR_HUPCF:
            self.stats.collateral_terminations += 1
            return TranslationResult(Disposition.TERMINATED, collateral=True)

        cached = self._tlb.get((bank_index << 32) | vpn)
        if cached is not None:
            self.stats.tlb_hits += 1
            return TranslationResult(Disposition.OK, frame=cached, tlb_hit=True)

        pte = pt.lookup(vpn)
        if pte.state == PageState.RESIDENT and (access is Access.READ
                                                or pte.writable):
            self._tlb[(bank_index << 32) | vpn] = pte.frame
            return TranslationResult(Disposition.OK, frame=pte.frame)

        disp, recorded = self._record_fault(bank, vpn, access, pte)
        return TranslationResult(disp, fault_recorded=recorded)

    def translate_disposition(self, bank_index: int, vpn: int,
                              access: Access) -> Disposition:
        """Allocation-free variant of :meth:`translate` for the per-page
        datapath (PLDMA source reads, destination arrivals): identical
        state transitions and stats, but returns only the
        :class:`Disposition` — the one field those paths consult — so
        the resident-page common case builds no result record.
        """
        bank = self.banks[bank_index]
        pt = bank.page_table
        assert pt is not None, f"context bank {bank_index} not attached"
        st = self.stats
        st.translations += 1
        if bank.fsr and not bank.sctlr & SCTLR_HUPCF:
            st.collateral_terminations += 1
            return Disposition.TERMINATED
        if (bank_index << 32) | vpn in self._tlb:
            st.tlb_hits += 1
            return Disposition.OK
        pte = pt.lookup(vpn)
        if pte.state == PageState.RESIDENT and (access is Access.READ
                                                or pte.writable):
            self._tlb[(bank_index << 32) | vpn] = pte.frame
            return Disposition.OK
        return self._record_fault(bank, vpn, access, pte)[0]

    def _record_fault(self, bank: ContextBank, vpn: int, access: Access,
                      pte) -> tuple[Disposition, bool]:
        """Shared fault path of both translate variants: FSR/FAR/FSYNR
        capture (first fault only), MULTI accounting, interrupt, and the
        Terminate-vs-Stall disposition.  Returns ``(disposition,
        fault_recorded)``."""
        permission = (pte.state == PageState.RESIDENT)  # mapped but not writable
        recorded = False
        if not bank.fsr:
            bank.fsr = FSR_PF if permission else FSR_TF
            iova = vpn << 12
            bank.far = iova & 0xFFFF_FFFF
            bank.far_high = (iova >> 32) & 0xFFFF
            bank.fsynr = (1 << 4) if access is Access.WRITE else 0
            recorded = True
            self.stats.faults_recorded += 1
            if bank.sctlr & SCTLR_CFIE and self.interrupt_handler is not None:
                self.stats.interrupts += 1
                self.interrupt_handler(bank.index)
        else:
            bank.fsr |= FSR_MULTI
            self.stats.multi_faults += 1

        if bank.fault_model is FaultModel.STALL:
            bank.stalled_vpn = vpn
            return Disposition.STALLED, recorded
        return Disposition.TERMINATED, recorded

    # ------------------------------------------------------------ driver IF
    def read_fault_record(self, bank_index: int) -> tuple[int, int, bool]:
        """Driver reads (iova, fsynr_wnr, is_translation_fault) of bank."""
        bank = self.banks[bank_index]
        return (bank.faulting_iova(), (bank.fsynr >> 4) & 1,
                bool(bank.fsr & FSR_TF))

    def clear_fault(self, bank_index: int) -> None:
        bank = self.banks[bank_index]
        bank.fsr = 0
        bank.far = bank.far_high = bank.fsynr = 0

    def resume_stalled(self, bank_index: int, retry: bool = True) -> Disposition:
        """CBn_RESUME write: retry or terminate a stalled transaction."""
        bank = self.banks[bank_index]
        vpn = bank.stalled_vpn
        bank.stalled_vpn = -1
        self.clear_fault(bank_index)
        if not retry or vpn < 0:
            return Disposition.TERMINATED
        res = self.translate(bank_index,
                             vpn, Access.WRITE if bank.fsynr else Access.READ)
        return res.disposition
