"""Address-space constants and helpers for the virtual-address RDMA system.

Mirrors the ExaNeSt / FORTH PLDMA environment described in the paper:

* 4 KB OS pages (the SMMU translation granule used by the thesis),
* transfers segmented by the R5 scheduler into 16 KB *blocks* (4 pages),
* blocks segmented by hardware into 256 B *packets* (the PLDMA MTU),
* 39-bit virtual addresses, 16-bit protection-domain IDs (16 SMMU context
  banks in the Zynq UltraScale+), 14-bit transaction IDs, 22-bit source-node
  IDs, 14-bit sequence numbers (Table 3.1 / Table 3.2).
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Paper constants (Section 1.3.2, 3.2.3.1, Appendix A)
# ---------------------------------------------------------------------------

PAGE_SIZE = 4096                       # bytes; SMMU/OS translation granule
BLOCK_SIZE = 16 * 1024                 # bytes; R5 segmentation unit
MTU = 256                              # bytes; PLDMA packet size
PAGES_PER_BLOCK = BLOCK_SIZE // PAGE_SIZE          # 4
PACKETS_PER_BLOCK = BLOCK_SIZE // MTU              # 64
PACKETS_PER_PAGE = PAGE_SIZE // MTU                # 16

VA_BITS = 39                           # system virtual-address width
NUM_CONTEXT_BANKS = 16                 # SMMU context banks == protection domains
VIRTUAL_CHANNELS_PER_PD = 64           # R5 virtual channels per protection domain
MAX_OUTSTANDING_TRANSFERS = VIRTUAL_CHANNELS_PER_PD * NUM_CONTEXT_BANKS  # 1024
OUTSTANDING_BLOCKS_PER_TRANSFER = 2    # "parameterized ... currently two (2)"

SRC_ID_BITS = 22
TR_ID_BITS = 14
SEQ_NUM_BITS = 14
PDID_BITS = 16
IOVA_FIELD_BITS = 32                   # FIFO/netlink field: 4b process idx + 28b VPN

SRC_ID_MASK = (1 << SRC_ID_BITS) - 1
TR_ID_MASK = (1 << TR_ID_BITS) - 1
SEQ_NUM_MASK = (1 << SEQ_NUM_BITS) - 1
PDID_MASK = (1 << PDID_BITS) - 1

#: size of the per-node transaction-ID space: the wire carries 14-bit
#: tr_IDs (Table 3.2), so a node can have at most this many blocks in
#: flight — ID reuse beyond it is a *protocol property*, handled by the
#: R5's free-list allocator (recycle on completion, host-side generation
#: tags), not an overflow bug.
TR_ID_SPACE = 1 << TR_ID_BITS

# RAPF mailbox opcode ("Retransmit After Page Fault handled", Section 3.2.1)
OPCODE_RAPF = 2

# Default R5 retransmission timeout.  The thesis tried 25 ms, 2.5 ms and 1 ms
# and found 1 ms best (Chapter 4); times here are microseconds.
DEFAULT_TIMEOUT_US = 1000.0
TIMEOUT_SWEEP_US = (25_000.0, 2_500.0, 1_000.0)


def page_index(va: int) -> int:
    """Virtual page number of a virtual address."""
    return va >> 12


def page_offset(va: int) -> int:
    return va & (PAGE_SIZE - 1)


def page_base(va: int) -> int:
    return va & ~(PAGE_SIZE - 1)


def block_base(va: int) -> int:
    return va & ~(BLOCK_SIZE - 1)


def num_pages(va: int, nbytes: int) -> int:
    """Number of 4 KB pages touched by [va, va+nbytes)."""
    if nbytes <= 0:
        return 0
    first = page_index(va)
    last = page_index(va + nbytes - 1)
    return last - first + 1


def pages_spanned(va: int, nbytes: int) -> list[int]:
    if nbytes <= 0:
        return []
    first = page_index(va)
    last = page_index(va + nbytes - 1)
    return list(range(first, last + 1))


def split_blocks(va: int, nbytes: int) -> list[tuple[int, int]]:
    """Segment a transfer into 16 KB-aligned blocks (R5 behaviour).

    Returns ``[(block_va, block_bytes), ...]``.  Blocks are 16 KB aligned, so
    the first/last block may be shorter than 16 KB (Section 1.3.2).
    """
    out: list[tuple[int, int]] = []
    cur = va
    end = va + nbytes
    while cur < end:
        boundary = block_base(cur) + BLOCK_SIZE
        chunk_end = min(boundary, end)
        out.append((cur, chunk_end - cur))
        cur = chunk_end
    return out


def num_packets(nbytes: int) -> int:
    return max(1, -(-nbytes // MTU))


@dataclasses.dataclass(frozen=True)
class NetlinkMessage:
    """Kernel → user message, Table 3.1 (99 bits, sent as hex string).

    ``Src_ID (22) | Tr_ID (14) | Seq_Num (14) | Faulty IOVA (32) | PDID (16)
    | R/W (1, LSB)``.  R/W == 0 → fault at *source* buffer (read access),
    R/W == 1 → fault at *destination* buffer (write access).
    """

    src_id: int
    tr_id: int
    seq_num: int
    iova_field: int     # 4-bit process index + 28-bit VPN field
    pdid: int
    rw: int             # 0 = read/source fault, 1 = write/destination fault

    def encode(self) -> int:
        v = self.src_id & SRC_ID_MASK
        v = (v << TR_ID_BITS) | (self.tr_id & TR_ID_MASK)
        v = (v << SEQ_NUM_BITS) | (self.seq_num & SEQ_NUM_MASK)
        v = (v << IOVA_FIELD_BITS) | (self.iova_field & 0xFFFF_FFFF)
        v = (v << PDID_BITS) | (self.pdid & PDID_MASK)
        v = (v << 1) | (self.rw & 1)
        return v

    def encode_hex(self) -> str:
        # 22+14+14+32+16+1 = 99 bits -> 25 hex digits
        return f"{self.encode():025x}"

    @staticmethod
    def decode(v: int) -> "NetlinkMessage":
        rw = v & 1
        v >>= 1
        pdid = v & PDID_MASK
        v >>= PDID_BITS
        iova_field = v & 0xFFFF_FFFF
        v >>= IOVA_FIELD_BITS
        seq_num = v & SEQ_NUM_MASK
        v >>= SEQ_NUM_BITS
        tr_id = v & TR_ID_MASK
        v >>= TR_ID_BITS
        src_id = v & SRC_ID_MASK
        return NetlinkMessage(src_id, tr_id, seq_num, iova_field, pdid, rw)

    @staticmethod
    def decode_hex(s: str) -> "NetlinkMessage":
        return NetlinkMessage.decode(int(s, 16))


def iova_field_pack(process_index: int, vpn: int) -> int:
    """Pack the 32-bit FIFO/netlink IOVA field (Section 3.2.3.2).

    4 MSBs = process index within the protection domain; 28 LSBs = the most
    significant bits of a 39-bit VA, i.e. the 27-bit VPN with bit 27 wired 0.
    """
    return ((process_index & 0xF) << 28) | (vpn & 0x0FFF_FFFF)


def iova_field_unpack(field: int) -> tuple[int, int]:
    return (field >> 28) & 0xF, field & 0x0FFF_FFFF


@dataclasses.dataclass(frozen=True)
class RAPFMessage:
    """Mailbox message requesting retransmission (opcode 2, Section 3.2.3.3).

    The low 12 bits after the opcode are *wired* by the kernel-space
    packetizer (the wired PDID) and cannot be forged from user space; R5
    cross-checks the wired PDID against the user-supplied one.
    """

    wired_pdid: int     # wired by the packetizer (trusted)
    rcved_pdid: int     # supplied by user space (untrusted)
    tr_id: int
    seq_num: int
    opcode: int = OPCODE_RAPF

    def encode_words(self) -> tuple[int, int]:
        word0 = (self.opcode & 0x3) | ((self.wired_pdid & PDID_MASK) << 2) | (
            (self.tr_id & TR_ID_MASK) << (2 + 16))
        word1 = (self.seq_num & 0xFFF) | ((self.rcved_pdid & PDID_MASK) << 12)
        return word0, word1

    @staticmethod
    def decode_words(word0: int, word1: int) -> "RAPFMessage":
        opcode = word0 & 0x3
        wired_pdid = (word0 >> 2) & PDID_MASK
        tr_id = (word0 >> (2 + 16)) & TR_ID_MASK
        seq_num = word1 & 0xFFF
        rcved_pdid = (word1 >> 12) & PDID_MASK
        return RAPFMessage(wired_pdid=wired_pdid, rcved_pdid=rcved_pdid,
                           tr_id=tr_id, seq_num=seq_num, opcode=opcode)
