"""The receive-path fault FIFO added to the FORTH PLDMA (thesis §3.2.3.1).

512-deep, 128-bit-wide hardware FIFO logging every NACKed (AXI slave-error)
packet: ``(src_ID, tr_ID, seq_num, PDID, faulty IOVA, EXA_ACK, R/W)``.

Faithful details implemented here:

* **Layout** — the four 32-bit words of Table 3.2, bit-exact packing and
  unpacking (valid bits in each word, wired-zero fields).
* **Read FSM** — entries are consumed by *two 64-bit reads*; only the read
  of the *second* half pops the entry; re-reading the second half first does
  not pop (§3.2.3.1 "the FSM ensures that this happens in a safe order").
* **Hardware dedup** — a new slave error is *not* pushed if it matches the
  most recently pushed entry on (src_ID, tr_ID, seq_num, virtual page)
  (§3.2.3.1 "if it has the same ... with the entry we added last time, we do
  not add it again").  Interleaved blocks (window = 2) still produce
  duplicates — the effect the thesis measures at 32/64 KB — which the
  *driver-side* last-2 check absorbs (see resolver.py).
* Overflow drops (FIFO full) are counted: lost entries are recovered by the
  R5 timeout path, another reason timeouts back-stop the mechanism.

**Generation sidecar (host-side, beyond the 128-bit wire format).**  Once a
node has launched 2^14 blocks, tr_IDs recycle and the wire key
``(src_ID, tr_ID, seq_num, vpage)`` aliases across *incarnations* of the
same ID.  The simulator keeps a per-entry generation tag *alongside* the
FIFO — ``push(entry, gen=...)`` / ``last_popped_gen`` — so the dedup
comparison and the driver's RAPF attribution stay correct under wrap.  The
tag never enters :meth:`FIFOEntry.pack_words`: the four 32-bit words of
Table 3.2 remain bit-exact, and the real hardware (which cannot see
generations) would fall back to the R5 timeout in the rare cross-incarnation
collision this tag disambiguates.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

FIFO_DEPTH = 512


@dataclasses.dataclass(frozen=True)
class FIFOEntry:
    src_id: int        # 22 bits: initiator node
    tr_id: int         # 14 bits
    seq_num: int       # 14 bits
    pdid: int          # 16 bits
    iova_field: int    # 32 bits: 4b process index + 28b VPN field
    exa_ack: int = 0   # 2 bits
    rw: int = 1        # write (destination) faults land here

    # ---- Table 3.2 bit-exact packing -----------------------------------
    def pack_words(self) -> tuple[int, int, int, int]:
        w0 = (((self.src_id & 0x3FFFFF) << 8)
              | (((self.tr_id >> 12) & 0x3) << 4)
              | 0x1)                                     # valid bit
        w1 = (((self.tr_id & 0xFFF) << 20)
              | ((self.seq_num & 0x3FFF) << 4)
              | 0x1)
        w2 = (((self.pdid & 0xFFFF) << 16)
              | (((self.iova_field >> 20) & 0xFFF) << 4)
              | ((self.exa_ack & 0x3) << 1)
              | 0x1)
        w3 = (((self.iova_field & 0xFFFFF) << 12)
              | 0x1)
        return w0, w1, w2, w3

    @staticmethod
    def unpack_words(w0: int, w1: int, w2: int, w3: int) -> "FIFOEntry":
        src_id = (w0 >> 8) & 0x3FFFFF
        tr_hi = (w0 >> 4) & 0x3
        tr_lo = (w1 >> 20) & 0xFFF
        seq = (w1 >> 4) & 0x3FFF
        pdid = (w2 >> 16) & 0xFFFF
        iova_hi = (w2 >> 4) & 0xFFF
        exa_ack = (w2 >> 1) & 0x3
        iova_lo = (w3 >> 12) & 0xFFFFF
        return FIFOEntry(src_id=src_id, tr_id=(tr_hi << 12) | tr_lo,
                         seq_num=seq, pdid=pdid,
                         iova_field=(iova_hi << 20) | iova_lo,
                         exa_ack=exa_ack)

    def vpage_key(self) -> tuple[int, int, int, int]:
        """Dedup key: src, transaction, sequence, virtual page (no offset)."""
        return (self.src_id, self.tr_id, self.seq_num,
                self.iova_field)  # iova_field already excludes the offset


@dataclasses.dataclass
class FIFOStats:
    pushes: int = 0
    dedup_skips: int = 0
    overflow_drops: int = 0
    pops: int = 0
    max_occupancy: int = 0


class FaultFIFO:
    def __init__(self, depth: int = FIFO_DEPTH):
        self.depth = depth
        self._q: deque[FIFOEntry] = deque()
        #: host-side generation sidecar, parallel to ``_q`` (see module
        #: docstring) — not part of the 128-bit hardware entry
        self._gen_q: deque[int] = deque()
        self._last_pushed: Optional[FIFOEntry] = None
        self._last_gen = 0
        self._read_lo_done = False
        #: packed words of the head entry, cached between the FSM's two
        #: 64-bit reads (the head only changes on pop) — at scale the
        #: double bit-exact repack per pop was a measurable hot spot
        self._head_words: Optional[tuple[int, int, int, int]] = None
        #: generation tag of the entry most recently popped by the
        #: two-read FSM (0 when the pusher supplied none)
        self.last_popped_gen = 0
        self.stats = FIFOStats()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def empty(self) -> bool:
        return not self._q

    # ---------------------------------------------------------------- push
    def push(self, entry: FIFOEntry, gen: int = 0) -> bool:
        """Hardware push on slave error.  Returns True if enqueued.

        ``gen`` is the host-side incarnation tag of ``entry.tr_id`` (0 =
        untagged): the consecutive-dedup only collapses entries of the
        *same* incarnation, so a recycled tr_ID faulting on the same page
        as its previous life still logs its entry.
        """
        if (self._last_pushed is not None
                and self._last_gen == gen
                and self._last_pushed.vpage_key() == entry.vpage_key()):
            self.stats.dedup_skips += 1
            return False
        if len(self._q) >= self.depth:
            self.stats.overflow_drops += 1
            return False
        self._q.append(entry)
        self._gen_q.append(gen)
        self._last_pushed = entry
        self._last_gen = gen
        self.stats.pushes += 1
        self.stats.max_occupancy = max(self.stats.max_occupancy, len(self._q))
        return True

    def break_dedup(self) -> None:
        """Forget the last-pushed entry, as an interleaved packet stream does.

        The hardware dedup only compares against the *immediately preceding*
        slave error; when two blocks' NACK packets interleave on the wire,
        the comparison never matches (§ Fig 4.2).  The PLDMA model calls
        this between pushes to reproduce that effect.
        """
        self._last_pushed = None

    # ---------------------------------------------------- two-read-pop FSM
    def read64(self, half: int) -> int:
        """AXI-lite 64-bit read.  ``half``: 0 = low, 1 = high (pops).

        Reading the high half without having read the low half first returns
        the data but does **not** pop (safe-order FSM, §3.2.3.1).
        """
        if not self._q:
            return 0
        words = self._head_words
        if words is None:
            words = self._head_words = self._q[0].pack_words()
        w0, w1, w2, w3 = words
        if half == 0:
            self._read_lo_done = True
            return (w1 << 32) | w0
        value = (w3 << 32) | w2
        if self._read_lo_done:
            self._q.popleft()
            self.last_popped_gen = self._gen_q.popleft()
            self._read_lo_done = False
            self._head_words = None
            self.stats.pops += 1
        return value

    def pop_entry(self) -> Optional[FIFOEntry]:
        """Driver convenience: the two 64-bit reads, decoded.

        Returns the head entry object directly instead of packing and
        re-decoding the four words — the roundtrip is bit-exact for every
        in-range field (``read64`` keeps the word-level FSM for register
        clients), and the pop bookkeeping below is identical to a
        low-then-high read pair.
        """
        if not self._q:
            return None
        head = self._q.popleft()
        self.last_popped_gen = self._gen_q.popleft()
        self._read_lo_done = False
        self._head_words = None
        self.stats.pops += 1
        return head
