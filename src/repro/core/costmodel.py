"""Calibrated cost model for the discrete-event simulator.

All times in **microseconds**, calibrated against the thesis measurements:

* Table 4.1 — per-buffer overhead of ``mmap/munmap/pin/unpin/touch`` for
  16 B … 64 KB buffers (measured on the Zynq UltraScale+ A53 @ Linux 4.9);
* "The round-trip latency of a remote DMA write transfer that experiences
  zero page faults ... is 4 µs for 16 Bytes" (Chapter 4);
* 100 ns hop-to-hop latency, 10 Gb/s HSS links (Section 1.3.1.2 / Chapter 4);
* 1 ms default R5 retransmission timeout (best of {25, 2.5, 1} ms).

The OS-call table is kept verbatim and interpolated in *pages*, so
``benchmarks/table_4_1.py`` reproduces the table exactly and every other
figure inherits consistent constants.
"""

from __future__ import annotations

import bisect
import dataclasses

from repro.core.addresses import MTU, PAGE_SIZE

# Table 4.1 (time in usec) — sizes in bytes, per single buffer.
TABLE_4_1_SIZES = (16, 64, 256, 1024, 4096, 16384, 32768, 65536)
TABLE_4_1 = {
    "mmap":   (2, 2, 2, 2, 2, 2, 2, 2),
    "munmap": (6, 6, 6, 6, 7, 10, 12, 19),
    "pin":    (6, 6, 6, 6, 6, 15, 27, 49),
    "unpin":  (2, 2, 2, 2, 2, 5, 8, 14),
    "touch":  (3, 3, 3, 3, 3, 10, 19, 40),
}


def _interp(op: str, nbytes: int) -> float:
    """Piecewise-linear interpolation of Table 4.1 in buffer size."""
    sizes = TABLE_4_1_SIZES
    vals = TABLE_4_1[op]
    if nbytes <= sizes[0]:
        return float(vals[0])
    if nbytes >= sizes[-1]:
        # extrapolate linearly per extra page beyond 64 KB
        per_page = (vals[-1] - vals[-2]) / ((sizes[-1] - sizes[-2]) / PAGE_SIZE)
        extra_pages = (nbytes - sizes[-1]) / PAGE_SIZE
        return float(vals[-1]) + per_page * extra_pages
    i = bisect.bisect_right(sizes, nbytes)
    lo_s, hi_s = sizes[i - 1], sizes[i]
    lo_v, hi_v = vals[i - 1], vals[i]
    frac = (nbytes - lo_s) / (hi_s - lo_s)
    return lo_v + frac * (hi_v - lo_v)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Microsecond-scale cost constants for the simulator.

    The per-event constants below are chosen so the end-to-end paths match
    the thesis numbers (see ``tests/test_costmodel.py`` and
    ``benchmarks/fig_4_*.py``): ideal 16 B RTT = 4 µs; destination-fault
    Touch-Ahead/Touch-A-Page ratios ≈ 1.7×/1.2×/1.2× at 16/32/64 KB;
    source-fault ratios ≈ 3.9×/3.9×/4.7×; driver latency µs-scale with the
    get_user_pages (Touch-Ahead) path costing more in-kernel time.
    """

    # --- network / PLDMA -------------------------------------------------
    hop_latency_us: float = 0.1                 # 100 ns per hop
    link_gbps: float = 10.0                     # HSS link
    dma_setup_us: float = 2.64                  # A53 -> TCM/mailbox + R5 init
    #   (calibrated so the zero-fault 16 B remote write lands on the
    #    thesis' measured 4 us round trip)
    per_block_r5_us: float = 0.35               # R5 segmentation/monitor per block
    ack_us: float = 0.3                         # ACK generation + mailbox write
    nack_us: float = 0.3                        # AXI slave-error propagation
    smmu_translate_us: float = 0.02             # TBU hit, ~2 clocks
    completion_poll_us: float = 0.5             # user polls PLDMA status reg

    # --- SMMU fault path (driver side; Fig 4.7 scale) ---------------------
    interrupt_us: float = 1.0                   # context-fault interrupt entry
    handler_regs_us: float = 0.6                # FSR/FAR/FSYNR reads + decode
    tasklet_latency_us: float = 1.2             # schedule -> run delay
    fifo_read64_us: float = 0.2                 # one AXI-lite 64-bit read
    driver_bookkeep_us: float = 0.6             # last-2 dedup check, state
    netlink_send_us: float = 1.1                # kernel -> user nl_send
    gup_base_us: float = 2.2                    # get_user_pages entry/exit
    gup_per_page_us: float = 2.6                # in-kernel page-in per page

    # --- user-space library (Touch-A-Page path) ---------------------------
    wakeup_us: float = 4.0                      # nl recv + ctx switch to thread
    touch_page_us: float = 2.8                  # 1-page touch (CPU MMU minor PF)
    pckzer_to_mbox_us: float = 1.0              # RAPF via packetizer -> mailbox
    sigsegv_recover_us: float = 9.0             # stale-page SIGSEGV handler

    # --- R5 scheduler ------------------------------------------------------
    timeout_us: float = 1000.0                  # retransmission timeout (1 ms)
    mailbox_poll_us: float = 0.4                # R5 mailbox decode
    retransmit_setup_us: float = 0.5            # R5 re-initiates a block

    # --- major faults (future-work knob in the paper; off by default) ------
    major_fault_extra_us: float = 150.0         # NVMe-class page-in

    # --- tenancy control plane (context-bank overcommit) -------------------
    bank_shootdown_us: float = 3.0              # tlb_invalidate_all broadcast
    bank_rebind_us: float = 1.5                 # TTBR0/SCTLR rewrite + sync

    # --- NP-RDMA backend (repro.npr; arXiv 2310.11062 scale) ---------------
    mtt_fill_us: float = 0.3                    # host installs one MTT entry
    npr_abort_ctrl_us: float = 0.3              # abort control message build
    npr_fixup_base_us: float = 1.5              # host fix-up handler entry
    pool_copy_page_us: float = 0.9              # pool frame -> app page copy
    pool_refill_us: float = 6.0                 # re-register a retired batch

    # ------------------------------------------------------------------ OS
    def mmap_us(self, nbytes: int) -> float:
        return _interp("mmap", nbytes)

    def munmap_us(self, nbytes: int) -> float:
        return _interp("munmap", nbytes)

    def pin_us(self, nbytes: int) -> float:
        return _interp("pin", nbytes)

    def unpin_us(self, nbytes: int) -> float:
        return _interp("unpin", nbytes)

    def touch_us(self, nbytes: int) -> float:
        """User-space touch of a whole buffer (one byte per page)."""
        return _interp("touch", nbytes)

    # ------------------------------------------------------------- network
    def packet_wire_us(self, nbytes: int = MTU) -> float:
        """Serialization time of one packet on the HSS link."""
        return (nbytes * 8) / (self.link_gbps * 1e3)  # Gb/s -> bits/us

    def gup_us(self, n_pages: int) -> float:
        return self.gup_base_us + self.gup_per_page_us * n_pages


DEFAULT_COST_MODEL = CostModel()


def cost_model_with_timeout(timeout_us: float) -> CostModel:
    return dataclasses.replace(DEFAULT_COST_MODEL, timeout_us=timeout_us)
