"""DEPRECATED synchronous engine — thin shim over :mod:`repro.api`.

``RDMAEngine`` was the original flat, synchronous API: a 9-kwarg
constructor, one global fault-resolution strategy, raw ``(pd, va,
nbytes)`` triples, and blocking ``run_transfer``.  It is kept only so the
seed tests and any out-of-tree callers keep working; everything it does is
delegated to the verbs-style API:

* ``RDMAEngine(...)``          -> ``Fabric.build(FabricConfig(...))``
* ``map_buffer(...)``          -> ``domain.register_memory(...)``
* ``remote_write/read(...)``   -> ``domain.post_write/post_read(...)``
* ``run_transfer(t)``          -> ``cq.wait(...)`` / ``wr.result(...)``

New code should import from :mod:`repro.api` directly.
"""

from __future__ import annotations

import warnings
from typing import Optional

from repro.core import addresses as A
from repro.core.costmodel import CostModel
from repro.core.fault import FaultModel
from repro.core.node import Transfer, TransferStats
from repro.core.resolver import Strategy
# Canonical homes are repro.api.memory; re-exported here for the old names.
from repro.api.memory import BufferPrep, PrepCost

__all__ = ["BufferPrep", "PrepCost", "RDMAEngine"]


class RDMAEngine:
    """Deprecated: use :class:`repro.api.Fabric` (see module docstring)."""

    def __init__(self, n_nodes: int = 2,
                 strategy: Strategy = Strategy.TOUCH_AHEAD,
                 cost: Optional[CostModel] = None,
                 hupcf: bool = True,
                 fault_model: FaultModel = FaultModel.TERMINATE,
                 frames_per_node: int = 1 << 20,
                 pin_limit_bytes: Optional[int] = None,
                 lookahead: int = A.PAGES_PER_BLOCK,
                 hops: int = 1):
        warnings.warn(
            "RDMAEngine is deprecated; build a repro.api.Fabric with a "
            "FabricConfig and use the verbs API (register_memory / "
            "post_write / CompletionQueue)", DeprecationWarning, stacklevel=2)
        from repro.api.config import FabricConfig
        from repro.api.fabric import Fabric
        from repro.api.policy import FaultPolicy
        policy = FaultPolicy(strategy=strategy, lookahead=lookahead,
                             pin_limit_bytes=pin_limit_bytes)
        self.fabric = Fabric.build(FabricConfig(
            n_nodes=n_nodes, hops=hops, cost=cost, hupcf=hupcf,
            fault_model=fault_model, frames_per_node=frames_per_node,
            default_policy=policy))
        # compatibility attributes the seed tests/benchmarks reach for
        self.loop = self.fabric.loop
        self.cost = self.fabric.cost
        self.nodes = self.fabric.nodes
        self.resolver = self.fabric.nodes[0].resolver
        self.pin_limit_bytes = pin_limit_bytes

    # ------------------------------------------------------------- buffers
    def map_buffer(self, node_idx: int, pd: int, va: int, nbytes: int,
                   prep: BufferPrep = BufferPrep.FAULTING,
                   charge: bool = True) -> PrepCost:
        """mmap (+ touch/pin) a buffer; returns the user-side cost."""
        dom = self.fabric.domain(pd) or self.fabric.open_domain(pd)
        mr = dom.register_memory(node_idx, va, nbytes, prep=prep,
                                 charge=charge)
        return mr.prep_cost

    def unmap_buffer(self, node_idx: int, pd: int, va: int, nbytes: int) -> float:
        self.nodes[node_idx].pt(pd).munmap(va, nbytes)
        return self.cost.munmap_us(nbytes)

    # ------------------------------------------------------------ transfers
    def remote_write(self, pd: int, src_node: int, src_va: int,
                     dst_node: int, dst_va: int, nbytes: int) -> Transfer:
        assert (src_va % A.PAGE_SIZE) == (dst_va % A.PAGE_SIZE), \
            "engine requires equally page-aligned src/dst (as in the thesis runs)"
        return self.fabric._start_write(pd, src_node, src_va,
                                        dst_node, dst_va, nbytes)

    def remote_read(self, pd: int, target_node: int, target_va: int,
                    local_node: int, local_va: int, nbytes: int) -> Transfer:
        """Remote read = request forwarded to the target, whose R5 turns it
        into a write back to the initiator (§1.3.2.2)."""
        return self.fabric._start_read(pd, target_node, target_va,
                                       local_node, local_va, nbytes)

    def run(self, until: Optional[float] = None) -> None:
        self.loop.run(until=until)

    def run_transfer(self, t: Transfer, deadline_us: float = 5e6) -> TransferStats:
        self.loop.run(until=self.loop.now + deadline_us)
        if not t.complete:
            raise RuntimeError(
                f"transfer tid={t.tid} incomplete after {deadline_us} us: "
                f"stats={t.stats}")
        return t.stats
