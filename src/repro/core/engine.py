"""User-level RDMA engine API over the simulated ExaNeSt fabric.

This is the "page fault library" + PLDMA user API of the thesis, exposed the
way an application would use it: map buffers, optionally prepare them
(pin / touch / leave faulting), then issue remote writes/reads and collect
per-transfer statistics.  `benchmarks/` and the property tests drive
everything through this class.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core import addresses as A
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.fault import FaultModel
from repro.core.node import Link, Node, Transfer, TransferStats
from repro.core.pagetable import FrameAllocator
from repro.core.resolver import Resolver, Strategy
from repro.core.simulator import EventLoop


class BufferPrep(enum.Enum):
    """How a buffer is prepared before the RDMA (the thesis' comparisons)."""
    FAULTING = "faulting"        # mmap'ed only: every page faults on access
    TOUCHED = "touched"          # pre-touched: resident, unpinned
    PINNED = "pinned"            # pinned (and therefore resident)


@dataclasses.dataclass
class PrepCost:
    """User-side microseconds spent preparing / releasing one buffer."""
    mmap_us: float = 0.0
    prep_us: float = 0.0         # touch or pin
    release_us: float = 0.0      # unpin (pin case)
    munmap_us: float = 0.0

    @property
    def total_us(self) -> float:
        return self.mmap_us + self.prep_us + self.release_us + self.munmap_us


class RDMAEngine:
    def __init__(self, n_nodes: int = 2,
                 strategy: Strategy = Strategy.TOUCH_AHEAD,
                 cost: Optional[CostModel] = None,
                 hupcf: bool = True,
                 fault_model: FaultModel = FaultModel.TERMINATE,
                 frames_per_node: int = 1 << 20,
                 pin_limit_bytes: Optional[int] = None,
                 lookahead: int = A.PAGES_PER_BLOCK,
                 hops: int = 1):
        self.loop = EventLoop()
        self.cost = cost or DEFAULT_COST_MODEL
        self.resolver = Resolver(strategy=strategy, cost=self.cost,
                                 lookahead=lookahead)
        self.pin_limit_bytes = pin_limit_bytes
        self.nodes: list[Node] = []
        for i in range(n_nodes):
            node = Node(self.loop, self.cost, i, self.resolver,
                        allocator=FrameAllocator(frames_per_node),
                        hupcf=hupcf, fault_model=fault_model)
            self.nodes.append(node)
        # full-duplex links between every pair (and loopback), one hop each
        for a in self.nodes:
            for b in self.nodes:
                a.links_to[b.node_id] = Link(self.loop, self.cost,
                                             hops=hops if a is not b else 1)
                a.peer[b.node_id] = b
        self._tid = 0

    # ------------------------------------------------------------- buffers
    def map_buffer(self, node_idx: int, pd: int, va: int, nbytes: int,
                   prep: BufferPrep = BufferPrep.FAULTING,
                   charge: bool = True) -> PrepCost:
        """mmap (+ touch/pin) a buffer; returns the user-side cost."""
        node = self.nodes[node_idx]
        if pd not in node.page_tables:
            node.create_domain(pd, pin_limit_bytes=self.pin_limit_bytes)
        pt = node.pt(pd)
        pt.mmap(va, nbytes)
        cost = PrepCost(mmap_us=self.cost.mmap_us(nbytes))
        if prep is BufferPrep.TOUCHED:
            for vpn in A.pages_spanned(va, nbytes):
                pt.touch(vpn)
            cost.prep_us = self.cost.touch_us(nbytes)
        elif prep is BufferPrep.PINNED:
            pt.pin(va, nbytes)
            cost.prep_us = self.cost.pin_us(nbytes)
            cost.release_us = self.cost.unpin_us(nbytes)
        if not charge:
            return PrepCost()
        return cost

    def unmap_buffer(self, node_idx: int, pd: int, va: int, nbytes: int) -> float:
        node = self.nodes[node_idx]
        node.pt(pd).munmap(va, nbytes)
        return self.cost.munmap_us(nbytes)

    # ------------------------------------------------------------ transfers
    def remote_write(self, pd: int, src_node: int, src_va: int,
                     dst_node: int, dst_va: int, nbytes: int) -> Transfer:
        assert (src_va % A.PAGE_SIZE) == (dst_va % A.PAGE_SIZE), \
            "engine requires equally page-aligned src/dst (as in the thesis runs)"
        self._tid += 1
        t = Transfer(self._tid, pd, self.nodes[src_node], self.nodes[dst_node],
                     src_va, dst_va, nbytes)
        self.nodes[src_node].r5.submit(t)
        return t

    def remote_read(self, pd: int, target_node: int, target_va: int,
                    local_node: int, local_va: int, nbytes: int) -> Transfer:
        """Remote read = request forwarded to the target, whose R5 turns it
        into a write back to the initiator (§1.3.2.2)."""
        self._tid += 1
        t = Transfer(self._tid, pd, self.nodes[target_node],
                     self.nodes[local_node], target_va, local_va, nbytes)
        # request packet: initiator -> target mailbox
        req_delay = (self.cost.pckzer_to_mbox_us
                     + (self.cost.hop_latency_us + self.cost.packet_wire_us(16)
                        if target_node != local_node else 0.0))
        self.loop.schedule(req_delay, self.nodes[target_node].r5.submit, t)
        return t

    def run(self, until: Optional[float] = None) -> None:
        self.loop.run(until=until)

    def run_transfer(self, t: Transfer, deadline_us: float = 5e6) -> TransferStats:
        self.loop.run(until=self.loop.now + deadline_us)
        if not t.complete:
            raise RuntimeError(
                f"transfer tid={t.tid} incomplete after {deadline_us} us: "
                f"stats={t.stats}")
        return t.stats
