"""Core: the paper's contribution — page-fault handling for virtual-address
RDMA — as a composable library (see DESIGN.md §2 for the TPU adaptation).

The public, verbs-style API lives in :mod:`repro.api` (``Fabric`` /
``ProtectionDomain`` / ``MemoryRegion`` / ``CompletionQueue``); the
``RDMAEngine`` re-exported here is a deprecated shim over it."""

from repro.core.addresses import (BLOCK_SIZE, MTU, PAGE_SIZE, PAGES_PER_BLOCK,
                                  NetlinkMessage, RAPFMessage)
from repro.core.arbiter import ArbiterStats, DMAArbiter, ServiceClass
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL
from repro.core.engine import BufferPrep, RDMAEngine
from repro.core.fault import SMMU, Access, Disposition, FaultModel
from repro.core.fault_fifo import FaultFIFO, FIFOEntry
from repro.core.pagetable import (FrameAllocator, PageState, PageTable,
                                  SegmentationFault)
from repro.core.resolver import Resolution, Resolver, Strategy
from repro.core.simulator import EventLoop, Resource

__all__ = [
    "ArbiterStats", "BLOCK_SIZE", "DMAArbiter", "MTU", "PAGE_SIZE",
    "PAGES_PER_BLOCK", "ServiceClass",
    "NetlinkMessage", "RAPFMessage", "CostModel", "DEFAULT_COST_MODEL",
    "BufferPrep", "RDMAEngine", "SMMU", "Access", "Disposition", "FaultModel",
    "FaultFIFO", "FIFOEntry", "FrameAllocator", "PageState", "PageTable",
    "SegmentationFault", "Resolution", "Resolver", "Strategy",
    "EventLoop", "Resource",
]
