"""Firehose registration-cache baseline (Bell & Bonachea, thesis §2.2).

Models the pinning-based strategies the thesis argues against, for the
Fig. 2.3 working-set experiment:

* **PIN_EVERYTHING** — one pin of the whole segment at startup.
* **BOUNCE_BUFFER**  — pinned staging buffers + a copy per transfer.
* **RENDEZVOUS**     — pin/transfer/unpin handshake on every operation.
* **FIREHOSE**       — each node owns F firehoses (pinned remote buckets);
  hits are one-sided and pay nothing; misses move a firehose: round-trip
  synchronization + pin of the new bucket + (deferred) unpin of a victim
  beyond the MAXVICTIM FIFO.

The cliff the paper shows — latency jumping towards Rendezvous once the
working set exceeds M (+MAXVICTIM) — comes out of the hit-rate model here
and is checked in ``benchmarks/fig_2_3_firehose.py``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

from repro.core.addresses import PAGE_SIZE
from repro.core.costmodel import CostModel, DEFAULT_COST_MODEL


@dataclasses.dataclass
class FirehoseConfig:
    M_bytes: int = 400 << 20            # pinnable memory for firehoses
    maxvictim_bytes: int = 50 << 20     # deferred-unpin FIFO
    bucket_bytes: int = PAGE_SIZE       # single-page buckets (paper setup)
    n_nodes: int = 2
    rtt_us: float = 4.0                 # put round-trip (calibrated, Fig 4.1)

    @property
    def firehoses_per_node(self) -> int:
        # F = floor(M / (P * (nodes-1)))
        return (self.M_bytes // (self.bucket_bytes
                                 * max(1, self.n_nodes - 1)))


class FirehoseNode:
    """Initiator-side state: which remote buckets our firehoses map."""

    def __init__(self, cfg: FirehoseConfig, cost: CostModel = DEFAULT_COST_MODEL):
        self.cfg = cfg
        self.cost = cost
        self.capacity = cfg.firehoses_per_node
        self.mapped: OrderedDict[int, None] = OrderedDict()  # bucket -> LRU
        self.victim_fifo: deque[int] = deque()
        self.victim_capacity = cfg.maxvictim_bytes // cfg.bucket_bytes
        self.hits = 0
        self.misses = 0
        self.unpins = 0

    def put_latency_us(self, bucket: int, payload_bytes: int = 8) -> float:
        """Latency of an 8-byte put to ``bucket`` on the remote node."""
        base = self.cfg.rtt_us
        if bucket in self.mapped:
            self.mapped.move_to_end(bucket)
            self.hits += 1
            return base
        self.misses += 1
        extra = 0.0
        if len(self.mapped) >= self.capacity:
            old, _ = self.mapped.popitem(last=False)
            self.victim_fifo.append(old)
            if len(self.victim_fifo) > self.victim_capacity:
                # must synchronously unpin a victim bucket remotely
                self.victim_fifo.popleft()
                self.unpins += 1
                extra += self.cost.unpin_us(self.cfg.bucket_bytes)
        self.mapped[bucket] = None
        # round-trip to move the firehose + pin of the new bucket remotely
        extra += self.cfg.rtt_us + self.cost.pin_us(self.cfg.bucket_bytes)
        return base + extra

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 1.0


def rendezvous_put_latency_us(nbytes: int,
                              cost: CostModel = DEFAULT_COST_MODEL,
                              rtt_us: float = 4.0,
                              unpin: bool = True) -> float:
    """Rendezvous: advertise + remote pin, transfer, (optionally) unpin."""
    lat = rtt_us                       # control round-trip
    lat += cost.pin_us(nbytes)         # remote pins the region
    lat += rtt_us                      # the DMA itself (small payload)
    if unpin:
        lat += cost.unpin_us(nbytes)
    return lat


def bounce_buffer_put_latency_us(nbytes: int,
                                 cost: CostModel = DEFAULT_COST_MODEL,
                                 rtt_us: float = 4.0,
                                 copy_gbps: float = 3.0) -> float:
    """Bounce buffers: transfer into pinned staging + remote-side copy."""
    copy_us = nbytes * 8 / (copy_gbps * 1e3)
    interrupt_us = 2.0    # target CPU involvement per put
    return rtt_us + copy_us + interrupt_us
