"""Per-protection-domain page tables with demand paging, COW, pinning & THP.

Models the OS-side state the thesis' mechanism manipulates:

* **Demand paging** (§3.1.2.1): ``mmap`` creates *valid but non-resident*
  mappings; the first touch allocates a frame (minor fault).
* **Copy-on-write** (§3.1.2.2): shared read-only frames duplicated on write.
* **Transparent Huge Pages** (§3.1.2.3): a ``khugepaged`` model that
  transiently *invalidates* mappings of huge-page-aligned regions while
  collapsing them — the thesis' motivation for why even touched/pinned
  buffers still fault during RDMA.
* **Pinning** (§2.2): pinned pages are exempt from reclaim/THP, subject to a
  pinnable-memory limit M (the Firehose constraint).

Each page table corresponds to one SMMU context bank (§1.3.1.4): the SMMU
walks *these* tables, so invalidating a PTE here makes the next RDMA
translation fault.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Iterable, Optional

from repro.core.addresses import PAGE_SIZE, page_index

HUGE_PAGE_PAGES = 512  # 2 MB huge page = 512 x 4 KB


class PageState(enum.Enum):
    NOT_MAPPED = 0        # no VMA: access is a segmentation fault
    MAPPED_NOT_RESIDENT = 1   # valid mapping, no frame: minor fault on access
    RESIDENT = 2          # frame assigned, translation succeeds
    SWAPPED = 3           # frame reclaimed to swap: major fault on access


@dataclasses.dataclass
class PTE:
    state: PageState = PageState.NOT_MAPPED
    frame: int = -1
    pinned: bool = False
    cow: bool = False          # shared read-only; write triggers duplication
    writable: bool = True
    touched_epoch: int = -1    # LRU bookkeeping for reclaim


class OutOfFramesError(RuntimeError):
    pass


class SegmentationFault(RuntimeError):
    def __init__(self, pd: int, vpn: int):
        super().__init__(f"segfault pd={pd} vpn={vpn:#x}")
        self.pd = pd
        self.vpn = vpn


class PinLimitExceeded(RuntimeError):
    pass


class FrameAllocator:
    """Finite pool of physical frames shared by all protection domains.

    Eviction of unpinned frames backs the SWAPPED state (major faults) and
    the Firehose-style working-set experiments.
    """

    def __init__(self, total_frames: int = 1 << 22):  # 16 GB default
        self.total_frames = total_frames
        # lazy free pool: frames >= _next_fresh have never been handed
        # out, released frames recycle LIFO — allocation order is
        # identical to the seed's materialized descending list, without
        # building (total_frames) ints per node at fabric construction
        # (64-node fabrics paid seconds of setup for untouched frames)
        self._next_fresh = 0
        self._released: list[int] = []
        self.owner: dict[int, tuple[int, int]] = {}   # frame -> (pd, vpn)
        self.refcount: dict[int, int] = {}

    @property
    def used(self) -> int:
        return len(self.owner)

    @property
    def free_frames(self) -> int:
        return self.total_frames - self._next_fresh + len(self._released)

    def alloc(self, pd: int, vpn: int) -> int:
        if self._released:
            f = self._released.pop()
        elif self._next_fresh < self.total_frames:
            f = self._next_fresh
            self._next_fresh += 1
        else:
            raise OutOfFramesError("frame pool exhausted")
        self.owner[f] = (pd, vpn)
        self.refcount[f] = 1
        return f

    def share(self, frame: int) -> None:
        self.refcount[frame] += 1

    def release(self, frame: int) -> None:
        rc = self.refcount.get(frame, 0)
        if rc <= 1:
            self.owner.pop(frame, None)
            self.refcount.pop(frame, None)
            self._released.append(frame)
        else:
            self.refcount[frame] = rc - 1


@dataclasses.dataclass
class PageTableStats:
    minor_faults: int = 0
    major_faults: int = 0
    cow_breaks: int = 0
    thp_invalidations: int = 0
    pins: int = 0
    unpins: int = 0
    touches: int = 0


class PageTable:
    """One protection domain's page table (== one SMMU context bank)."""

    def __init__(self, pd: int, allocator: FrameAllocator,
                 pin_limit_bytes: Optional[int] = None):
        self.pd = pd
        self.allocator = allocator
        self.entries: dict[int, PTE] = {}
        self.pin_limit_pages = (
            None if pin_limit_bytes is None else pin_limit_bytes // PAGE_SIZE)
        self.pinned_pages = 0
        self.stats = PageTableStats()
        self.epoch = 0
        # Hooks: the SMMU driver registers here to shoot down its TLB when a
        # mapping is invalidated (the paper's invalidation flow, §2.1.1).
        self.invalidation_hooks: list[Callable[[int], None]] = []

    # ----------------------------------------------------------------- VMA
    def mmap(self, va: int, nbytes: int) -> None:
        """Create valid, *non-resident* mappings (demand paging)."""
        for vpn in range(page_index(va), page_index(va + nbytes - 1) + 1):
            if vpn not in self.entries or self.entries[vpn].state == PageState.NOT_MAPPED:
                self.entries[vpn] = PTE(state=PageState.MAPPED_NOT_RESIDENT)

    def munmap(self, va: int, nbytes: int) -> None:
        for vpn in range(page_index(va), page_index(va + nbytes - 1) + 1):
            pte = self.entries.get(vpn)
            if pte is None:
                continue
            if pte.state in (PageState.RESIDENT, PageState.SWAPPED) and pte.frame >= 0:
                self.allocator.release(pte.frame)
            if pte.pinned:
                self.pinned_pages -= 1
            del self.entries[vpn]
            self._notify_invalidation(vpn)

    def release_all(self) -> int:
        """Release every frame this domain holds (domain teardown).

        Bulk form of ``munmap`` over the whole table: frames return to
        the shared allocator, pins drop, PTEs clear.  Per-page
        invalidation hooks are *not* fired — on ``close_domain`` the SMMU
        bank is detached (full TLB shootdown) and the NP-RDMA MTT domain
        dropped wholesale, so per-page notification would only inflate
        shootdown counters O(pages).  Returns the frames released.
        """
        released = 0
        # lint: allow(det-dict-iter): frame reuse tracks PT insertion order
        for pte in self.entries.values():
            if (pte.state in (PageState.RESIDENT, PageState.SWAPPED)
                    and pte.frame >= 0):
                self.allocator.release(pte.frame)
                released += 1
        self.entries.clear()
        self.pinned_pages = 0
        return released

    # --------------------------------------------------------------- lookup
    def lookup(self, vpn: int) -> PTE:
        pte = self.entries.get(vpn)
        if pte is None:
            return PTE(state=PageState.NOT_MAPPED)
        return pte

    def is_resident(self, vpn: int) -> bool:
        return self.lookup(vpn).state == PageState.RESIDENT

    def resident_fraction(self, va: int, nbytes: int) -> float:
        vpns = range(page_index(va), page_index(va + nbytes - 1) + 1)
        n = len(vpns)
        return sum(self.is_resident(v) for v in vpns) / max(1, n)

    # --------------------------------------------------------------- faults
    def touch(self, vpn: int, write: bool = True) -> tuple[bool, PTE]:
        """CPU access to a page: resolve the fault like the MMU would.

        Returns ``(was_major, pte)``.  Raises SegmentationFault for unmapped
        pages (the library's ``sig_handler`` scenario, Fig 3.2).
        """
        pte = self.entries.get(vpn)
        if pte is None or pte.state == PageState.NOT_MAPPED:
            raise SegmentationFault(self.pd, vpn)
        self.stats.touches += 1
        self.epoch += 1
        major = False
        if pte.state == PageState.MAPPED_NOT_RESIDENT:
            pte.frame = self.allocator.alloc(self.pd, vpn)
            pte.state = PageState.RESIDENT
            self.stats.minor_faults += 1
        elif pte.state == PageState.SWAPPED:
            pte.frame = self.allocator.alloc(self.pd, vpn)
            pte.state = PageState.RESIDENT
            self.stats.major_faults += 1
            major = True
        if write and pte.cow:
            self._break_cow(vpn, pte)
        pte.touched_epoch = self.epoch
        return major, pte

    def get_user_pages(self, start_vpn: int, max_pages: int,
                       write: bool = True) -> int:
        """Kernel-space page-in of up to ``max_pages`` consecutive pages.

        Faithful to §3.2.2.1: stops at the first page that does not belong
        to the application's address space and returns the number of pages
        actually brought in.
        """
        n = 0
        for vpn in range(start_vpn, start_vpn + max_pages):
            pte = self.entries.get(vpn)
            if pte is None or pte.state == PageState.NOT_MAPPED:
                break
            self.touch(vpn, write=write)
            n += 1
        return n

    # ------------------------------------------------------------------ COW
    def fork_share(self, vpns: Iterable[int]) -> None:
        """Mark resident pages COW (shared read-only), as after fork()."""
        for vpn in vpns:
            pte = self.entries.get(vpn)
            if pte is not None and pte.state == PageState.RESIDENT:
                pte.cow = True
                self.allocator.share(pte.frame)

    def _break_cow(self, vpn: int, pte: PTE) -> None:
        old = pte.frame
        pte.frame = self.allocator.alloc(self.pd, vpn)
        self.allocator.release(old)
        pte.cow = False
        self.stats.cow_breaks += 1

    # -------------------------------------------------------------- pinning
    def pin(self, va: int, nbytes: int) -> None:
        vpns = list(range(page_index(va), page_index(va + nbytes - 1) + 1))
        new_pins = sum(1 for v in vpns if not self.lookup(v).pinned)
        if (self.pin_limit_pages is not None
                and self.pinned_pages + new_pins > self.pin_limit_pages):
            raise PinLimitExceeded(
                f"pin limit {self.pin_limit_pages} pages exceeded")
        for vpn in vpns:
            self.touch(vpn)  # pinning implies residency
            pte = self.entries[vpn]
            if not pte.pinned:
                pte.pinned = True
                self.pinned_pages += 1
        self.stats.pins += 1

    def unpin(self, va: int, nbytes: int) -> None:
        for vpn in range(page_index(va), page_index(va + nbytes - 1) + 1):
            pte = self.entries.get(vpn)
            if pte is not None and pte.pinned:
                pte.pinned = False
                self.pinned_pages -= 1
        self.stats.unpins += 1

    # -------------------------------------------------------------- reclaim
    def reclaim(self, n_pages: int) -> int:
        """Swap out up to n unpinned resident pages (LRU). Returns count."""
        candidates = sorted(
            ((pte.touched_epoch, vpn) for vpn, pte in self.entries.items()
             if pte.state == PageState.RESIDENT and not pte.pinned),
        )
        done = 0
        for _, vpn in candidates[:n_pages]:
            pte = self.entries[vpn]
            self.allocator.release(pte.frame)
            pte.frame = -1
            pte.state = PageState.SWAPPED
            self._notify_invalidation(vpn)
            done += 1
        return done

    # ------------------------------------------------------------------ THP
    def khugepaged_collapse(self, region_vpn: int) -> int:
        """Model one khugepaged pass over a 2 MB-aligned region (§3.1.2.3).

        While the kernel migrates small pages into a huge page, the old PTEs
        are transiently invalid — an RDMA translating through the SMMU in
        that window faults *even though the pages were touched*.  We model
        the observable effect: resident, unpinned PTEs in the region revert
        to MAPPED_NOT_RESIDENT (minor fault on next access) and SMMU TLBs
        are shot down.  Pinned pages are skipped (but note the thesis:
        pinning does not stop khugepaged scanning cost).
        """
        base = region_vpn - (region_vpn % HUGE_PAGE_PAGES)
        inv = 0
        for vpn in range(base, base + HUGE_PAGE_PAGES):
            pte = self.entries.get(vpn)
            if pte is not None and pte.state == PageState.RESIDENT and not pte.pinned:
                self.allocator.release(pte.frame)
                pte.frame = -1
                pte.state = PageState.MAPPED_NOT_RESIDENT
                self._notify_invalidation(vpn)
                inv += 1
        if inv:
            self.stats.thp_invalidations += inv
        return inv

    # ---------------------------------------------------------------- hooks
    def _notify_invalidation(self, vpn: int) -> None:
        for hook in self.invalidation_hooks:
            hook(vpn)
