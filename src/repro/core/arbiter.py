"""Fault-aware multi-tenant DMA arbiter (the thesis' §3.2 "adjustments to
the DMA scheduling logic", grown into a QoS scheduler).

The thesis prototype required the DMA engine to *pause* a faulting
transfer without stalling the engine.  The seed's ``R5Scheduler`` kept the
pause but not the "without stalling" part for multi-tenant traffic: every
launched block went straight to the PLDMA, so one tenant's fault storm
(pause → 1 ms timeout → full retransmit) could book the engine and the
wire ahead of everyone else's traffic — head-of-line blocking across
protection domains.

:class:`DMAArbiter` sits between the R5 block launcher and the PLDMA:

* **per-(domain, class) send queues** — launched blocks queue per
  protection domain, in one of two service classes:
  :attr:`ServiceClass.LATENCY` (serving-style small work requests) and
  :attr:`ServiceClass.BULK` (training/offload streams).  LATENCY queues
  are served with strict priority over BULK queues;
* **deficit round-robin across domains** within a class: each domain's
  queue accrues ``quantum × weight`` bytes of service credit per turn and
  dispatches whole blocks against it, so bandwidth shares follow the
  configured weights regardless of block sizes;
* **bounded PLDMA occupancy** — ``slots`` blocks may occupy the engine at
  once (default 2, the hardware's outstanding-block window, now shared by
  all tenants instead of granted per transfer);
* **deschedule-on-fault** — a block entering ``PAUSED_SRC``/``PAUSED_DST``
  yields its PLDMA slot *immediately*; the RAPF / retransmission-timeout
  re-enqueues it at the back of its class queue, so a faulting tenant
  waits out its own page faults instead of holding the engine;
* **per-domain outstanding-block quotas** — ``Fabric``'s posting verbs
  consult :meth:`DMAArbiter.over_quota` and refuse work beyond a domain's
  budget (:class:`~repro.api.completion.DomainQuotaExceeded`), turning
  runaway tenants into backpressure instead of queue growth.

Everything is observable: one :class:`ArbiterStats` per domain plus a
node-level total, with the invariant (checked by ``repro.testing``) that
the per-domain records sum to the total.
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import TYPE_CHECKING, Optional

from repro.core import addresses as A

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.core.node import Block, Node


class ServiceClass(enum.Enum):
    """Arbiter service class of a work request / protection domain.

    The class governs two arbitration points: the PLDMA slot scheduler
    below, and — on shared-link topologies (:mod:`repro.net`) — the wire
    itself, where :attr:`wire_priority` traffic overtakes BULK backlogs
    on every congested hop of its route.
    """
    LATENCY = "latency"      # serving-style small WRs: strict priority
    BULK = "bulk"            # training/offload streams: bandwidth-shared

    def __lt__(self, other: "ServiceClass") -> bool:   # stable sort keys
        return self.value < other.value

    @property
    def wire_priority(self) -> bool:
        """Does this class jump BULK queues on contended links?"""
        return self is ServiceClass.LATENCY


#: scheduling order: LATENCY queues are always served before BULK queues
CLASS_PRIORITY = (ServiceClass.LATENCY, ServiceClass.BULK)

#: default PLDMA occupancy: the hardware's two outstanding blocks,
#: now a *shared* resource arbitrated across all tenants of the node
DEFAULT_PLDMA_SLOTS = A.OUTSTANDING_BLOCKS_PER_TRANSFER


@dataclasses.dataclass(slots=True)
class ArbiterStats:
    """Per-domain (or node-total) arbiter telemetry.

    All fields except ``max_queue_depth`` are additive: the node total is
    the field-wise sum of the per-domain records (a ``repro.testing``
    invariant).  ``max_queue_depth`` is a high-water mark — per domain of
    its own queues, for the total of the node-wide backlog.
    """
    enqueued: int = 0            # fresh blocks entering the send queues
    dispatched: int = 0          # blocks granted a PLDMA slot
    completed: int = 0           # blocks ACKed
    deschedules: int = 0         # PAUSED_* blocks yielding their slot
    requeues: int = 0            # timeout/RAPF re-entries (back of queue)
    bytes_served: int = 0        # payload bytes of dispatched blocks
    quota_rejections: int = 0    # posts refused by the domain quota
    max_queue_depth: int = 0     # high-water mark (not additive)

    ADDITIVE = ("enqueued", "dispatched", "completed", "deschedules",
                "requeues", "bytes_served", "quota_rejections")


class _DomainQueue:
    """One (protection domain, service class) send queue with its DRR state."""

    __slots__ = ("pd", "service_class", "weight", "blocks", "deficit",
                 "credited", "in_ring")

    def __init__(self, pd: int, service_class: ServiceClass, weight: int):
        self.pd = pd
        self.service_class = service_class
        self.weight = max(1, weight)
        self.blocks: deque = deque()
        self.deficit = 0.0       # bytes of service credit (DRR counter)
        self.credited = False    # already credited for the current turn
        self.in_ring = False     # member of its class's active ring


class DMAArbiter:
    """Deficit-round-robin block scheduler in front of one node's PLDMA."""

    def __init__(self, node: "Node", slots: int = DEFAULT_PLDMA_SLOTS,
                 quantum_bytes: int = A.BLOCK_SIZE):
        if slots < 1:
            raise ValueError(f"need at least one PLDMA slot, got {slots}")
        if quantum_bytes < 1:
            raise ValueError(f"DRR quantum must be >= 1 B, got {quantum_bytes}")
        self.node = node
        self.slots = slots
        self.quantum = quantum_bytes
        self.in_flight = 0                   # blocks occupying PLDMA slots
        # (pd, class) -> queue; active rings hold queues with blocks
        self.queues: dict[tuple[int, ServiceClass], _DomainQueue] = {}
        self._active: dict[ServiceClass, deque] = {
            cls: deque() for cls in CLASS_PRIORITY}
        # domain registration (class/weight/quota defaults per pd)
        self._dom_class: dict[int, ServiceClass] = {}
        self._dom_weight: dict[int, int] = {}
        self._dom_quota: dict[int, Optional[int]] = {}
        self._outstanding: dict[int, int] = {}   # launched, not-yet-done
        # O(1) queue-depth counters (node total + per domain): queue_depth
        # is consulted on EVERY enqueue for the high-water stats, and the
        # seed's sum-over-queues scan made intake O(domains) per block
        self._depth_total = 0
        self._depth_by_pd: dict[int, int] = {}
        self.stats = ArbiterStats()              # node-wide total
        self.domain_stats: dict[int, ArbiterStats] = {}
        # cached enum member: enqueue/_pump test it per block, and the
        # import is circular at module load (node.py imports arbiter) but
        # fine here — a DMAArbiter only exists once its Node does
        from repro.core.node import BlockState
        self._done = BlockState.DONE
        # DRR rotation bound factor, hoisted out of _next_block (the
        # integer division showed up at million-block scale)
        self._rot_factor = A.BLOCK_SIZE // self.quantum + 2

    # ------------------------------------------------------------ domains
    def register_domain(self, pd: int,
                        service_class: Optional[ServiceClass] = None,
                        weight: int = 1,
                        max_outstanding_blocks: Optional[int] = None) -> None:
        """Declare a domain's arbitration parameters (idempotent)."""
        self._dom_class[pd] = service_class or ServiceClass.BULK
        self._dom_weight[pd] = max(1, weight)
        self._dom_quota[pd] = max_outstanding_blocks
        self.domain_stats.setdefault(pd, ArbiterStats())

    def class_of(self, pd: int) -> ServiceClass:
        return self._dom_class.get(pd, ServiceClass.BULK)

    def outstanding(self, pd: int) -> int:
        """Blocks of ``pd`` submitted and not yet completed (pending
        launch, queued, in a PLDMA slot, or paused awaiting RAPF/timeout)."""
        return self._outstanding.get(pd, 0)

    def note_submit(self, transfer) -> None:
        """Count a posted transfer's blocks against its domain quota
        (called synchronously from the fabric's posting verbs — for both
        writes and reads — so quota checks see work the moment it is
        posted, not when blocks launch on this node)."""
        pd = transfer.pd
        self._outstanding[pd] = (self._outstanding.get(pd, 0)
                                 + len(transfer.blocks))

    def over_quota(self, pd: int) -> bool:
        """Is the domain at (or beyond) its outstanding-block quota?

        Posts are refused while ``outstanding >= quota``; a single work
        request may overshoot the quota by its own block count (the quota
        is a backpressure threshold, not a hard block-count ceiling).
        """
        quota = self._dom_quota.get(pd)
        return quota is not None and self.outstanding(pd) >= quota

    def note_quota_rejection(self, pd: int) -> None:
        self._stats_for(pd).quota_rejections += 1
        self.stats.quota_rejections += 1

    def queue_depth(self, pd: Optional[int] = None) -> int:
        if pd is None:
            return self._depth_total
        return self._depth_by_pd.get(pd, 0)

    def _stats_for(self, pd: int) -> ArbiterStats:
        # hot path (every enqueue/dispatch/completion): probe the dict
        # once instead of allocating a throwaway default per setdefault
        st = self.domain_stats.get(pd)
        if st is None:
            st = self.domain_stats[pd] = ArbiterStats()
        return st

    def _queue_for(self, pd: int, cls: ServiceClass) -> _DomainQueue:
        q = self.queues.get((pd, cls))
        if q is None:
            if pd not in self._dom_class:
                self.register_domain(pd)
            q = _DomainQueue(pd, cls, self._dom_weight.get(pd, 1))
            self.queues[(pd, cls)] = q
        return q

    # ------------------------------------------------------------- intake
    def enqueue(self, block: "Block", *, retransmit: bool = False) -> None:
        """Queue a block for a PLDMA slot (fresh launch or re-entry).

        Re-entries go to the *back* of their class queue — a faulting
        block that lost its slot does not jump fresh traffic.
        """
        if block.queued or block.state is self._done:
            return
        pd = block.transfer.pd
        cls = (block.transfer.service_class or self.class_of(pd))
        block.service_class = cls
        block.is_retransmit = retransmit
        block.queued = True
        q = self._queue_for(pd, cls)
        q.blocks.append(block)
        total = self._depth_total + 1
        self._depth_total = total
        depth = self._depth_by_pd.get(pd, 0) + 1
        self._depth_by_pd[pd] = depth
        if not q.in_ring:
            q.in_ring = True
            self._active[cls].append(q)
        st = self.domain_stats.get(pd)       # _stats_for, inlined (hot)
        if st is None:
            st = self.domain_stats[pd] = ArbiterStats()
        tot_st = self.stats
        if retransmit:
            st.requeues += 1
            tot_st.requeues += 1
        else:
            st.enqueued += 1
            tot_st.enqueued += 1
        if depth > st.max_queue_depth:            # high-water marks
            st.max_queue_depth = depth
        if total > tot_st.max_queue_depth:
            tot_st.max_queue_depth = total
        self._pump()

    def requeue(self, block: "Block") -> None:
        """Timeout/RAPF re-entry: release the slot if held, back of queue.

        Idempotent against the timeout-then-late-RAPF race: a block that
        is already queued, or already granted a slot with its dispatch
        event in flight (``grant_pending``), is on its way to retransmit
        — a second requeue must not steal the slot or double-queue it.
        """
        if block.queued or block.grant_pending:
            return
        self._release_slot(block, descheduled=False)
        self.enqueue(block, retransmit=True)

    # ---------------------------------------------------------- slot events
    def on_block_paused(self, block: "Block") -> None:
        """Deschedule-on-fault: a PAUSED_* block yields its slot NOW."""
        if self._release_slot(block, descheduled=True):
            self._pump()

    def on_block_done(self, block: "Block") -> None:
        pd = block.transfer.pd
        st = self.domain_stats.get(pd)       # _stats_for, inlined (hot)
        if st is None:
            st = self.domain_stats[pd] = ArbiterStats()
        st.completed += 1
        self.stats.completed += 1
        left = self._outstanding.get(pd, 0) - 1
        self._outstanding[pd] = max(0, left)
        if self._release_slot(block, descheduled=False):
            self._pump()

    # ----------------------------------------------------------- crash fault
    def purge(self, block: "Block") -> None:
        """Remove a terminally-failed block from the scheduler entirely:
        drop it from its send queue (if queued) and release its PLDMA
        slot (if held).  No completion stats — the block did not finish;
        quota release happens per transfer in :meth:`on_transfer_failed`.
        """
        released = False
        if block.queued:
            pd = block.transfer.pd
            cls = block.service_class or self.class_of(pd)
            q = self.queues.get((pd, cls))
            if q is not None:
                try:
                    q.blocks.remove(block)
                except ValueError:          # pragma: no cover - defensive
                    pass
                else:
                    self._depth_total -= 1
                    self._depth_by_pd[pd] -= 1
            block.queued = False
        if self._release_slot(block, descheduled=False):
            released = True
        if released:
            self._pump()

    def on_transfer_failed(self, transfer) -> None:
        """Release the quota held by a failed transfer's unfinished blocks
        (its ACKed blocks already released theirs in :meth:`on_block_done`,
        so the drained-fabric invariant ``outstanding(pd) == 0`` survives
        crashes and retry exhaustion)."""
        pd = transfer.pd
        remaining = len(transfer.blocks) - transfer.done_blocks
        if remaining > 0:
            left = self._outstanding.get(pd, 0) - remaining
            self._outstanding[pd] = max(0, left)

    def _release_slot(self, block: "Block", descheduled: bool) -> bool:
        if not block.holds_slot:
            return False
        block.holds_slot = False
        self.in_flight -= 1
        if descheduled:
            st = self._stats_for(block.transfer.pd)
            st.deschedules += 1
            self.stats.deschedules += 1
        return True

    # ------------------------------------------------------------ scheduling
    def _pump(self) -> None:
        """Grant free PLDMA slots to queued blocks per class/DRR order."""
        while self.in_flight < self.slots:
            block = self._next_block()
            if block is None:
                return
            block.queued = False
            if block.state is self._done:      # completed while queued
                continue
            block.holds_slot = True
            block.grant_pending = True
            self.in_flight += 1
            pd = block.transfer.pd
            nbytes = block.nbytes
            st = self.domain_stats.get(pd)   # _stats_for, inlined (hot)
            if st is None:
                st = self.domain_stats[pd] = ArbiterStats()
            st.dispatched += 1
            st.bytes_served += nbytes
            tot_st = self.stats
            tot_st.dispatched += 1
            tot_st.bytes_served += nbytes
            node = self.node
            delay = (node.cost.retransmit_setup_us
                     if block.is_retransmit else node.cost.per_block_r5_us)
            node.loop.schedule(delay, node.r5._dispatch, block,
                               block.is_retransmit)

    def _next_block(self) -> Optional["Block"]:
        """Deficit round robin, LATENCY ring strictly before BULK."""
        for cls in CLASS_PRIORITY:
            active = self._active[cls]
            if not active:
                continue
            # a full rotation credits every queue by quantum × weight, so
            # some head fits within ceil(BLOCK_SIZE / quantum) + 1 rotations
            max_rot = (len(active) + 1) * self._rot_factor
            rotations = 0
            while active and rotations <= max_rot:
                q = active[0]
                if not q.blocks:
                    # drained queue leaves the ring; credit does not hoard
                    active.popleft()
                    q.in_ring = False
                    q.deficit = 0.0
                    q.credited = False
                    continue
                if not q.credited:
                    q.deficit += self.quantum * q.weight
                    q.credited = True
                head = q.blocks[0]
                if q.deficit >= head.nbytes:
                    q.deficit -= head.nbytes
                    block = q.blocks.popleft()
                    self._depth_total -= 1
                    self._depth_by_pd[q.pd] -= 1
                    if not q.blocks:
                        active.popleft()
                        q.in_ring = False
                        q.deficit = 0.0
                        q.credited = False
                    return block
                # credit did not cover the head block: turn passes
                q.credited = False
                active.rotate(-1)
                rotations += 1
        return None

    # ------------------------------------------------------------ invariants
    def depth_counter_violations(self) -> list[str]:
        """The O(1) depth counters must equal the actual queue contents."""
        out = []
        actual_total = sum(len(q.blocks) for q in self.queues.values())
        if actual_total != self._depth_total:
            out.append(f"node {self.node.node_id}: depth counter "
                       f"{self._depth_total} != actual backlog {actual_total}")
        # lint: allow(det-dict-iter): diagnostic list order only
        for pd, n in self._depth_by_pd.items():
            actual = sum(len(q.blocks) for q in self.queues.values()
                         if q.pd == pd)
            if actual != n:
                out.append(f"node {self.node.node_id} pd={pd}: depth counter "
                           f"{n} != actual backlog {actual}")
        return out

    def deficit_bound_violations(self) -> list[str]:
        """DRR fairness bound: 0 <= deficit <= BLOCK_SIZE + quantum × weight.

        A queue is credited quantum × weight per turn and serves whole
        blocks (each ≤ BLOCK_SIZE) against the credit, so the counter can
        never exceed one un-served head (< BLOCK_SIZE) plus one fresh
        credit.  ``repro.testing`` asserts this after a soak.
        """
        out = []
        # lint: allow(det-dict-iter): diagnostic list order only
        for (pd, cls), q in self.queues.items():
            hi = A.BLOCK_SIZE + self.quantum * q.weight
            if not (0.0 <= q.deficit <= hi):
                out.append(
                    f"node {self.node.node_id} pd={pd} {cls.value}: "
                    f"deficit {q.deficit} outside [0, {hi}]")
        return out
