"""Sharded per-node event processing (opt-in, ``FabricConfig(shards=)``).

The single :class:`~repro.core.simulator.EventLoop` wheel serializes the
whole fabric through one queue.  This module partitions the fabric's
nodes into ``shards`` groups, each owning a private bucketed wheel, and
merges them under the classic conservative-lookahead rule
(Chandy–Misra–Bryant): with

    lookahead = min routed link latency  (one hop, ``hop_latency_us``)

no shard can receive a cross-shard event earlier than
``min(head of every shard) + lookahead``, because every cross-node
message must cross at least one physical link.  ``safe_horizon()``
exposes that bound — a parallel executor may run every shard to it
without inter-shard synchronization.

The sequential executor below fires events strictly in global
``(time, seq)`` order (shards share one sequence counter and one
clock), so a sharded fabric is **byte-identical** to the single-wheel
fabric on every topology — the equivalence tests in
``tests/test_sharded.py`` assert exactly that.  What sharding buys
today is bounded per-queue size (each wheel holds only its nodes'
events) and the scaffold for parallel execution; the lookahead rule is
the contract a threaded or multi-process driver would build on.

The partitioning idiom — one host presenting N logical execution
shards, selected by a config knob — follows the JAX host-platform
device-count pattern (``xla_force_host_platform_device_count``; see
SNIPPETS.md snippet 1): the topology of the work does not change, only
how many queues serve it.

Shard assignment is ``node_id % shards``: round-robin keeps
neighbouring torus/ring nodes in *different* shards, which is the
adversarial case for the lookahead rule and therefore the one the
equivalence tests exercise.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Optional

from repro.core.simulator import Event, EventLoop


class _ShardWheel(EventLoop):
    """One shard's bucketed wheel, sharing the parent's clock and the
    global schedule-sequence counter (the frozen ``(time, seq)``
    tie-break must stay *global*, or same-time events in different
    shards would lose their schedule-order contract)."""

    def __init__(self, parent: "ShardedEventLoop"):
        self.parent = parent        # before super(): the clock property
        super().__init__()
        self._seq = parent._seq     # shared global sequence counter

    @property
    def now(self) -> float:
        return self.parent.now

    @now.setter
    def now(self, t: float) -> None:
        self.parent.now = t


class ShardHandle:
    """A node-facing facade of one shard: ``schedule``/``at`` land in
    the shard's wheel (and refresh the parent's head cache); clock and
    drain queries delegate to the parent, so protocol code is oblivious
    to whether it runs sharded."""

    __slots__ = ("parent", "wheel", "index")

    def __init__(self, parent: "ShardedEventLoop", index: int):
        self.parent = parent
        self.index = index
        self.wheel = parent.shards[index]

    @property
    def now(self) -> float:
        return self.parent.now

    def schedule(self, delay: float, fn: Callable[..., Any],
                 *args: Any) -> Event:
        ev = self.wheel.schedule(delay, fn, *args)
        heads = self.parent._heads
        h = heads[self.index]
        if h is None or ev.time < h[0] or (ev.time == h[0]
                                           and ev.seq < h[1]):
            heads[self.index] = (ev.time, ev.seq)
        return ev

    def at(self, time: float, fn: Callable[..., Any], *args: Any) -> Event:
        return self.schedule(max(0.0, time - self.parent.now), fn, *args)

    # drain/introspection: the per-shard view is not meaningful to
    # protocol code — answer for the whole fabric
    def run(self, until: Optional[float] = None,
            max_events: int = 50_000_000) -> None:
        self.parent.run(until=until, max_events=max_events)

    def run_batch(self, limit: int) -> int:
        return self.parent.run_batch(limit)

    def step(self) -> bool:
        return self.parent.step()

    def peek_time(self) -> Optional[float]:
        return self.parent.peek_time()

    @property
    def idle(self) -> bool:
        return self.parent.idle

    @property
    def events_processed(self) -> int:
        return self.parent.events_processed


class ShardedEventLoop:
    """``EventLoop``-compatible facade over N per-shard wheels.

    Firing is a head-merge: the cached ``(time, seq)`` head of every
    shard is scanned, the globally smallest is validated against its
    wheel (cancellations make cached heads stale-early, never
    stale-late) and fired.  Handlers scheduling into any shard refresh
    that shard's cached head through their :class:`ShardHandle`, so the
    cache is always conservative and the merge never misses an event.
    """

    def __init__(self, n_shards: int, lookahead_us: float):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if lookahead_us <= 0:
            raise ValueError(
                f"lookahead_us must be > 0 (the minimum routed link "
                f"latency), got {lookahead_us}")
        self.now: float = 0.0
        self.lookahead_us = lookahead_us
        self._seq = itertools.count()
        self.shards = [_ShardWheel(self) for _ in range(n_shards)]
        self._heads: list[Optional[tuple[float, int]]] = [None] * n_shards
        self._handles = [ShardHandle(self, i) for i in range(n_shards)]

    # ------------------------------------------------------------ wiring
    def handle_for(self, node_id: int) -> ShardHandle:
        """The :class:`ShardHandle` serving ``node_id`` (round-robin)."""
        return self._handles[node_id % len(self.shards)]

    # ------------------------------------------------------------- heads
    def _refresh(self, i: int) -> Optional[tuple[float, int]]:
        wheel = self.shards[i]
        if wheel.peek_time() is None:
            self._heads[i] = None
            return None
        entry = wheel._active[0]
        head = (entry[0], entry[1])
        self._heads[i] = head
        return head

    def _select(self) -> int:
        """Index of the shard holding the globally next live event, or
        -1 when every shard is drained.  Cached heads can be stale-early
        (their event was cancelled); validate-and-rescan fixes that."""
        heads = self._heads
        while True:
            best = -1
            best_head = None
            for i, h in enumerate(heads):
                if h is not None and (best_head is None or h < best_head):
                    best_head = h
                    best = i
            if best < 0:
                return -1
            if self._refresh(best) == best_head:
                return best
            # stale head (cancelled/compacted): rescan with it corrected

    # ---------------------------------------------------------- execution
    def run(self, until: Optional[float] = None,
            max_events: int = 50_000_000) -> None:
        fired = 0
        while True:
            i = self._select()
            if i < 0:
                return
            if until is not None and self._heads[i][0] > until:
                return
            if fired >= max_events:
                raise RuntimeError("event budget exhausted — livelock?")
            fired += 1
            self.shards[i].run_batch(1)
            self._refresh(i)

    def run_batch(self, limit: int) -> int:
        fired = 0
        while fired < limit:
            i = self._select()
            if i < 0:
                break
            self.shards[i].run_batch(1)
            self._refresh(i)
            fired += 1
        return fired

    def step(self) -> bool:
        return self.run_batch(1) == 1

    def peek_time(self) -> Optional[float]:
        i = self._select()
        return None if i < 0 else self._heads[i][0]

    def safe_horizon(self) -> Optional[float]:
        """The conservative-lookahead bound: every shard may execute all
        its events strictly below this time with no inter-shard merge —
        no cross-shard event can arrive earlier, because it must cross
        at least one link (``lookahead_us`` = min routed link latency).
        ``None`` when the fabric is drained."""
        t = self.peek_time()
        return None if t is None else t + self.lookahead_us

    # ------------------------------------------- fabric-level scheduling
    # (post verbs, harness timers — routed to shard 0; any shard works,
    # the merge preserves global order regardless of placement)
    def schedule(self, delay: float, fn: Callable[..., Any],
                 *args: Any) -> Event:
        return self._handles[0].schedule(delay, fn, *args)

    def at(self, time: float, fn: Callable[..., Any], *args: Any) -> Event:
        return self._handles[0].at(time, fn, *args)

    # --------------------------------------------------------- accounting
    @property
    def idle(self) -> bool:
        return all(w._n_queued <= w._n_cancelled for w in self.shards)

    @property
    def events_processed(self) -> int:
        return sum(w.events_processed for w in self.shards)

    @property
    def compactions(self) -> int:
        return sum(w.compactions for w in self.shards)
