"""Deterministic discrete-event simulation kernel.

Pure virtual time (microseconds, float).  No wall-clock, no randomness
unless a seeded RNG is explicitly passed to a component — identical inputs
give identical traces, which the property tests rely on.

Two interchangeable queue implementations share the :class:`Event`
contract and the frozen ``(time, seq)`` tie-break (same-time events fire
in schedule order, always):

* :class:`EventLoop` — the default **bucketed event wheel** (calendar
  queue).  The protocol's delay spectrum is dominated by a few classes —
  sub-microsecond driver/completion hops, the 0.1 us link hop, the
  200 us poll cadence, the 1 ms retransmission timeout — so almost every
  event lands within a few thousand microseconds of *now*.  The wheel
  covers that horizon with fixed-width buckets; only the far tail (lease
  expiries, long arrival periods) pays for a real heap.
* :class:`HeapEventLoop` — the previous global binary heap, kept as the
  A/B reference behind ``REPRO_EVENT_LOOP=heap`` (the equivalence
  property tests drive both and assert identical traces).

``make_event_loop()`` picks by the ``REPRO_EVENT_LOOP`` environment
variable; :class:`repro.api.fabric.Fabric` goes through it.
"""

from __future__ import annotations

import heapq
import itertools
import os
from typing import Any, Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "args", "cancelled", "loop")

    def __init__(self, time: float, seq: int, fn: Callable[..., Any],
                 args: tuple, loop: "Optional[EventLoop]" = None):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.args = args
        self.cancelled = False
        self.loop = loop

    def cancel(self) -> None:
        if not self.cancelled:
            self.cancelled = True
            if self.loop is not None:
                self.loop._n_cancelled += 1

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


#: allocation shortcut for the schedule() hot path: build the Event with
#: direct slot stores instead of an ``__init__`` frame (identical object)
_EVENT_NEW = Event.__new__


# ---------------------------------------------------------------- wheel
#: bucket width in virtual microseconds.  A power of two, so the index
#: computation ``int(t * _WHEEL_INV)`` is an exact binary scale of the
#: float timestamp: two timestamps compare the same way their bucket
#: indices do, which is what keeps cross-bucket ordering exact.
WHEEL_BUCKET_US = 8.0
_WHEEL_INV = 1.0 / WHEEL_BUCKET_US          # exact (power of two)
#: wheel span in buckets (power of two).  8192 us of horizon: the poll
#: cadence (200 us), every driver/wire delay and the 1 ms timeout round
#: all land in-wheel; only lease expiries and long open-loop arrival
#: periods overflow to the far-future heap.
WHEEL_SPAN = 1024
_WHEEL_MASK = WHEEL_SPAN - 1


class EventLoop:
    """Bucketed event wheel (calendar queue), the default kernel.

    Three tiers, ordered by distance from *now*:

    * ``_active`` — a small binary heap of ``(time, seq, Event)`` entries
      holding every event of the *current* bucket.  Pops come from here;
      new events that land at or before the current bucket are pushed
      here, so intra-bucket ordering is exact.
    * ``_buckets`` — ``WHEEL_SPAN`` unsorted append-only lists covering
      the next ``WHEEL_SPAN × WHEEL_BUCKET_US`` microseconds.  Scheduling
      into the window is an O(1) append; a bucket is heapified once, when
      it becomes current.  ``_pending_buckets`` is a heap of the
      *non-empty* bucket indices, so advancing skips empty buckets in
      O(log buckets-in-use) instead of scanning.
    * ``_overflow`` — a binary heap for events beyond the window (the
      far-future tail); entries migrate into ``_active`` when their
      bucket comes up.

    Cancelled events (every ACKed block cancels its 1 ms timeout) are
    reclaimed in bulk when their bucket activates — the filter happens
    *before* the heapify, so, unlike the heap loop, a cancelled timeout
    never costs a single sift.  ``compactions`` counts those bulk sweeps.

    The ``(time, seq)`` tie-break contract is frozen: same-time events
    fire in schedule-sequence order, bit-identical to the heap loop (the
    ``tests/test_event_loop_equiv.py`` property drives both).
    """

    #: kept for API parity with the heap loop (compaction threshold there)
    COMPACT_MIN = 1024

    def __init__(self):
        self.now: float = 0.0
        self._seq = itertools.count()
        self._active: list[tuple[float, int, Event]] = []
        self._cur = 0                 # absolute index of the active bucket
        self._buckets: list[list] = [[] for _ in range(WHEEL_SPAN)]
        self._pending_buckets: list[int] = []   # heap of non-empty indices
        self._overflow: list[tuple[float, int, Event]] = []
        self._n_queued = 0            # entries enqueued (incl. cancelled)
        self._n_cancelled = 0         # cancelled events still enqueued
        self.events_processed = 0
        self.compactions = 0          # bulk cancelled-entry sweeps

    def schedule(self, delay: float, fn: Callable[..., Any], *args: Any) -> Event:
        assert delay >= 0, f"negative delay {delay}"
        t = self.now + delay
        seq = next(self._seq)
        ev = _EVENT_NEW(Event)
        ev.time = t
        ev.seq = seq
        ev.fn = fn
        ev.args = args
        ev.cancelled = False
        ev.loop = self
        b = int(t * _WHEEL_INV)
        cur = self._cur
        if b <= cur:
            heapq.heappush(self._active, (t, seq, ev))
        elif b - cur < WHEEL_SPAN:
            lst = self._buckets[b & _WHEEL_MASK]
            if not lst:
                # bucket indices are pushed only on an empty->non-empty
                # transition, so every entry in this heap is unique
                # lint: allow(det-heap-tiebreak): unique int keys, no tie
                heapq.heappush(self._pending_buckets, b)
            lst.append((t, seq, ev))
        else:
            heapq.heappush(self._overflow, (t, seq, ev))
        self._n_queued += 1
        return ev

    def at(self, time: float, fn: Callable[..., Any], *args: Any) -> Event:
        return self.schedule(max(0.0, time - self.now), fn, *args)

    def _refill(self) -> bool:
        """Advance to the next non-empty bucket; False when drained.

        Structural only: ``now`` does not move until an event fires, so
        ``peek_time()`` may refill without advancing the clock.
        """
        while not self._active:
            pend = self._pending_buckets
            over = self._overflow
            if pend:
                b = pend[0]
                if over:
                    b2 = int(over[0][0] * _WHEEL_INV)
                    if b2 < b:
                        b = b2
            elif over:
                b = int(over[0][0] * _WHEEL_INV)
            else:
                return False
            self._cur = b
            if pend and pend[0] == b:
                heapq.heappop(pend)
                slot = self._buckets[b & _WHEEL_MASK]
                active = [e for e in slot if not e[2].cancelled]
                swept = len(slot) - len(active)
                if swept:
                    self._n_queued -= swept
                    self._n_cancelled -= swept
                    self.compactions += 1
                del slot[:]
                heapq.heapify(active)
            else:
                active = []
            while over and int(over[0][0] * _WHEEL_INV) == b:
                # lint: allow(det-heap-tiebreak): migrates an existing (time, seq, Event) tuple between tiers — seq is the tie-break
                heapq.heappush(active, heapq.heappop(over))
            self._active = active
        return True

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        """Run until drained / past ``until``; ``max_events`` bounds THIS
        call (a livelock guard, not a cumulative-counter trip wire)."""
        fired = 0
        heappop = heapq.heappop
        while True:
            active = self._active
            if not active:
                if not self._refill():
                    return
                active = self._active
            entry = heappop(active)
            ev = entry[2]
            if ev.cancelled:
                self._n_queued -= 1
                self._n_cancelled -= 1
                continue
            if until is not None and entry[0] > until:
                heapq.heappush(active, entry)
                return
            if fired >= max_events:
                heapq.heappush(active, entry)
                raise RuntimeError("event budget exhausted — livelock?")
            fired += 1
            self.now = entry[0]
            self.events_processed += 1
            self._n_queued -= 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)

    def run_batch(self, limit: int) -> int:
        """Fire up to ``limit`` live events; returns how many fired.

        The chunked-stepping API: harness driver loops (``soak()``) call
        this once per chunk instead of ``step()`` per event, keeping the
        per-event overhead inside the kernel's tight loop.  0 means the
        loop is drained.
        """
        fired = 0
        heappop = heapq.heappop
        while fired < limit:
            active = self._active
            if not active:
                if not self._refill():
                    break
                active = self._active
            t, _, ev = heappop(active)
            if ev.cancelled:
                self._n_queued -= 1
                self._n_cancelled -= 1
                continue
            self.now = t
            self.events_processed += 1
            self._n_queued -= 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)
            fired += 1
        return fired

    def peek_time(self) -> Optional[float]:
        """Time of the next live event, or None if the loop is drained."""
        while True:
            active = self._active
            if not active:
                if not self._refill():
                    return None
                active = self._active
            if active[0][2].cancelled:
                heapq.heappop(active)
                self._n_queued -= 1
                self._n_cancelled -= 1
                continue
            return active[0][0]

    def step(self) -> bool:
        """Execute exactly one live event.  Returns False if none remain.

        Lets completion-queue ``wait()`` stop the clock at the instant a
        completion is delivered instead of free-running to a deadline.
        """
        return self.run_batch(1) == 1

    @property
    def idle(self) -> bool:
        # the counters make this O(1): live = queued - cancelled
        return self._n_queued <= self._n_cancelled


class HeapEventLoop(EventLoop):
    """Global binary-heap event queue — the pre-wheel kernel, kept as the
    A/B reference (``REPRO_EVENT_LOOP=heap``).

    * **Tuple-keyed heap** — entries are ``(time, seq, Event)``, so sift
      comparisons resolve on the C-level float/int compare (``seq`` is
      unique, the :class:`Event` is never compared).  The seed heaped
      ``Event`` objects directly, paying a Python ``__lt__`` call per
      comparison — the single hottest function at scale.
    * **Lazy-cancel compaction** — cancelled events (every ACKed block
      cancels its 1 ms retransmission timeout) stay heaped until their
      timestamp; under a million-block soak they would dominate the heap
      and tax every push/pop with a larger log factor.  The loop counts
      live cancellations and rebuilds the heap whenever cancelled entries
      outnumber live ones: an amortized-O(1) sweep keeping heap
      operations sized to *live* work.
    """

    #: don't bother compacting heaps smaller than this
    COMPACT_MIN = 1024

    def __init__(self):
        self.now: float = 0.0
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._n_cancelled = 0         # cancelled events still in the heap
        self.events_processed = 0
        self.compactions = 0

    def schedule(self, delay: float, fn: Callable[..., Any], *args: Any) -> Event:
        assert delay >= 0, f"negative delay {delay}"
        t = self.now + delay
        seq = next(self._seq)
        ev = _EVENT_NEW(Event)
        ev.time = t
        ev.seq = seq
        ev.fn = fn
        ev.args = args
        ev.cancelled = False
        ev.loop = self
        heap = self._heap
        if self._n_cancelled > self.COMPACT_MIN \
                and self._n_cancelled * 2 > len(heap):
            self._heap = heap = [h for h in heap if not h[2].cancelled]
            heapq.heapify(heap)
            self._n_cancelled = 0
            self.compactions += 1
        heapq.heappush(heap, (t, seq, ev))
        return ev

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        fired = 0
        heap = self._heap
        while heap:
            entry = heapq.heappop(heap)
            ev = entry[2]
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            if until is not None and entry[0] > until:
                heapq.heappush(heap, entry)
                return
            if fired >= max_events:
                # the budget bounds THIS call, not the loop's lifetime —
                # a long soak followed by a later run() must not trip it
                heapq.heappush(heap, entry)
                raise RuntimeError("event budget exhausted — livelock?")
            fired += 1
            self.now = entry[0]
            self.events_processed += 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)
            heap = self._heap   # schedule() may have compacted

    def run_batch(self, limit: int) -> int:
        fired = 0
        heap = self._heap
        while fired < limit and heap:
            t, _, ev = heapq.heappop(heap)
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            self.now = t
            self.events_processed += 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)
            fired += 1
            heap = self._heap   # schedule() may have compacted
        return fired

    def peek_time(self) -> Optional[float]:
        """Time of the next live event, or None if the loop is drained."""
        heap = self._heap
        while heap and heap[0][2].cancelled:
            heapq.heappop(heap)
            self._n_cancelled -= 1
        return heap[0][0] if heap else None

    def step(self) -> bool:
        """Execute exactly one live event.  Returns False if none remain."""
        while self._heap:
            t, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            self.now = t
            self.events_processed += 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)
            return True
        return False

    @property
    def idle(self) -> bool:
        # the cancellation counter makes this O(1): live = total - cancelled
        return len(self._heap) <= self._n_cancelled


def make_event_loop() -> EventLoop:
    """The configured kernel: the wheel, or ``REPRO_EVENT_LOOP=heap`` for
    the legacy binary heap (A/B comparisons, bisecting a trace diff)."""
    kind = os.environ.get("REPRO_EVENT_LOOP", "wheel")
    if kind == "heap":
        return HeapEventLoop()
    if kind not in ("", "wheel"):
        raise ValueError(
            f"REPRO_EVENT_LOOP must be 'wheel' or 'heap', got {kind!r}")
    return EventLoop()


class Resource:
    """A serially-occupied resource (a CPU core, a link).

    ``reserve(duration)`` books the next available slot at or after *now*
    and returns ``(start, end)``; callers schedule their completion events
    at ``end``.  ``busy_overlap`` reports whether the reservation had to
    queue — the link-interleaving signal used by the PLDMA model.
    """

    def __init__(self, loop: EventLoop, name: str = ""):
        self.loop = loop
        self.name = name
        self.busy_until: float = 0.0
        self.busy_time: float = 0.0
        self.reservations = 0

    def reserve(self, duration: float) -> tuple[float, float]:
        start = self.loop.now
        if self.busy_until > start:
            start = self.busy_until
        end = start + duration
        self.busy_until = end
        self.busy_time += duration
        self.reservations += 1
        return start, end

    def would_queue(self) -> bool:
        return self.busy_until > self.loop.now
