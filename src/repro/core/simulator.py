"""Deterministic discrete-event simulation kernel.

Pure virtual time (microseconds, float).  No wall-clock, no randomness
unless a seeded RNG is explicitly passed to a component — identical inputs
give identical traces, which the property tests rely on.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "args", "cancelled")

    def __init__(self, time: float, seq: int, fn: Callable[..., Any], args: tuple):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.args = args
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventLoop:
    def __init__(self):
        self.now: float = 0.0
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self.events_processed = 0

    def schedule(self, delay: float, fn: Callable[..., Any], *args: Any) -> Event:
        assert delay >= 0, f"negative delay {delay}"
        ev = Event(self.now + delay, next(self._seq), fn, args)
        heapq.heappush(self._heap, ev)
        return ev

    def at(self, time: float, fn: Callable[..., Any], *args: Any) -> Event:
        return self.schedule(max(0.0, time - self.now), fn, *args)

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        while self._heap and self.events_processed < max_events:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if until is not None and ev.time > until:
                heapq.heappush(self._heap, ev)
                return
            self.now = ev.time
            self.events_processed += 1
            ev.fn(*ev.args)
        if self._heap and self.events_processed >= max_events:
            raise RuntimeError("event budget exhausted — livelock?")

    def peek_time(self) -> Optional[float]:
        """Time of the next live event, or None if the loop is drained."""
        while self._heap and self._heap[0].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0].time if self._heap else None

    def step(self) -> bool:
        """Execute exactly one live event.  Returns False if none remain.

        Lets completion-queue ``wait()`` stop the clock at the instant a
        completion is delivered instead of free-running to a deadline.
        """
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            self.events_processed += 1
            ev.fn(*ev.args)
            return True
        return False

    @property
    def idle(self) -> bool:
        return not any(not e.cancelled for e in self._heap)


class Resource:
    """A serially-occupied resource (a CPU core, a link).

    ``reserve(duration)`` books the next available slot at or after *now*
    and returns ``(start, end)``; callers schedule their completion events
    at ``end``.  ``busy_overlap`` reports whether the reservation had to
    queue — the link-interleaving signal used by the PLDMA model.
    """

    def __init__(self, loop: EventLoop, name: str = ""):
        self.loop = loop
        self.name = name
        self.busy_until: float = 0.0
        self.busy_time: float = 0.0
        self.reservations = 0

    def reserve(self, duration: float) -> tuple[float, float]:
        start = max(self.loop.now, self.busy_until)
        end = start + duration
        self.busy_until = end
        self.busy_time += duration
        self.reservations += 1
        return start, end

    def would_queue(self) -> bool:
        return self.busy_until > self.loop.now
