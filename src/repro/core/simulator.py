"""Deterministic discrete-event simulation kernel.

Pure virtual time (microseconds, float).  No wall-clock, no randomness
unless a seeded RNG is explicitly passed to a component — identical inputs
give identical traces, which the property tests rely on.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Optional


class Event:
    __slots__ = ("time", "seq", "fn", "args", "cancelled", "loop")

    def __init__(self, time: float, seq: int, fn: Callable[..., Any],
                 args: tuple, loop: "Optional[EventLoop]" = None):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.args = args
        self.cancelled = False
        self.loop = loop

    def cancel(self) -> None:
        if not self.cancelled:
            self.cancelled = True
            if self.loop is not None:
                self.loop._n_cancelled += 1

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventLoop:
    """Binary-heap event queue, tuned for multi-million-event soaks.

    * **Tuple-keyed heap** — entries are ``(time, seq, Event)``, so sift
      comparisons resolve on the C-level float/int compare (``seq`` is
      unique, the :class:`Event` is never compared).  The seed heaped
      ``Event`` objects directly, paying a Python ``__lt__`` call per
      comparison — the single hottest function at scale.
    * **Lazy-cancel compaction** — cancelled events (every ACKed block
      cancels its 1 ms retransmission timeout) stay heaped until their
      timestamp; under a million-block soak they would dominate the heap
      and tax every push/pop with a larger log factor.  The loop counts
      live cancellations and rebuilds the heap whenever cancelled entries
      outnumber live ones: an amortized-O(1) sweep keeping heap
      operations sized to *live* work.
    """

    #: don't bother compacting heaps smaller than this
    COMPACT_MIN = 1024

    def __init__(self):
        self.now: float = 0.0
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._n_cancelled = 0         # cancelled events still in the heap
        self.events_processed = 0
        self.compactions = 0

    def schedule(self, delay: float, fn: Callable[..., Any], *args: Any) -> Event:
        assert delay >= 0, f"negative delay {delay}"
        t = self.now + delay
        seq = next(self._seq)
        ev = Event(t, seq, fn, args, self)
        heap = self._heap
        if self._n_cancelled > self.COMPACT_MIN \
                and self._n_cancelled * 2 > len(heap):
            self._heap = heap = [h for h in heap if not h[2].cancelled]
            heapq.heapify(heap)
            self._n_cancelled = 0
            self.compactions += 1
        heapq.heappush(heap, (t, seq, ev))
        return ev

    def at(self, time: float, fn: Callable[..., Any], *args: Any) -> Event:
        return self.schedule(max(0.0, time - self.now), fn, *args)

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        heap = self._heap
        while heap and self.events_processed < max_events:
            entry = heapq.heappop(heap)
            ev = entry[2]
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            if until is not None and entry[0] > until:
                heapq.heappush(heap, entry)
                return
            self.now = entry[0]
            self.events_processed += 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)
            heap = self._heap   # schedule() may have compacted
        if self._heap and self.events_processed >= max_events:
            raise RuntimeError("event budget exhausted — livelock?")

    def peek_time(self) -> Optional[float]:
        """Time of the next live event, or None if the loop is drained."""
        heap = self._heap
        while heap and heap[0][2].cancelled:
            heapq.heappop(heap)
            self._n_cancelled -= 1
        return heap[0][0] if heap else None

    def step(self) -> bool:
        """Execute exactly one live event.  Returns False if none remain.

        Lets completion-queue ``wait()`` stop the clock at the instant a
        completion is delivered instead of free-running to a deadline.
        """
        while self._heap:
            t, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                self._n_cancelled -= 1
                continue
            self.now = t
            self.events_processed += 1
            ev.loop = None      # fired: a late cancel() must not count
            ev.fn(*ev.args)
            return True
        return False

    @property
    def idle(self) -> bool:
        # the cancellation counter makes this O(1): live = total - cancelled
        return len(self._heap) <= self._n_cancelled


class Resource:
    """A serially-occupied resource (a CPU core, a link).

    ``reserve(duration)`` books the next available slot at or after *now*
    and returns ``(start, end)``; callers schedule their completion events
    at ``end``.  ``busy_overlap`` reports whether the reservation had to
    queue — the link-interleaving signal used by the PLDMA model.
    """

    def __init__(self, loop: EventLoop, name: str = ""):
        self.loop = loop
        self.name = name
        self.busy_until: float = 0.0
        self.busy_time: float = 0.0
        self.reservations = 0

    def reserve(self, duration: float) -> tuple[float, float]:
        start = max(self.loop.now, self.busy_until)
        end = start + duration
        self.busy_until = end
        self.busy_time += duration
        self.reservations += 1
        return start, end

    def would_queue(self) -> bool:
        return self.busy_until > self.loop.now
