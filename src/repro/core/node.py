"""One ExaNeSt computing node: A53s + SMMU + fault FIFO + R5 + PLDMA.

Event-driven model of the full thesis mechanism:

* **Send path** (§1.3.2.1, §3.2.2): the R5 segments transfers into 16 KB
  blocks (window of 2 outstanding per transfer); the PLDMA translates source
  pages through the local SMMU as it packetizes — a source fault *pauses*
  the block after streaming the pages already translated; recovery is by
  timeout only (the prototype has no explicit source-side resume).
* **Receive path** (§3.2.3): destination pages are translated as packets
  arrive; the first faulting page of a block NACKs the block (AXI slave
  error), every NACKed packet is logged in the 512×128 b fault FIFO (with
  the hardware consecutive-dedup), and the remaining packets of the failed
  block are dropped.  The sender R5 *pauses* the transaction instead of
  instantly retransmitting (the thesis' firmware change).
* **Driver** (§3.2.1, §3.2.3.2): the ``arm_smmu_context_fault`` handler reads
  FSR/FAR/FSYNR on the driver CPU, clears the fault, and schedules the
  ``pf_send_handler`` / ``pf_rcv_tasklet`` tasklet by the WNR bit.  The
  receive tasklet drains the FIFO, skips entries already handled (the
  last-two-transactions cache that absorbs interleaving duplicates) and
  resolves faults via the configured strategy; for destination faults it
  fires the RAPF retransmit request at the initiator's mailbox.
* **Retransmission** (§3.2.3.3): R5 retransmits on RAPF (validating seq_num
  and the packetizer-wired PDID) or on timeout (1 ms default).
* **tr_ID lifecycle** (Table 3.2): the wire carries 14-bit transaction IDs,
  so once a node has launched 2^14 blocks, ID reuse is a *protocol
  property*.  The R5 allocates tr_IDs from a free list tied to its
  ``pending`` set — fresh IDs first, then IDs recycled **only on block
  completion** — so a still-paused block can never be aliased by a later
  launch.  Each allocation bumps a host-side *generation* tag (never on the
  wire; the 128-bit FIFO entry and the RAPF mailbox words stay bit-exact):
  RAPF matching, driver dedup and fault attribution all compare generations,
  so stale control traffic for a previous incarnation of an ID is dropped
  instead of retransmitting (or skipping) the wrong block.  When all 16K IDs
  are in flight the launch is deferred (FIFO) until a completion frees one;
  the posting verbs surface the same condition as typed backpressure
  (``repro.api.TrIdExhausted``).
"""

from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import TYPE_CHECKING, Callable, Optional

from repro.core import addresses as A
from repro.core.addresses import (NetlinkMessage, RAPFMessage, iova_field_pack,
                                  iova_field_unpack, split_blocks)
from repro.core.arbiter import DEFAULT_PLDMA_SLOTS, DMAArbiter, ServiceClass
from repro.core.costmodel import CostModel
from repro.core.fault import (SCTLR_HUPCF, SMMU, Access, Disposition,
                              FaultModel)
from repro.core.fault_fifo import FaultFIFO, FIFOEntry
from repro.core.pagetable import FrameAllocator, PageTable
from repro.core.resolver import DriverDedupCache, Resolver, Strategy
from repro.core.simulator import EventLoop, Resource
# runtime import of the bottom layer is safe: repro.net.router imports
# only repro.net.topology, never repro.core
from repro.net.router import NetworkPartitioned
from repro.tenancy import TenancyManager
from repro.tenancy.slo import SLOClass

if TYPE_CHECKING:                                    # pragma: no cover
    # type-only: importing repro.net at runtime here would make the two
    # packages circularly dependent (net is the lower layer)
    from repro.net.interconnect import Interconnect
    from repro.net.link import Path


# The typed error hierarchy lives in the dependency-free repro.errors
# (so repro.tenancy / repro.api can raise it without importing this
# module); re-exported here because these names were born here and the
# API layer + tests import them from repro.core.node.
from repro.errors import (BankCollision, DomainClosed,  # noqa: F401
                          DomainExists, FabricError, NodeDown)


class BlockState(enum.Enum):
    PENDING = 0
    IN_FLIGHT = 1
    PAUSED_SRC = 2    # source translation fault: waiting for timeout
    PAUSED_DST = 3    # PF-NACK received: waiting for RAPF or timeout
    DONE = 4


@dataclasses.dataclass
class TrIdStats:
    """Host-side telemetry of one node's 14-bit tr_ID lifecycle.

    ``space`` is the ID-space size (2^14 on hardware; tests may shrink it
    via ``FabricConfig.tr_id_space`` to exercise wraps cheaply — the wire
    encoding is unaffected, every ID always fits the 14-bit field).
    """

    space: int = A.TR_ID_SPACE
    allocated: int = 0           # total allocations (fresh + recycled)
    fresh: int = 0               # allocations from the never-used range
    recycled: int = 0            # allocations from the completion free list
    stalls: int = 0              # launches deferred: every ID in flight
    exhausted_posts: int = 0     # posts refused with TrIdExhausted
    in_flight: int = 0           # IDs currently owned by pending blocks
    max_in_flight: int = 0       # high-water mark of the above
    stale_rapf_drops: int = 0    # RAPFs for a previous incarnation dropped
    stale_fifo_entries: int = 0  # FIFO entries outliving their incarnation
    stale_npr_aborts: int = 0    # NP-RDMA aborts for a dead incarnation/round
    lease_reclaims: int = 0      # crash-orphaned IDs reclaimed at lease expiry

    @property
    def wraps(self) -> int:
        """Times the ID space has been fully consumed (>=1 once recycled
        IDs are in play, the regime the scale soak must survive)."""
        return self.allocated // self.space

    def as_dict(self) -> dict:
        return {
            "allocated": self.allocated, "fresh": self.fresh,
            "recycled": self.recycled, "stalls": self.stalls,
            "exhausted_posts": self.exhausted_posts,
            "max_in_flight": self.max_in_flight, "wraps": self.wraps,
            "stale_rapf_drops": self.stale_rapf_drops,
            "stale_fifo_entries": self.stale_fifo_entries,
            "stale_npr_aborts": self.stale_npr_aborts,
            "lease_reclaims": self.lease_reclaims,
        }


@dataclasses.dataclass(slots=True)
class TransferStats:
    t_submit: float = 0.0
    t_complete: float = -1.0
    timeouts: int = 0
    phantom_timeouts: int = 0    # of those, rounds with zero bytes on wire
    rapf_retransmits: int = 0
    retransmissions: int = 0
    src_faults: int = 0
    dst_faults: int = 0
    netlink_msgs: int = 0
    driver_us: float = 0.0       # kernel time: interrupt handler + tasklets
    user_us: float = 0.0         # library-thread time
    fifo_entries_handled: int = 0
    fifo_entries_skipped: int = 0
    segfaults_recovered: int = 0
    major_faults: int = 0
    # NP-RDMA backend (repro.npr) — zero for thesis-datapath transfers
    mtt_hits: int = 0
    mtt_misses: int = 0
    mtt_stale: int = 0
    npr_aborts: int = 0
    pool_redirect_pages: int = 0

    @property
    def latency_us(self) -> float:
        return self.t_complete - self.t_submit


class Block:
    __slots__ = ("transfer", "index", "src_va", "dst_va", "nbytes", "tr_id",
                 "gen", "seq_num", "state", "attempts", "round_id",
                 "delivered", "nacked_round", "timeout_event", "n_pages",
                 "wire_bytes", "service_class", "queued", "holds_slot",
                 "grant_pending", "is_retransmit", "npr_redirect",
                 "retries", "dead_rounds")

    def __init__(self, transfer: "Transfer", index: int, src_va: int,
                 dst_va: int, nbytes: int):
        self.transfer = transfer
        self.index = index
        self.src_va = src_va
        self.dst_va = dst_va
        self.nbytes = nbytes
        self.tr_id = -1
        self.gen = 0                 # host-side incarnation tag of tr_id
        self.seq_num = index & A.SEQ_NUM_MASK
        self.state = BlockState.PENDING
        self.attempts = 0
        self.round_id = 0
        self.delivered: set[int] = set()
        self.nacked_round = -1       # round for which a PF-NACK was sent
        self.timeout_event = None
        self.n_pages = A.num_pages(dst_va, nbytes)
        self.wire_bytes = 0          # bytes streamed in the current round
        # DMA-arbiter state (repro.core.arbiter)
        self.service_class: Optional[ServiceClass] = None
        self.queued = False          # sitting in an arbiter send queue
        self.holds_slot = False      # occupying a PLDMA slot
        self.grant_pending = False   # slot granted, _dispatch not yet run
        self.is_retransmit = False
        # NP-RDMA: an abort redirected this block into the DMA pool
        self.npr_redirect = False
        # crash-fault layer: retransmissions charged against the domain's
        # retry budget, and consecutive timeout rounds against a peer
        # that looks dead (crashed or unreachable)
        self.retries = 0
        self.dead_rounds = 0


class Transfer:
    # hot state: every page arrival and every ACK chases attributes on
    # this object, so it is slotted like Block — no per-instance dict
    __slots__ = ("tid", "pd", "service_class", "src_node", "dst_node",
                 "src_va", "dst_va", "nbytes", "on_complete", "stats",
                 "failed_status", "origin_id", "srq_held", "srq_node",
                 "blocks", "next_block", "done_blocks", "live_blocks")

    def __init__(self, tid: int, pd: int, src_node: "Node", dst_node: "Node",
                 src_va: int, dst_va: int, nbytes: int,
                 on_complete: Optional[Callable[["Transfer"], None]] = None,
                 service_class: Optional[ServiceClass] = None):
        self.tid = tid
        self.pd = pd
        # per-transfer arbiter class override (None -> the domain's class)
        self.service_class = service_class
        self.src_node = src_node
        self.dst_node = dst_node
        self.src_va = src_va
        self.dst_va = dst_va
        self.nbytes = nbytes
        self.on_complete = on_complete
        self.stats = TransferStats()
        # crash-fault layer: the terminal error, as a WCStatus *value*
        # string ("retry_exc_err"/"wr_flush_err"/"remote_op_err") — core
        # must not import repro.api, so the enum mapping happens in the
        # fabric's completion tracker.  None = not failed.
        self.failed_status: Optional[str] = None
        # node the WR was posted from (set by the posting verbs; None for
        # direct engine use, where src_node is the origin) — picks
        # WR_FLUSH_ERR vs REMOTE_OP_ERR when a node crashes mid-transfer
        self.origin_id: Optional[int] = None
        # SRQ receive entries held on the destination node (repro.tenancy):
        # acquired at post time, released when the completion fires
        self.srq_held = 0
        self.srq_node = -1
        # R5 16 KB-aligned segmentation; src/dst assumed equally page-aligned.
        self.blocks = [Block(self, i, sva, dst_va + (sva - src_va), n)
                       for i, (sva, n) in enumerate(split_blocks(src_va, nbytes))]
        self.next_block = 0
        self.done_blocks = 0
        # blocks currently IN_FLIGHT or PAUSED_* — the O(1) form of the
        # per-page "is another block of this transfer live on the wire"
        # interleave check (previously an O(n_blocks) scan per page)
        self.live_blocks = 0

    @property
    def complete(self) -> bool:
        return self.done_blocks == len(self.blocks)


class Node:
    def __init__(self, loop: EventLoop, cost: CostModel, node_id: int,
                 resolver: Resolver, allocator: Optional[FrameAllocator] = None,
                 hupcf: bool = True,
                 fault_model: FaultModel = FaultModel.TERMINATE,
                 pldma_slots: int = DEFAULT_PLDMA_SLOTS,
                 arb_quantum_bytes: int = A.BLOCK_SIZE,
                 tr_id_space: Optional[int] = None,
                 mtt_entries: int = 4096,
                 dma_pool_frames: int = 64,
                 speculation: bool = True,
                 bank_overcommit: bool = True,
                 srq_entries: Optional[int] = None,
                 srq_gold_reserve: int = 0,
                 tenants_per_node: Optional[int] = None,
                 crash_detect_retries: int = 3,
                 lease_timeout_us: float = 10_000.0):
        self.loop = loop
        self.cost = cost
        self.node_id = node_id
        self.resolver = resolver                 # node-default policy
        self.domain_resolvers: dict[int, Resolver] = {}   # per-PDID override
        self.allocator = allocator or FrameAllocator()
        self.page_tables: dict[int, PageTable] = {}
        self.smmu = SMMU(node_id, interrupt_handler=self._on_smmu_interrupt)
        self.fifo = FaultFIFO()
        self.driver_cpu = Resource(loop, f"n{node_id}.cpu0")   # IRQs+tasklets
        self.user_cpu = Resource(loop, f"n{node_id}.cpu2")     # library thread
        self.hupcf = hupcf
        self.fault_model = fault_model
        self.r5 = R5Scheduler(self, tr_id_space=tr_id_space)
        self.arbiter = DMAArbiter(self, slots=pldma_slots,
                                  quantum_bytes=arb_quantum_bytes)
        # driver last-2-transactions dedup cache (§ Fig 4.2 discussion),
        # generation-aware so recycled tr_IDs can't alias fresh faults
        self._handled = DriverDedupCache()
        self._rcv_tasklet_pending = False
        # engine wiring: the routed interconnect every transmit path —
        # data pages AND control packets — travels through
        self.interconnect: Optional[Interconnect] = None
        self.peer: dict[int, "Node"] = {}
        # NP-RDMA backend (competing datapath; engages only for domains
        # whose FaultPolicy selects Strategy.NP_RDMA).  Function-level
        # import: repro.npr.engine imports this module at its top level.
        from repro.npr.engine import NPREngine
        self.npr = NPREngine(self, mtt_entries=mtt_entries,
                             dma_pool_frames=dma_pool_frames,
                             speculation=speculation)
        # tenancy control plane: context-bank virtualization + SRQ/QP
        # multiplexing + per-node tenant admission (repro.tenancy)
        self.bank_overcommit = bank_overcommit
        self.tenancy = TenancyManager(
            srq_entries=srq_entries, srq_gold_reserve=srq_gold_reserve,
            tenants_per_node=tenants_per_node)
        # crash-fault layer (fail-stop machine-failure model)
        self.crashed = False
        self.crash_detect_retries = crash_detect_retries
        self.lease_timeout_us = lease_timeout_us
        # per-domain retry budgets: pd -> (max_retries, retry_backoff)
        self.retry_budgets: dict[int, tuple[Optional[int], float]] = {}
        # hot-path cache of the BankManager's per-domain handle: a bound
        # domain's bank is one dict probe away (see bank_of_pd); a steal
        # nulls the victim handle's bank, so entries self-invalidate
        self._bank_dom: dict[int, object] = {}
        # stable references to the per-page-hot containers (both dicts
        # are mutated in place, never rebound) — saves two attribute
        # chains per received page
        self._npr_domains = self.npr.domains
        self._banks = self.tenancy.banks
        # demo/bench hook: blocks by (pd, src vpn) for source-fault attribution
        self.netlink_log: list[NetlinkMessage] = []

    # ------------------------------------------------------------- domains
    def create_domain(self, pd: int, pin_limit_bytes: Optional[int] = None,
                      resolver: Optional[Resolver] = None,
                      service_class: Optional[ServiceClass] = None,
                      arb_weight: int = 1,
                      max_outstanding_blocks: Optional[int] = None,
                      slo: Optional[SLOClass] = None,
                      max_retries: Optional[int] = None,
                      retry_backoff: float = 1.0
                      ) -> PageTable:
        """Create protection domain ``pd``, optionally with its own fault
        resolver (per-domain :class:`~repro.api.policy.FaultPolicy`),
        DMA-arbiter parameters (service class, DRR weight, block quota)
        and SLO class (GOLD banks are steal-immune).

        With bank overcommit (the default) the BankManager binds the
        domain to a free context bank eagerly when one exists — byte
        identical to the seed's ``pd % 16`` for workloads that fit —
        and otherwise defers binding to first SMMU use, where an LRU
        bank steal (shootdown + rebind, cost-modeled) makes room.
        With ``bank_overcommit=False`` the seed's hard ceiling applies:
        a ``pd % NUM_CONTEXT_BANKS`` clash raises :class:`BankCollision`.
        """
        if pd in self.page_tables:
            raise DomainExists(
                f"pd={pd} already live on node {self.node_id}")
        if not self.bank_overcommit:
            bank = pd % A.NUM_CONTEXT_BANKS
            owner = self.pd_for_bank(bank)
            if owner is not None and owner != pd:
                raise BankCollision(
                    f"pd={pd} maps to SMMU context bank {bank}, already "
                    f"live for domain pd={owner} on node {self.node_id} "
                    f"(bank = pd % {A.NUM_CONTEXT_BANKS}); only "
                    f"{A.NUM_CONTEXT_BANKS} concurrent domains fit one "
                    f"node with bank_overcommit=False")
        # admission control: per-node tenant cap + the GOLD-bank ceiling
        self.tenancy.register(pd, slo)
        pt = PageTable(pd, self.allocator, pin_limit_bytes=pin_limit_bytes)
        self.page_tables[pd] = pt
        if max_retries is not None or retry_backoff != 1.0:
            self.retry_budgets[pd] = (max_retries, retry_backoff)
        if resolver is not None:
            self.domain_resolvers[pd] = resolver
        if self.resolver_for(pd).strategy is Strategy.NP_RDMA:
            # the domain's traffic goes through the NP-RDMA datapath:
            # MTT-translated sends, verified receives, pool redirects
            self.npr.register_domain(pd, pt)
        self.arbiter.register_domain(
            pd, service_class=service_class, weight=arb_weight,
            max_outstanding_blocks=max_outstanding_blocks)
        bound = self.tenancy.banks.try_bind(pd)
        if bound is not None:
            self.smmu.attach_domain(bound, pt, hupcf=self.hupcf,
                                    fault_model=self.fault_model)
        return pt

    def release_domain(self, pd: int) -> int:
        """Tear down every per-domain resource (``Fabric.close_domain``):
        detach + shoot down the SMMU bank, drop NP-RDMA MTT entries,
        release all frames back to the shared pool, forget resolvers.
        Returns the number of frames released.
        """
        bank = self.tenancy.banks.bank_of(pd)
        if bank is not None:
            self.smmu.detach_domain(bank)
        self.tenancy.release(pd)
        self._bank_dom.pop(pd, None)
        self.npr.unregister_domain(pd)
        self.retry_budgets.pop(pd, None)
        self.domain_resolvers.pop(pd, None)
        pt = self.page_tables.pop(pd, None)
        return 0 if pt is None else pt.release_all()

    def bank_of_pd(self, pd: int) -> tuple[int, float]:
        """The physical context bank serving ``pd``, binding on demand.

        Returns ``(bank, penalty_us)``.  A hit costs nothing.  A lazy
        bind to a free bank charges the page-table rebind; a bank steal
        additionally charges the victim's full-TLB shootdown — both
        reserved on the driver CPU (they are SMMU driver work) and
        returned so the caller can delay the datapath by the same amount
        (the cost shows up in fault latency, not just CPU accounting).
        Stealing detaches the victim from the SMMU and invalidates the
        victim's NP-RDMA MTT entries: zero stale completions.
        """
        banks = self.tenancy.banks
        # fast path: a cached, still-bound domain handle costs one dict
        # probe plus the same LRU-touch + hit accounting bind() would do
        # — no lambda, no Binding allocation (this runs once per page)
        dom = self._bank_dom.get(pd)
        if dom is None:
            dom = banks.domain_handle(pd)
            if dom is not None:
                self._bank_dom[pd] = dom
        if dom is not None:
            bank = dom.bank
            if bank is not None:
                # BankManager.note_hit inlined (LRU touch + hit counter):
                # this is the once-per-page common case
                banks.stats.hits += 1
                tick = banks._tick + 1
                banks._tick = tick
                dom.last_use = tick
                return bank, 0.0
        tn = self.tenancy
        binding = tn.bind_bank(
            pd, fault_active=lambda b: self.smmu.banks[b].fault_active)
        if binding.hit:            # pragma: no cover - cache served above
            return binding.bank, 0.0
        penalty = self.cost.bank_rebind_us
        if binding.stolen:
            self.smmu.detach_domain(binding.bank)
            tn.banks.stats.shootdowns += 1
            penalty += self.cost.bank_shootdown_us
            if binding.victim_pd is not None:
                # the stolen domain's cached NIC translations must die
                # with the bank or a speculative NP-RDMA launch could
                # complete against a translation the SMMU no longer backs
                self.npr.invalidate_domain(binding.victim_pd)
        self.smmu.attach_domain(binding.bank, self.page_tables[pd],
                                hupcf=self.hupcf,
                                fault_model=self.fault_model)
        self.driver_cpu.reserve(penalty)
        return binding.bank, penalty

    def pt(self, pd: int) -> PageTable:
        return self.page_tables[pd]

    def resolver_for(self, pd: int) -> Resolver:
        """The fault resolver governing domain ``pd`` (policy > default)."""
        return self.domain_resolvers.get(pd, self.resolver)

    def max_retries_for(self, pd: int) -> Optional[int]:
        """Domain retry budget (``FaultPolicy.max_retries``; None = ∞)."""
        return self.retry_budgets.get(pd, (None, 1.0))[0]

    def retry_backoff_for(self, pd: int) -> float:
        """Domain timeout-backoff multiplier (1.0 = the flat 1 ms timer)."""
        return self.retry_budgets.get(pd, (None, 1.0))[1]

    # -------------------------------------------------------------- failure
    def crash(self) -> None:
        """Fail-stop machine failure, mid-whatever-was-happening.

        Takes every incident physical link down (peers' routes detour or
        partition), silences this node's receive/driver datapaths, and
        fails every transfer its R5 was executing: the initiating side
        gets error completions (``WR_FLUSH_ERR`` for work posted here,
        ``REMOTE_OP_ERR`` for remote reads posted against it) instead of
        eternal retransmission.  tr_IDs owned by the dead blocks stay
        leased until ``lease_timeout_us`` and only then rejoin the free
        list, so the PR-5 ID-lifecycle invariants survive the crash.
        Idempotent; there is no un-crash.
        """
        if self.crashed:
            return
        self.crashed = True
        if self.interconnect is not None:
            self.interconnect.fail_node(self.node_id)
        self.r5.on_local_crash()

    def pd_for_bank(self, bank_index: int) -> Optional[int]:
        """The PDID *currently bound to* an SMMU context bank.

        Fault records carry only the bank index; domain state (page
        tables, resolvers, pending blocks) is keyed by the full PDID, so
        the driver needs this reverse map.  O(1) via the BankManager's
        binding table — and under overcommit the answer changes over
        time, which is why the fault handler resolves the pd at
        fault-record-read time, not at tasklet time.
        """
        return self.tenancy.banks.pd_for_bank(bank_index)

    # ------------------------------------------------------------- network
    def path_to(self, node_id: int) -> Path:
        """The routed interconnect path from this node to ``node_id``."""
        return self.interconnect.path(self.node_id, node_id)

    # =================================================== SMMU driver (CPU0)
    def _on_smmu_interrupt(self, bank_index: int) -> None:
        """arm_smmu_context_fault — runs on the driver CPU."""
        c = self.cost
        _, end = self.driver_cpu.reserve(c.interrupt_us + c.handler_regs_us)
        self.loop.at(end, self._handler_body, bank_index)

    def _handler_body(self, bank_index: int) -> None:
        iova, wnr, is_tf = self.smmu.read_fault_record(bank_index)
        self.smmu.clear_fault(bank_index)
        if not is_tf:
            return  # permission faults: future work in the thesis
        vpn = iova >> 12
        c = self.cost
        if wnr:  # destination (write) fault -> pf_rcv_tasklet
            self._schedule_rcv_tasklet()
        else:    # source (read) fault -> pf_send_handler
            # resolve bank -> pd NOW: under bank overcommit the bank can
            # be stolen and rebound to another tenant during the tasklet
            # latency, and the tasklet must bill the *faulting* domain
            pd = self.pd_for_bank(bank_index)
            if pd is None:
                return  # bank stolen before the record was read
            _, end = self.driver_cpu.reserve(c.tasklet_latency_us)
            self.loop.at(end, self._pf_send_handler, pd, vpn)

    # ------------------------------------------------- source-fault tasklet
    def _pf_send_handler(self, pd: int, vpn: int) -> None:
        if self.crashed:
            return  # dead CPUs run no tasklets
        c = self.cost
        pt = self.page_tables.get(pd)
        if pt is None:
            return
        block = self.r5.find_block_by_src_page(pd, vpn)
        stats = block.transfer.stats if block else None
        remaining = A.PAGES_PER_BLOCK
        if block is not None:
            last_vpn = A.page_index(block.src_va + block.nbytes - 1)
            remaining = max(1, last_vpn - vpn + 1)
        res = self.resolver_for(pd).resolve(
            pt, vpn, is_dst=False, block_pages_remaining=remaining)
        _, kend = self.driver_cpu.reserve(res.kernel_us)
        if stats:
            stats.driver_us += c.tasklet_latency_us + res.kernel_us
            stats.netlink_msgs += 0 if res.rapf_from_kernel else 1
            stats.segfaults_recovered += res.segfault_recovered
            stats.major_faults += res.major
        if res.user_us > 0:
            # library thread touches the page; no RAPF for source faults
            self.loop.at(kend, self._user_thread_work, res.user_us, stats, None)
        # §3.2.2.1: also kick the receive tasklet, "just in case"
        self._schedule_rcv_tasklet()

    # ----------------------------------------------- destination tasklet
    def _schedule_rcv_tasklet(self) -> None:
        if self._rcv_tasklet_pending:
            return
        self._rcv_tasklet_pending = True
        _, end = self.driver_cpu.reserve(self.cost.tasklet_latency_us)
        self.loop.at(end, self._pf_rcv_tasklet)

    def _pf_rcv_tasklet(self) -> None:
        """Drain the fault FIFO; resolve + RAPF per new entry.

        The tasklet scans the FIFO to empty — with interleaved duplicate
        entries from the two outstanding blocks, "it takes more time to
        find a new page / set of pages to page-in during the handling"
        (Fig 4.2 discussion): every pop costs two 64-bit AXI-lite reads on
        the driver CPU before the entry can even be dedup-checked.
        """
        self._rcv_tasklet_pending = False
        if self.crashed:
            return  # dead CPUs run no tasklets
        c = self.cost
        backlog = len(self.fifo)
        if backlog:
            # the scan through the queued (mostly duplicate) entries is on
            # the critical path of every resolution in this invocation
            self.driver_cpu.reserve(2 * c.fifo_read64_us * backlog)
        while not self.fifo.empty:
            entry = self.fifo.pop_entry()
            if entry is None:
                break
            gen = self.fifo.last_popped_gen
            key = entry.vpage_key() + (gen,)
            src_node = self.peer.get(entry.src_id)
            stats = None
            if src_node is not None:
                # O(1) lookup; the generation tag rejects entries that
                # outlived their block (the tr_id has been recycled) so a
                # stale entry can't charge a new incarnation's stats
                blk = src_node.r5.pending.get(entry.tr_id)
                if blk is not None and (gen == 0 or blk.gen == gen):
                    stats = blk.transfer.stats
                elif gen:
                    src_node.r5.id_stats.stale_fifo_entries += 1
            _, vpn27 = iova_field_unpack(entry.iova_field)
            pt = self.page_tables.get(entry.pdid)
            if self._handled.seen(key) or (pt is not None
                                           and pt.is_resident(vpn27)):
                # last-2-transactions cache (absorbs interleaving dups) or a
                # page an earlier get_user_pages already brought in: skip.
                _, _ = self.driver_cpu.reserve(c.driver_bookkeep_us)
                if stats:
                    stats.fifo_entries_skipped += 1
                    stats.driver_us += 2 * c.fifo_read64_us + c.driver_bookkeep_us
                continue
            self._handled.note(key)
            if pt is None:
                continue
            res = self.resolver_for(entry.pdid).resolve(
                pt, vpn27, is_dst=True,
                block_pages_remaining=A.PAGES_PER_BLOCK)
            _, kend = self.driver_cpu.reserve(res.kernel_us + c.driver_bookkeep_us)
            if stats:
                stats.fifo_entries_handled += 1
                stats.driver_us += (2 * c.fifo_read64_us + c.driver_bookkeep_us
                                    + res.kernel_us)
                stats.netlink_msgs += 0 if res.rapf_from_kernel else 1
                stats.segfaults_recovered += res.segfault_recovered
                stats.major_faults += res.major
            rapf = RAPFMessage(wired_pdid=entry.pdid, rcved_pdid=entry.pdid,
                               tr_id=entry.tr_id, seq_num=entry.seq_num)
            if res.rapf_from_kernel:
                self.loop.at(kend, self._send_rapf, entry.src_id, rapf, stats,
                             gen)
            else:
                self.netlink_log.append(NetlinkMessage(
                    src_id=entry.src_id, tr_id=entry.tr_id,
                    seq_num=entry.seq_num, iova_field=entry.iova_field,
                    pdid=entry.pdid, rw=1))
                self.loop.at(kend, self._user_thread_work, res.user_us, stats,
                             (entry.src_id, rapf, gen))

    def _user_thread_work(self, duration: float, stats: Optional[TransferStats],
                          rapf: Optional[tuple[int, RAPFMessage, int]]) -> None:
        _, end = self.user_cpu.reserve(duration)
        if stats:
            stats.user_us += duration
        if rapf is not None:
            self.loop.at(end, self._send_rapf, rapf[0], rapf[1], stats,
                         rapf[2])

    def _send_rapf(self, src_node_id: int, msg: RAPFMessage,
                   stats: Optional[TransferStats], gen: int = 0) -> None:
        if self.crashed:
            return
        target = self.peer.get(src_node_id)
        if target is None:
            return
        delay = self.cost.pckzer_to_mbox_us
        if target is not self:
            # the RAPF retransmission request rides the interconnect to
            # the initiator's mailbox: charge (and, on shared-link
            # topologies, reserve) the full routed distance — the seed
            # charged one hop_latency_us however far the initiator was
            try:
                delay += self.path_to(src_node_id).send_ctrl(8)
            except NetworkPartitioned:
                return  # RAPF lost; the sender's timeout recovers
        self.loop.schedule(delay, target.r5.on_mailbox, msg, stats, gen)

    # ============================================================== receive
    def recv_page(self, block: Block, page_idx: int, round_id: int,
                  interleaved: bool, nbytes: int) -> None:
        """Arrival of one page worth of packets at the destination PLDMA.

        With HUPCF set (the thesis' experimental configuration) every page
        of an in-flight block is translated independently, so a multi-page
        block with a cold destination logs one FIFO entry *per faulty page*
        in the first round (plus packet-level duplicates when the two
        outstanding blocks interleave on the wire — the Fig 4.2 dampening
        effect).  Without HUPCF the SMMU terminates even resident pages
        while a fault is outstanding (collateral NACKs, §3.2.1).
        """
        if self.crashed:
            return  # packets delivered to a dead node vanish
        if block.state is BlockState.DONE or round_id != block.round_id:
            return  # stale packets from a superseded round
        transfer = block.transfer
        pd = transfer.pd
        if pd in self._npr_domains:         # inlined NPREngine.owns()
            # NP-RDMA domain: host-side verification instead of the SMMU
            # translate -> NACK -> fault-FIFO path
            self.npr.recv_page(block, page_idx, round_id, nbytes)
            return
        # two outstanding blocks streaming together -> their NACK packets
        # interleave and defeat the FIFO's consecutive-dedup (§ Fig 4.2).
        # live_blocks counts this transfer's IN_FLIGHT/PAUSED_* blocks —
        # including this one — so "any other live block" is a counter
        # compare instead of a per-page scan over every block.
        interleaved = interleaved or transfer.live_blocks > 1
        vpn = (block.dst_va >> 12) + page_idx   # A.page_index, inlined
        # bind-on-use: an overcommitted domain may have to steal a bank
        # here; the shootdown+rebind penalty delays this page's ACK/NACK
        # (it is SMMU driver work on the translation's critical path).
        # The bank_of_pd hit path is inlined — cached bound handle, LRU
        # touch, hit count, zero penalty — it runs once per page.
        dom = self._bank_dom.get(pd)
        if dom is not None and dom.bank is not None:
            bank = dom.bank
            banks = self._banks
            banks.stats.hits += 1
            tick = banks._tick + 1
            banks._tick = tick
            dom.last_use = tick
            penalty = 0.0
        else:
            bank, penalty = self.bank_of_pd(pd)
            if penalty:
                transfer.stats.driver_us += penalty
        # SMMU TLB-hit fast path inlined (once per received page):
        # resident, cached, and not gated by an outstanding fault —
        # stats identical to translate_disposition()'s hit branch
        smmu = self.smmu
        cbank = smmu.banks[bank]
        if ((not cbank.fsr or cbank.sctlr & SCTLR_HUPCF)
                and (bank << 32) | vpn in smmu._tlb):
            sst = smmu.stats
            sst.translations += 1
            sst.tlb_hits += 1
            ok = True
        else:
            ok = (smmu.translate_disposition(bank, vpn, Access.WRITE)
                  is Disposition.OK)
        if ok:
            delivered = block.delivered
            delivered.add(page_idx)
            if len(delivered) == block.n_pages:
                # the ACK travels back over the interconnect: charge the
                # routed distance (the seed charged one hop, flat)
                src_node = transfer.src_node
                try:
                    ctrl = self.path_to(src_node.node_id).send_ctrl(0)
                except NetworkPartitioned:
                    return  # ACK lost; the sender's timeout recovers
                delay = penalty + self.cost.ack_us + ctrl
                self.loop.schedule(delay, src_node.r5.on_ack,
                                   block, round_id)
            return
        # ---- destination fault: NACK + FIFO logging --------------------
        transfer.stats.dst_faults += 1
        entry = FIFOEntry(src_id=transfer.src_node.node_id,
                          tr_id=block.tr_id, seq_num=block.seq_num,
                          pdid=pd,
                          iova_field=iova_field_pack(0, vpn))
        # every NACKed packet logs; consecutive same-page packets collapse
        # in the FIFO's dedup, but wire interleaving between the two
        # outstanding blocks breaks the "same as last pushed" check.
        n_pushes = max(1, nbytes // A.MTU) if interleaved else 1
        for _ in range(n_pushes):
            pushed = self.fifo.push(entry, gen=block.gen)
            if not interleaved and not pushed:
                break
            if interleaved:
                # alternating streams: defeat the consecutive-dedup the way
                # real interleaved packets do
                self.fifo.break_dedup()
        if block.nacked_round != round_id:
            block.nacked_round = round_id
            # the PF-NACK (AXI slave error) propagates back per routed hop
            try:
                ctrl = (self.path_to(transfer.src_node.node_id)
                            .send_ctrl(0))
            except NetworkPartitioned:
                ctrl = None  # NACK lost; the sender's timeout recovers
            if ctrl is not None:
                delay = penalty + self.cost.nack_us + ctrl
                self.loop.schedule(delay,
                                   transfer.src_node.r5.on_nack,
                                   block, round_id)
        # the SMMU interrupt fired inside translate() if this was the first
        # outstanding fault; MULTI faults rely on the FIFO alone (§3.2.1) —
        # make sure a drain is queued either way.
        self._schedule_rcv_tasklet()


class R5Scheduler:
    """The Cortex-R5 firmware model (thesis §1.3.2 + §3.2.3.3).

    Owns the node's 14-bit tr_ID space: IDs are allocated fresh until the
    space has been fully issued once, then recycled from a free list fed
    **only by block completions** — a paused block keeps its ID until it
    is ACKed, so launching 2^14+ blocks can never alias ``pending``.
    Every allocation bumps the ID's host-side generation tag, the
    disambiguator RAPF matching and driver dedup use once IDs recycle.
    """

    def __init__(self, node: Node, tr_id_space: Optional[int] = None):
        self.node = node
        self.loop = node.loop
        self.cost = node.cost
        space = int(tr_id_space) if tr_id_space is not None else A.TR_ID_SPACE
        if not 1 <= space <= A.TR_ID_SPACE:
            raise ValueError(
                f"tr_id_space must be in [1, {A.TR_ID_SPACE}] (the 14-bit "
                f"wire field, Table 3.2), got {space}")
        self.tr_id_space = space
        self._fresh_next = 0                  # next never-issued ID
        self._free: deque[int] = deque()      # IDs recycled on completion
        self._gen: dict[int, int] = {}        # ID -> current generation
        self._starved: deque[Transfer] = deque()   # deferred launches
        self.pending: dict[int, Block] = {}   # tr_id -> block
        # per-(pd, src vpn) index over pending blocks, launch-ordered:
        # the O(1) replacement for the per-fault O(pending) scan in
        # find_block_by_src_page (maintained on launch/completion).
        # Keys are packed ints ``(pd << 32) | vpn`` — int hashing beats
        # tuple hashing on the per-block add/remove path, and vpns are
        # 27-bit (39-bit IOVA space), so the packing never collides.
        self._src_index: dict[int, list[Block]] = {}
        self.id_stats = TrIdStats(space=space)

    # ----------------------------------------------------------- tr_ID pool
    def tr_ids_free(self) -> int:
        """IDs available to new launches right now (fresh + recycled)."""
        return (self.tr_id_space - self._fresh_next) + len(self._free)

    def _alloc_tr_id(self) -> Optional[int]:
        """Allocate a tr_ID, or None when all are owned by pending blocks.

        Fresh IDs are issued in order first (bit-identical to the seed's
        counter below one wrap); after that, completions feed the FIFO
        free list.  The ID's generation is bumped on every allocation.
        """
        st = self.id_stats
        if self._fresh_next < self.tr_id_space:
            tid = self._fresh_next
            self._fresh_next += 1
            st.fresh += 1
        elif self._free:
            tid = self._free.popleft()
            st.recycled += 1
        else:
            return None
        self._gen[tid] = self._gen.get(tid, 0) + 1
        st.allocated += 1
        st.in_flight += 1
        if st.in_flight > st.max_in_flight:
            st.max_in_flight = st.in_flight
        return tid

    def _free_tr_id(self, tid: int) -> None:
        """Recycle a completed block's ID (the ONLY way IDs come back)."""
        self._free.append(tid)
        self.id_stats.in_flight -= 1

    # ------------------------------------------------------ src-fault index
    def _index_add(self, block: Block) -> None:
        base = block.transfer.pd << 32
        idx = self._src_index
        first = base | (block.src_va >> 12)
        last = base | ((block.src_va + block.nbytes - 1) >> 12)
        for key in range(first, last + 1):
            lst = idx.get(key)
            if lst is None:
                idx[key] = [block]
            else:
                lst.append(block)

    def _index_remove(self, block: Block) -> None:
        base = block.transfer.pd << 32
        idx = self._src_index
        first = base | (block.src_va >> 12)
        last = base | ((block.src_va + block.nbytes - 1) >> 12)
        for key in range(first, last + 1):
            lst = idx.get(key)
            if lst is not None:
                try:
                    lst.remove(block)
                except ValueError:          # pragma: no cover - defensive
                    pass
                if not lst:
                    del idx[key]

    # ---------------------------------------------------------------- user
    def submit(self, transfer: Transfer) -> None:
        # NOTE: quota accounting (arbiter.note_submit) happens at POST
        # time in repro.api.fabric, not here — for remote reads this
        # method only runs after the request-packet delay, too late for
        # the posting verbs' backpressure check to see the work.
        transfer.stats.t_submit = self.loop.now
        if self.node.crashed:
            # work arriving at (or posted on) a dead executing node —
            # e.g. a remote read whose request packet was in flight when
            # the target died — flushes immediately
            self.fail_transfer(transfer, self._crash_status(transfer))
            return
        self.loop.schedule(self.cost.dma_setup_us, self._start, transfer)

    def _start(self, transfer: Transfer) -> None:
        if self.node.crashed:
            # crashed during DMA setup: the transfer had no pending
            # blocks yet, so on_local_crash could not have seen it
            self.fail_transfer(transfer, self._crash_status(transfer))
            return
        for _ in range(A.OUTSTANDING_BLOCKS_PER_TRANSFER):
            self._launch_next(transfer)

    def _launch_next(self, transfer: Transfer) -> None:
        if transfer.next_block >= len(transfer.blocks):
            return
        tid = self._alloc_tr_id()
        if tid is None:
            # every ID is owned by a pending block: defer this launch.
            # Each completion frees an ID and redeems one ticket (FIFO),
            # so deferred traffic drains in launch order.
            self.id_stats.stalls += 1
            self._starved.append(transfer)
            return
        block = transfer.blocks[transfer.next_block]
        transfer.next_block += 1
        block.tr_id = tid
        block.gen = self._gen[tid]
        self.pending[tid] = block
        self._index_add(block)
        # blocks no longer go straight to the PLDMA: the fault-aware
        # arbiter grants slots per service class / DRR across domains
        self.node.arbiter.enqueue(block)

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, block: Block, is_retransmit: bool) -> None:
        block.grant_pending = False
        if block.state is BlockState.DONE:
            return
        node = self.node
        transfer = block.transfer
        prev_wire_bytes = block.wire_bytes
        block.round_id += 1
        block.attempts += 1
        block.delivered.clear()
        block.wire_bytes = 0
        if block.state is BlockState.PENDING:
            transfer.live_blocks += 1
        block.state = BlockState.IN_FLIGHT
        if is_retransmit and prev_wire_bytes:
            # only rounds that put bytes on the wire are *re*-transmitted;
            # a re-dispatch after a PAUSED_SRC-at-first-page round (zero
            # bytes streamed) is this data's first transmission
            transfer.stats.retransmissions += 1

        pd = transfer.pd
        first_vpn = block.src_va >> 12
        src_pages = range(first_vpn,
                          ((block.src_va + block.nbytes - 1) >> 12) + 1)
        # PLDMA reads/packetizes pages in order; a source fault stops the
        # stream (pages already read remain in flight).
        try:
            path = node.path_to(transfer.dst_node.node_id)
        except NetworkPartitioned:
            # no live route this round: yield the slot and let the R5
            # timer run — the timeout path counts dead rounds toward
            # REMOTE_OP_ERR if the partition persists
            block.state = BlockState.PAUSED_SRC
            node.arbiter.on_block_paused(block)
            self._arm_timeout(block)
            return
        # the DMA arbiter's service class extends to link arbitration:
        # LATENCY blocks overtake BULK backlogs on congested shared hops
        latency_class = (block.service_class is not None
                         and block.service_class.wire_priority)
        if pd in node._npr_domains:         # inlined NPREngine.owns()
            # NP-RDMA domain: the engine translates through its MTT (and
            # fixes source misses up host-side) instead of the SMMU loop
            # below; the R5 timeout stays armed as the common backstop
            node.npr.dispatch(block, path, latency_class)
            self._arm_timeout(block)
            return
        # bind-on-use: an overcommitted domain claims (possibly steals) a
        # context bank before the PLDMA can translate its source pages —
        # the shootdown+rebind penalty offsets every page this round puts
        # on the wire, so the steal cost is visible end to end
        bank, bank_penalty = node.bank_of_pd(pd)
        if bank_penalty:
            transfer.stats.driver_us += bank_penalty
        # the per-page loop is the hottest code in the simulator: bind
        # every loop-invariant lookup once, accumulate wire_bytes locally
        src_va = block.src_va
        src_end = src_va + block.nbytes
        # stream key: (transfer, block-index) — unique among streams
        # that can coexist on a link, unlike id(block), which CPython
        # may reuse after a finished block is collected while its
        # link is still draining (aliasing the interleave detector)
        stream_key = (transfer.tid, block.index)
        recv = transfer.dst_node.recv_page
        schedule = self.loop.schedule
        round_id = block.round_id
        smmu = node.smmu
        cbank = smmu.banks[bank]
        sst = smmu.stats
        tlb = smmu._tlb
        bank_key = bank << 32
        translate = smmu.translate_disposition
        read = Access.READ
        ok = Disposition.OK
        stream = path.stream_page
        wire_bytes = 0
        for i, vpn in enumerate(src_pages):
            # SMMU TLB-hit fast path inlined (per source page): cached
            # and not gated by an outstanding fault — identical stats
            # to translate_disposition()'s hit branch
            if ((not cbank.fsr or cbank.sctlr & SCTLR_HUPCF)
                    and bank_key | vpn in tlb):
                sst.translations += 1
                sst.tlb_hits += 1
            elif translate(bank, vpn, read) is not ok:
                block.state = BlockState.PAUSED_SRC
                transfer.stats.src_faults += 1
                # deschedule-on-fault: the paused block yields its PLDMA
                # slot so other tenants' queued blocks keep streaming
                node.arbiter.on_block_paused(block)
                break
            pg_start = vpn << 12
            if src_va > pg_start:
                pg_start = src_va
            pg_end = (vpn + 1) << 12
            if src_end < pg_end:
                pg_end = src_end
            nbytes = pg_end - pg_start
            delay, interleaved = stream(nbytes, stream_key,
                                        latency_class=latency_class)
            wire_bytes += nbytes
            schedule(bank_penalty + delay, recv, block, i,
                     round_id, interleaved, nbytes)
        block.wire_bytes = wire_bytes
        self._arm_timeout(block)

    def _arm_timeout(self, block: Block) -> None:
        if block.timeout_event is not None:
            block.timeout_event.cancel()
        timeout = self.cost.timeout_us
        # hot path (every dispatch re-arms): probe the budget dict once
        # instead of building the (None, 1.0) default tuple per call
        budget = self.node.retry_budgets.get(block.transfer.pd)
        if budget is not None and budget[1] > 1.0 and block.retries:
            # exponential backoff per consecutive retransmission of this
            # block (FaultPolicy.retry_backoff; exponent capped so a long
            # retry tail cannot overflow the float timeline)
            timeout *= budget[1] ** min(block.retries, 16)
        block.timeout_event = self.loop.schedule(
            timeout, self._on_timeout, block, block.round_id)

    def _on_timeout(self, block: Block, round_id: int) -> None:
        if block.state is BlockState.DONE or round_id != block.round_id:
            return
        transfer = block.transfer
        stats = transfer.stats
        stats.timeouts += 1
        if block.wire_bytes == 0:
            # the round paused PAUSED_SRC before any packet left the node:
            # the R5 timer still fires (source-fault recovery is by timeout
            # only in the prototype) but nothing was on the wire to lose —
            # accounted separately so phantom rounds are subtractable
            stats.phantom_timeouts += 1
        node = self.node
        peer = transfer.dst_node
        if peer.crashed or (node.interconnect is not None
                            and node.interconnect.down
                            and not node.interconnect.reachable(
                                node.node_id, peer.node_id)):
            # the peer looks dead (fail-stop crash or persistent
            # partition): count the round instead of retransmitting into
            # the void; enough consecutive dead rounds fail the transfer
            block.dead_rounds += 1
            if block.dead_rounds >= node.crash_detect_retries:
                self.fail_transfer(transfer, "remote_op_err")
                return
            if block.state is BlockState.IN_FLIGHT:
                # don't retransmit into the void, and don't camp on a
                # PLDMA slot while waiting out the detection window
                block.state = BlockState.PAUSED_SRC
                node.arbiter.on_block_paused(block)
            self._arm_timeout(block)
            return
        block.dead_rounds = 0
        if not self._charge_retry(block):
            return  # budget exhausted: the transfer just failed
        # re-enter at the BACK of the block's class queue: a faulting
        # tenant's retransmits do not jump other tenants' fresh traffic
        self.node.arbiter.requeue(block)

    # -------------------------------------------------------- crash faults
    def _charge_retry(self, block: Block) -> bool:
        """Charge one retransmission against the domain's retry budget.

        Returns True if the retransmit may proceed; False when the budget
        is exhausted (the transfer just completed with RETRY_EXC_ERR).
        The budget counts every retransmission of a block — timeout- and
        RAPF-triggered alike — so a permanently-faulting peer page cannot
        spin the 1 ms timer forever when a budget is set.
        """
        block.retries += 1
        max_retries = self.node.max_retries_for(block.transfer.pd)
        if max_retries is not None and block.retries > max_retries:
            self.fail_transfer(block.transfer, "retry_exc_err")
            return False
        return True

    def _crash_status(self, transfer: Transfer) -> str:
        """WR_FLUSH_ERR for work posted *from* this (dead) node,
        REMOTE_OP_ERR for work another node posted against it."""
        origin = (transfer.origin_id if transfer.origin_id is not None
                  else transfer.src_node.node_id)
        return ("wr_flush_err" if origin == self.node.node_id
                else "remote_op_err")

    def fail_transfer(self, transfer: Transfer, status: str,
                      free_ids: bool = True) -> None:
        """Terminally fail a transfer's remaining blocks and deliver its
        (error) completion exactly once.

        Failed blocks go to ``DONE`` without ever counting toward
        ``done_blocks``, so ``transfer.complete`` stays False forever: a
        late ACK can neither double-complete the transfer nor resurrect
        it.  ``free_ids=False`` leaves the blocks' tr_IDs leased in
        ``pending`` (crash orphans, reclaimed by ``_reclaim_leases``).
        """
        if transfer.failed_status is not None or transfer.complete:
            return
        transfer.failed_status = status
        for block in transfer.blocks:
            if block.state is not BlockState.DONE:
                self._fail_block(block, free_ids=free_ids)
        transfer.next_block = len(transfer.blocks)
        if self._starved:
            self._starved = deque(t for t in self._starved
                                  if t is not transfer)
        self.node.arbiter.on_transfer_failed(transfer)
        transfer.stats.t_complete = (self.loop.now
                                     + self.cost.completion_poll_us)
        if transfer.on_complete is not None:
            self.loop.schedule(self.cost.completion_poll_us,
                               transfer.on_complete, transfer)

    def _fail_block(self, block: Block, free_ids: bool) -> None:
        if block.state is BlockState.DONE:
            # every caller filters DONE already; the explicit guard keeps
            # DONE terminal by construction (repro.lint conformance)
            return
        if block.timeout_event is not None:
            block.timeout_event.cancel()
            block.timeout_event = None
        if block.state in (BlockState.IN_FLIGHT, BlockState.PAUSED_SRC,
                           BlockState.PAUSED_DST):
            block.transfer.live_blocks -= 1
        block.state = BlockState.DONE
        self.node.arbiter.purge(block)
        if block.tr_id >= 0 and free_ids \
                and self.pending.get(block.tr_id) is block:
            del self.pending[block.tr_id]
            self._index_remove(block)
            self._free_tr_id(block.tr_id)
        # free_ids=False: the ID stays leased in pending AND the source
        # index (the lifecycle invariant mirrors one from the other)
        # until _reclaim_leases retires both

    def on_local_crash(self) -> None:
        """Fail every live transfer this (now dead) R5 was executing.

        tr_IDs owned by the dead blocks are NOT recycled immediately: a
        late wire packet could still name them, so they stay leased in
        ``pending`` until ``lease_timeout_us`` elapses, then return to
        the free list (each next allocation bumping the generation tag,
        exactly as a completion-recycled ID would).
        """
        transfers = {b.transfer for b in self.pending.values()}
        transfers.update(self._starved)
        self._starved.clear()
        for t in sorted(transfers, key=lambda t: t.tid):
            self.fail_transfer(t, self._crash_status(t), free_ids=False)
        orphans = tuple(sorted(self.pending))
        if orphans:
            self.loop.schedule(self.node.lease_timeout_us,
                               self._reclaim_leases, orphans)

    def _reclaim_leases(self, orphans: tuple) -> None:
        """Lease expiry: orphaned tr_IDs rejoin the free list."""
        for tid in orphans:
            block = self.pending.pop(tid, None)
            if block is None:               # pragma: no cover - defensive
                continue
            self._index_remove(block)
            self._free_tr_id(tid)
            self.id_stats.lease_reclaims += 1

    # ------------------------------------------------------------- arrivals
    def on_ack(self, block: Block, round_id: int) -> None:
        if block.state is BlockState.DONE or round_id != block.round_id:
            return
        transfer = block.transfer
        block.state = BlockState.DONE
        transfer.live_blocks -= 1
        if block.timeout_event is not None:
            block.timeout_event.cancel()
        tid = block.tr_id
        if self.pending.pop(tid, None) is block:
            self._index_remove(block)
            self._free_tr_id(tid)           # recycle ONLY on completion
        self.node.arbiter.on_block_done(block)
        transfer.done_blocks += 1
        # the freed ID may unblock launches deferred at exhaustion; the
        # completing transfer's own next block takes its turn BEHIND any
        # already-deferred work, so deferral tickets really are redeemed
        # in launch order (no self-refill priority inversion)
        if self._starved:
            self._starved.append(transfer)
        else:
            self._launch_next(transfer)
        while self._starved and self.tr_ids_free() > 0:
            self._launch_next(self._starved.popleft())
        if transfer.done_blocks == len(transfer.blocks):   # == .complete
            transfer.stats.t_complete = (self.loop.now
                                         + self.cost.completion_poll_us)
            if transfer.on_complete is not None:
                # the user observes the completion when the PLDMA
                # status-register poll returns — fire the callback AT
                # t_complete, not completion_poll_us before it (which
                # handed callbacks a timestamp from the future)
                self.loop.schedule(self.cost.completion_poll_us,
                                   transfer.on_complete, transfer)

    def on_nack(self, block: Block, round_id: int) -> None:
        # thesis firmware change: pause instead of instant retransmit.
        # Only a block that streamed this round can be NACKed: IN_FLIGHT,
        # or PAUSED_SRC when a mid-block source fault trailed packets the
        # destination then faulted on.  The round check alone excludes
        # the other states dynamically (PENDING blocks are on round 0,
        # one NACK per nacked_round); stating it as a guard makes the
        # spec'd transitions explicit (repro.lint conformance).
        if block.state not in (BlockState.IN_FLIGHT, BlockState.PAUSED_SRC) \
                or round_id != block.round_id:
            return
        block.dead_rounds = 0        # a NACK is proof the peer is alive
        block.state = BlockState.PAUSED_DST
        self.node.arbiter.on_block_paused(block)

    def on_mailbox(self, msg: RAPFMessage, stats: Optional[TransferStats],
                   gen: int = 0) -> None:
        if msg.opcode != A.OPCODE_RAPF:
            return
        self.loop.schedule(self.cost.mailbox_poll_us, self._rapf_body, msg,
                           stats, gen)

    def _rapf_body(self, msg: RAPFMessage, stats, gen: int = 0) -> None:
        block = self.pending.get(msg.tr_id)
        if block is None or block.state is BlockState.DONE:
            return
        if gen and block.gen != gen:
            # the tr_ID was recycled between the fault and this RAPF: the
            # request addresses a finished incarnation, not this block —
            # without the generation check a wrapped seq_num could force
            # a spurious retransmit of (or steal the timeout of) a
            # brand-new block that inherited the ID
            self.id_stats.stale_rapf_drops += 1
            return
        if msg.seq_num != (block.seq_num & 0xFFF):
            return  # stale/forged: dropped, as in the firmware listing
        if msg.wired_pdid != block.transfer.pd:
            return  # security check: wired PDID mismatch
        block.transfer.stats.rapf_retransmits += 1
        block.dead_rounds = 0        # an RAPF is proof the peer is alive
        if block.timeout_event is not None:
            block.timeout_event.cancel()
        if not self._charge_retry(block):
            return  # retry budget exhausted: RETRY_EXC_ERR just fired
        self.node.arbiter.requeue(block)

    def on_npr_abort(self, tr_id: int, gen: int, round_id: int) -> None:
        """NP-RDMA abort-and-redirect request from the destination host.

        Validated exactly like a RAPF: the (generation, round) pair must
        match the live incarnation of the tr_ID — an abort that raced a
        completion (or a recycled ID) is dropped, not acted on, so it can
        never redirect a block it was not issued against.
        """
        block = self.pending.get(tr_id)
        if block is None or block.state is BlockState.DONE:
            return
        if (gen and block.gen != gen) or round_id != block.round_id:
            self.id_stats.stale_npr_aborts += 1
            return
        block.npr_redirect = True
        if block.timeout_event is not None:
            block.timeout_event.cancel()
        self.node.arbiter.requeue(block)

    # ----------------------------------------------------------- utilities
    def find_block_by_src_page(self, pd: int, vpn: int) -> Optional[Block]:
        """Earliest-launched pending block covering source page ``vpn``.

        O(1) via the per-(pd, vpn) index — the seed scanned every pending
        block per source fault, O(pending) on the driver's critical path.
        """
        lst = self._src_index.get((pd << 32) | vpn)
        return lst[0] if lst else None
