"""Pallas TPU kernels: page gather/scatter between frame pools and
contiguous transfer buffers.

This is the DMA *block-assembly* stage of the thesis' engine on TPU: the
R5 segments a transfer into blocks whose pages are scattered across the
physical pool; ``page_gather`` packs the pages named by a (scalar-prefetch)
page list into a contiguous staging buffer for the interconnect, and
``page_scatter`` is the receive-side inverse (packets land contiguously,
pages fan out to their frames).  One grid step = one page = one VMEM-sized
DMA, the translation again living in the index_map.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(idx_ref, src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def page_gather(pool, indices, *, interpret: bool = False):
    """pool: (P, page_elems); indices: (n,) int32 -> (n, page_elems).

    indices < 0 are "unmapped" (thesis: a fault the runtime must resolve
    first); they are clamped to frame 0 — callers mask, the kernel never
    traps, faults are a control-plane event (DESIGN.md §2).
    """
    P, E = pool.shape
    n = indices.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, E),
                               lambda i, idx: (jnp.maximum(idx[i], 0), 0))],
        out_specs=pl.BlockSpec((1, E), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, E), pool.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), pool)


def _scatter_kernel(idx_ref, blk_ref, pool_ref, out_ref):
    out_ref[...] = blk_ref[...]


def page_scatter(pool, indices, block, *, interpret: bool = False):
    """Scatter ``block`` (n, page_elems) into ``pool`` at ``indices``.

    The pool is aliased to the output (in-place on TPU): rows not named by
    ``indices`` keep their contents.  Unmapped (-1) entries clamp to frame
    0 — callers must resolve residency first, as the serving engine does.
    """
    P, E = pool.shape
    n = indices.shape[0]

    def pool_map(i, idx):
        return (jnp.maximum(idx[i], 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, E), lambda i, idx: (i, 0)),   # block rows
                  pl.BlockSpec((1, E), pool_map)],               # pool (alias)
        out_specs=pl.BlockSpec((1, E), pool_map),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, E), pool.dtype),
        interpret=interpret,
        input_output_aliases={2: 0},
    )(indices.astype(jnp.int32), block, pool)
