"""Pure-jnp oracles for page gather/scatter."""

from __future__ import annotations

import jax.numpy as jnp


def page_gather_ref(pool, indices):
    return jnp.take(pool, jnp.maximum(indices, 0), axis=0)


def page_scatter_ref(pool, indices, block):
    return pool.at[jnp.maximum(indices, 0)].set(block)
