"""jit'd wrappers for page gather/scatter (flattened page payloads)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.page_pack.page_pack import page_gather, page_scatter


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_pages(pool, indices, *, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    flat = pool.reshape(pool.shape[0], -1)
    out = page_gather(flat, indices, interpret=interpret)
    return out.reshape((indices.shape[0],) + pool.shape[1:])


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(0,))
def scatter_pages(pool, indices, block, *, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    flat = pool.reshape(pool.shape[0], -1)
    blk = block.reshape(block.shape[0], -1)
    out = page_scatter(flat, indices, blk, interpret=interpret)
    return out.reshape(pool.shape)
