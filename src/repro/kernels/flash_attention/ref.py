"""Pure-jnp oracle for the flash-attention kernel (kernel layout)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, S, D); k, v: (B, KVH, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    KVH = k.shape[1]
    G = H // KVH
    qh = q.reshape(B, KVH, G, S, D).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qh,
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, D).astype(q.dtype)
