"""jit'd wrapper for the flash-attention kernel (model layout (B,S,H,D))."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, S, H, D); k, v: (B, S, KVH, D) -> (B, S, H, D)."""
    if interpret is None:
        interpret = _default_interpret()
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(qt, kt, vt, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out.transpose(0, 2, 1, 3)
