"""Pallas TPU kernel: blocked causal flash attention (prefill/train path).

Grid ``(B, H, n_q_blocks, n_kv_blocks)`` — the kv axis is innermost and
sequential, carrying online-softmax accumulators in VMEM scratch.  Causal
blocks entirely above the diagonal are skipped with ``pl.when`` (no MXU
work issued), which is the 2× triangle saving; sliding-window blocks fully
outside the window are likewise skipped.

Block shapes default to (128 q × 128 kv) tiles over head_dim lanes —
multiples of the MXU (128×128) and the (8,128) bf16 VMEM tile.  GQA is
handled in the k/v index_map: query head h reads kv head h // group.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, n_kv: int, window: int, causal: bool,
            scale: float, seq_len: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk
    # skip blocks fully above the causal diagonal / outside the window
    needed = True
    if causal:
        needed = k_start <= q_start + bq - 1
    if window > 0:
        needed = needed & (q_start - (k_start + bk - 1) < window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)     # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)     # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_len
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1)[:, None])
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)[:, None]
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, KVH, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    KVH = k.shape[1]
    G = H // KVH
    bq = min(block_q, S)
    bk = min(block_k, S)
    pad_q = (-S) % bq
    pad_k = (-S) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // bq
    nk = k.shape[2] // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, n_kv=nk, window=window,
                               causal=causal, scale=1.0 / math.sqrt(D),
                               seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S] if pad_q else out
