"""Pure-jnp oracle for the paged-attention kernel (materializing gather)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pool, v_pool, page_table, lengths, *,
                        window: int = 0):
    """q: (B, KVH, G, D); k/v_pool: (KVH, P, ps, D); page_table: (B, NP).

    Gathers the full context per sequence (jnp.take) and runs a plain
    masked softmax — O(B·S) memory, small-shape testing only.
    """
    B, KVH, G, D = q.shape
    _, P, ps, _ = k_pool.shape
    NP = page_table.shape[1]
    safe = jnp.maximum(page_table, 0)                       # (B, NP)
    k = jnp.take(k_pool, safe, axis=1)                      # (KVH, B, NP, ps, D)
    v = jnp.take(v_pool, safe, axis=1)
    k = k.transpose(1, 0, 2, 3, 4).reshape(B, KVH, NP * ps, D)
    v = v.transpose(1, 0, 2, 3, 4).reshape(B, KVH, NP * ps, D)

    s = jnp.einsum("bhgd,bhkd->bhgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    pos = jnp.arange(NP * ps)
    valid = pos[None, :] < lengths[:, None]                 # (B, S)
    mapped = jnp.repeat(page_table >= 0, ps, axis=1)
    valid = valid & mapped
    if window > 0:
        valid = valid & ((lengths[:, None] - 1 - pos[None, :]) < window)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
