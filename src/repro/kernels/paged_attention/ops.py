"""jit'd model-layout wrapper for the paged-attention kernel.

Model layout (what serving/decoder.py uses):
    q (B, H, D);  k/v_pool (P, ps, KVH, D);  page_table (B, NP); lengths (B,)
Kernel layout:
    q (B, KVH, G, D);  k/v_pool (KVH, P, ps, D)

On CPU (this container) the kernel runs in interpret mode; on TPU set
``interpret=False`` (the default resolves by backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention_kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    window: int = 0, interpret: bool | None = None):
    if interpret is None:
        interpret = _default_interpret()
    B, H, D = q.shape
    P, ps, KVH, _ = k_pool.shape
    G = H // KVH
    qk = q.reshape(B, KVH, G, D)
    kp = k_pool.transpose(2, 0, 1, 3)          # (KVH, P, ps, D)
    vp = v_pool.transpose(2, 0, 1, 3)
    out = paged_attention_kernel(qk, kp, vp, page_table.astype(jnp.int32),
                                 lengths.astype(jnp.int32), window=window,
                                 interpret=interpret)
    return out.reshape(B, H, D)
