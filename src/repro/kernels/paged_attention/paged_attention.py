"""Pallas TPU kernel: decode attention through a KV page table.

This is the hardware hot spot of the thesis' technique on TPU: the page
table (the SMMU of our adaptation) is a **scalar-prefetch** operand, and
the per-page translation happens in the BlockSpec ``index_map`` — each grid
step DMAs exactly one (page_tokens × head_dim) K/V tile from the HBM frame
pool into VMEM, so non-contiguous ("virtually addressed") context reads
never materialize a gathered copy.

Grid: ``(batch, kv_heads, n_pages)`` with the page axis innermost —
sequential on TPU, carrying the online-softmax accumulators in VMEM
scratch.  Block shapes keep the MXU happy: the (G × page) score tile is a
multiple of (8, 128) for bf16 at the production page size (256 tokens).

Index-map translation == the SMMU walk; an unmapped page (table entry -1)
is clamped to frame 0 and masked out of the softmax — the compiled step
never faults, because the runtime (serving engine) resolves residency
*before* dispatch, exactly where the thesis puts its driver.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(page_table_ref, lengths_ref,          # scalar-prefetch operands
            q_ref, k_ref, v_ref,                  # VMEM tiles
            o_ref,                                # output tile
            acc_ref, m_ref, l_ref,                # VMEM scratch
            *, page_tokens: int, n_pages: int, window: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (ps, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (ps, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    length = lengths_ref[b]
    mapped = page_table_ref[b, i] >= 0
    pos = i * page_tokens + jax.lax.broadcasted_iota(jnp.int32,
                                                     (1, page_tokens), 1)
    valid = (pos < length) & mapped
    if window > 0:
        valid &= (length - 1 - pos) < window
    s = jnp.where(valid, s, NEG_INF)               # (G, ps) via broadcast

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)[:, None]            # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (G, ps)
    corr = jnp.exp(m_prev - m_new)                 # (G, 1)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)[:, None]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(i == n_pages - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_attention_kernel(q, k_pool, v_pool, page_table, lengths, *,
                           window: int = 0, interpret: bool = False):
    """q: (B, KVH, G, D); k/v_pool: (KVH, P, ps, D); page_table: (B, NP).

    Returns (B, KVH, G, D).  See ops.py for the model-layout wrapper.
    """
    B, KVH, G, D = q.shape
    _, P, ps, _ = k_pool.shape
    n_pages = page_table.shape[1]
    scale = 1.0 / math.sqrt(D)

    grid = (B, KVH, n_pages)

    def q_map(b, h, i, pt, ln):
        return (b, h, 0, 0)

    def kv_map(b, h, i, pt, ln):
        frame = jnp.maximum(pt[b, i], 0)    # clamp unmapped; masked in-kernel
        return (h, frame, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), q_map),
            pl.BlockSpec((1, 1, ps, D), kv_map),
            pl.BlockSpec((1, 1, ps, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, page_tokens=ps, n_pages=n_pages,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, D), q.dtype),
        interpret=interpret,
    )(page_table, lengths, q, k_pool, v_pool)
