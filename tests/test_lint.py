"""Fixture tests for ``repro.lint``: each rule with a good/bad pair,
suppression hygiene, the spec round-trip against the real sources, the
exhaustive spec model checker, and the same-timestamp race sanitizer.

Fixtures are built from inline source strings via ``SourceFile(rel,
text)`` — the ``rel`` path matters because several rules are scoped
(``det-dict-iter``/``det-id-order`` to event-path modules,
``typed-raise`` to the API surface).  Suppression markers inside
fixtures are assembled at runtime so the linter's raw-line scan of THIS
file never sees a live allow() comment.
"""

import dataclasses
import json
import textwrap
import types
from pathlib import Path

import pytest

from repro.api import Fabric, FabricConfig
from repro.core.simulator import EventLoop
from repro.lint import (cli, conformance, determinism, model,
                        stats_coverage, typed_errors)
from repro.lint.common import KNOWN_RULES, SourceFile, collect_files
from repro.lint.race import RaceCheckLoop, footprint_of
from repro.lint.specs import ALL_SPECS, BLOCK, WC_ERROR_STATUSES
from repro.testing import soak

ROOT = Path(__file__).resolve().parents[1]

EVENT_PATH = "src/repro/core/fixture.py"      # det-dict-iter/id-order scope
OFF_PATH = "src/repro/launch/fixture.py"      # in repro, off the event path
API_PATH = "src/repro/api/fixture.py"         # typed-raise scope

#: runtime-assembled so no raw line of this file parses as a suppression
ALLOW = "# lint: " + "allow"


def sf(text, rel=EVENT_PATH):
    return SourceFile(rel, textwrap.dedent(text))


def rules(findings):
    return sorted(f.rule for f in findings)


def det(text, rel=EVENT_PATH):
    return rules(determinism.run([sf(text, rel)]))


# ---------------------------------------------------------------------------
# determinism rules
# ---------------------------------------------------------------------------
class TestDeterminismRules:
    def test_set_iter_flagged(self):
        assert det("""
            def visit(fn):
                for x in {1, 2, 3}:
                    fn(x)
            """) == ["det-set-iter"]

    def test_set_iter_sorted_ok(self):
        assert det("""
            def visit(fn):
                for x in sorted({1, 2, 3}):
                    fn(x)
            """) == []

    def test_set_algebra_flagged(self):
        assert det("""
            def visit(a, b, fn):
                for x in a | b:
                    fn(x)
            """) == []  # plain names: could be ints; not provably setlike

    def test_set_literal_algebra_flagged(self):
        assert det("""
            def visit(b, fn):
                for x in {1, 2} | b:
                    fn(x)
            """) == ["det-set-iter"]

    def test_dict_iter_event_path_flagged(self):
        assert det("""
            def drain(d, out):
                for k, v in d.items():
                    out.append(k)
            """) == ["det-dict-iter"]

    def test_dict_iter_off_event_path_ok(self):
        assert det("""
            def drain(d, out):
                for k, v in d.items():
                    out.append(k)
            """, rel=OFF_PATH) == []

    def test_dict_iter_sorted_ok(self):
        assert det("""
            def drain(d, out):
                for k in sorted(d.keys()):
                    out.append(k)
            """) == []

    def test_sum_genexp_over_dict_values_ok(self):
        # regression: the genexp's consumer (sum) is order-insensitive —
        # the walk must climb comprehension clause -> genexp -> call
        assert det("""
            def depth(queues):
                return sum(len(q.blocks) for q in queues.values())
            """) == []

    def test_setcomp_over_dict_values_ok(self):
        assert det("""
            def live(d):
                return {v for v in d.values()}
            """) == []

    def test_listcomp_over_dict_values_flagged(self):
        assert det("""
            def order(d):
                return [v for v in d.values()]
            """) == ["det-dict-iter"]

    def test_wallclock_flagged(self):
        assert det("""
            import time
            def stamp():
                return time.time()
            """) == ["det-wallclock"]
        assert det("""
            import time
            T0 = time.perf_counter()
            """) == ["det-wallclock"]

    def test_virtual_time_ok(self):
        assert det("""
            def stamp(loop):
                return loop.now
            """) == []

    def test_unseeded_random_flagged(self):
        assert det("""
            import random
            def roll():
                return random.randint(0, 7)
            """) == ["det-unseeded-random"]

    def test_seeded_random_instance_ok(self):
        assert det("""
            import random
            def roll(seed):
                rng = random.Random(seed)
                return rng.randint(0, 7)
            """) == []

    def test_id_as_key_flagged_on_event_path(self):
        assert det("""
            def index(objs, m):
                for o in objs:
                    m[id(o)] = o
            """) == ["det-id-order"]

    def test_id_dedup_ok(self):
        assert det("""
            def dedup(objs, seen):
                for o in objs:
                    if id(o) == id(objs[0]):
                        continue
                    seen.add(id(o))
            """) == []

    def test_id_off_event_path_ok(self):
        assert det("""
            def index(objs, m):
                for o in objs:
                    m[id(o)] = o
            """, rel=OFF_PATH) == []

    def test_heap_push_without_tiebreak_flagged(self):
        assert det("""
            import heapq
            def push(h, t, obj):
                heapq.heappush(h, (t, obj))
            """) == ["det-heap-tiebreak"]

    def test_heap_push_with_counter_ok(self):
        assert det("""
            import heapq
            def push(h, c, t, obj):
                heapq.heappush(h, (t, next(c), obj))
            """) == []

    def test_heap_repush_popped_entry_ok(self):
        assert det("""
            import heapq
            def rotate(h):
                entry = heapq.heappop(h)
                heapq.heappush(h, entry)
            """) == []


# ---------------------------------------------------------------------------
# typed-raise
# ---------------------------------------------------------------------------
class TestTypedErrors:
    def bad(self, rel):
        return rules(typed_errors.run([sf("""
            def f():
                raise ValueError("bad knob")
            """, rel=rel)]))

    def test_bare_raise_at_api_surface_flagged(self):
        assert self.bad(API_PATH) == ["typed-raise"]
        assert self.bad("src/repro/tenancy/fixture.py") == ["typed-raise"]

    def test_core_is_out_of_scope(self):
        assert self.bad(EVENT_PATH) == []

    def test_typed_error_ok(self):
        assert rules(typed_errors.run([sf("""
            from repro.errors import ConfigError
            def f():
                raise ConfigError("bad knob")
            """, rel=API_PATH)])) == []

    def test_reraise_and_typeerror_ok(self):
        assert rules(typed_errors.run([sf("""
            def f(x):
                if not isinstance(x, int):
                    raise TypeError("x must be int")
                try:
                    return 1 // x
                except ZeroDivisionError:
                    raise
            """, rel=API_PATH)])) == []


# ---------------------------------------------------------------------------
# stats-coverage
# ---------------------------------------------------------------------------
STATS_FIXTURE = """
    class FooStats:
        lost: int = 0
        seen: int = 0
    """

INVARIANTS_REL = "src/repro/testing/invariants.py"


def _foo_findings(inv_body):
    files = [sf(STATS_FIXTURE, rel="src/repro/core/metrics.py"),
             sf(inv_body, rel=INVARIANTS_REL)]
    return [f for f in stats_coverage.run(files) if "FooStats" in f.message]


class TestStatsCoverage:
    def test_unchecked_counter_flagged(self):
        found = _foo_findings("""
            def check_foo(s):
                return ["bad"] if s.seen < 0 else []
            """)
        assert rules(found) == ["stats-coverage"]
        assert "FooStats.lost" in found[0].message

    def test_checked_counter_ok(self):
        assert _foo_findings("""
            def check_foo(s):
                return ["bad"] if s.lost != s.seen else []
            """) == []

    def test_missing_invariants_module_is_itself_a_finding(self):
        files = [sf(STATS_FIXTURE, rel="src/repro/core/metrics.py")]
        found = stats_coverage.run(files)
        assert rules(found) == ["stats-coverage"]
        assert "cannot prove" in found[0].message


# ---------------------------------------------------------------------------
# conformance: transitions, state names, mutators, statuses
# ---------------------------------------------------------------------------
class TestConformance:
    def test_unguarded_write_flags_illegal_pairs(self):
        findings, observed = conformance.extract_block_transitions([sf("""
            def regress(self, block):
                block.state = BlockState.PENDING
            """)])
        assert "conf-transition" in rules(findings)
        # unguarded: every from-state is possible, including DONE
        assert ("DONE", "PENDING") in observed

    def test_guarded_write_extracts_exact_pair(self):
        findings, observed = conformance.extract_block_transitions([sf("""
            def on_ack(self, block):
                if block.state is BlockState.IN_FLIGHT:
                    block.state = BlockState.DONE
            """)])
        assert findings == []
        assert observed == {("IN_FLIGHT", "DONE")}

    def test_init_must_start_in_spec_initial_state(self):
        bad, _ = conformance.extract_block_transitions([sf("""
            class Block:
                def __init__(self):
                    self.state = BlockState.DONE
            """)])
        assert rules(bad) == ["conf-transition"]
        good, _ = conformance.extract_block_transitions([sf("""
            class Block:
                def __init__(self):
                    self.state = BlockState.PENDING
            """)])
        assert good == []

    def test_state_name_typo_flagged(self):
        files = [sf("""
            from enum import Enum
            class BlockState(Enum):
                PENDING = 1
            def f(b):
                return b.state.name == "PENDNIG"
            """)]
        assert rules(conformance.check_state_names(files)) \
            == ["conf-state-name"]

    def test_state_name_spelled_right_ok(self):
        files = [sf("""
            from enum import Enum
            class BlockState(Enum):
                PENDING = 1
            def f(b):
                return b.state.name == "PENDING"
            """)]
        assert conformance.check_state_names(files) == []

    def test_foreign_tr_id_mutation_flagged(self):
        files = [sf("""
            def hack(node, tid, blocks):
                node.r5.pending[tid] = blocks
            """, rel=API_PATH)]
        found = conformance.check_mutators(files)
        assert rules(found) == ["conf-mutator"]
        assert "pending" in found[0].message

    def test_bad_fail_transfer_status_flagged(self):
        files = [sf("""
            def kill(self, t):
                self.r5.fail_transfer(t, "bogus")
            """)]
        found = conformance.check_statuses(files)
        assert rules(found) == ["conf-status"]

    @pytest.mark.parametrize("status", WC_ERROR_STATUSES)
    def test_spec_statuses_ok(self, status):
        files = [sf(f"""
            def kill(self, t):
                self.r5.fail_transfer(t, "{status}")
            """)]
        assert conformance.check_statuses(files) == []


# ---------------------------------------------------------------------------
# spec round-trip against the real sources
# ---------------------------------------------------------------------------
class TestSpecRoundTrip:
    """The acceptance bar: the spec tables and the implementation agree,
    with the extractor proving every spec'd block transition has a
    guarded write site and no write site exceeds the spec."""

    @pytest.fixture(scope="class")
    def src_files(self):
        return collect_files(["src"], ROOT)

    def test_block_transitions_round_trip(self, src_files):
        findings, observed = conformance.extract_block_transitions(src_files)
        assert findings == []
        assert observed == set(BLOCK.transitions)

    def test_conformance_pass_clean_on_repo(self, src_files):
        assert conformance.run(src_files) == []

    def test_typed_errors_clean_on_repo(self, src_files):
        assert typed_errors.run(src_files) == []


# ---------------------------------------------------------------------------
# the spec model checker
# ---------------------------------------------------------------------------
class TestModelChecker:
    @pytest.fixture(scope="class")
    def result(self):
        return model.check_model()

    def test_model_clean(self, result):
        assert result.findings == []
        assert result.states_explored > 0

    def test_every_spec_row_exercised(self, result):
        for spec in ALL_SPECS:
            assert result.taken[spec.name] == set(spec.transitions)

    def test_every_spec_state_reachable(self, result):
        for spec in ALL_SPECS:
            assert result.visited[spec.name] == set(spec.states)

    def test_scenarios_cover_fault_and_crash_axes(self):
        scs = model.scenarios()
        assert len(scs) == len({sc.label() for sc in scs})
        assert any(sc.crash != "none" for sc in scs)
        assert any(sc.budget == "bounded" for sc in scs)
        assert any(sc.fault == "both" for sc in scs)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
class TestSuppressions:
    def test_justified_allow_suppresses(self):
        text = ("import time\n\nT0 = time.time()  "
                + ALLOW + "(det-wallclock): host telemetry only\n")
        assert cli.lint([SourceFile(EVENT_PATH, text)],
                        with_model=False) == []

    def test_line_above_form_suppresses(self):
        text = ("import time\n\n"
                + ALLOW + "(det-wallclock): host telemetry only\n"
                + "T0 = time.time()\n")
        assert cli.lint([SourceFile(EVENT_PATH, text)],
                        with_model=False) == []

    def test_allow_without_justification_is_a_finding(self):
        text = ("import time\n\nT0 = time.time()  "
                + ALLOW + "(det-wallclock)\n")
        found = cli.lint([SourceFile(EVENT_PATH, text)], with_model=False)
        assert rules(found) == ["lint-suppression"]

    def test_unknown_rule_is_a_finding(self):
        text = "X = 1  " + ALLOW + "(no-such-rule): because\n"
        found = cli.lint([SourceFile(EVENT_PATH, text)], with_model=False)
        assert "lint-suppression" in rules(found)

    def test_unused_allow_is_a_finding(self):
        text = "X = 1  " + ALLOW + "(det-wallclock): stale comment\n"
        found = cli.lint([SourceFile(EVENT_PATH, text)], with_model=False)
        assert rules(found) == ["lint-unused-suppression"]

    def test_every_repo_suppression_names_a_known_rule(self):
        for f in collect_files(["src", "tests", "benchmarks"], ROOT):
            for sup in f.suppressions.values():
                assert set(sup.rules) <= set(KNOWN_RULES), \
                    f"{f.rel}:{sup.line}"
                assert sup.justification, f"{f.rel}:{sup.line}"


# ---------------------------------------------------------------------------
# same-timestamp race sanitizer
# ---------------------------------------------------------------------------
def _writer(key):
    def cb():
        pass
    cb.__race_footprint__ = lambda args: (frozenset(), frozenset({key}))
    return cb


def _reader(key):
    def cb():
        pass
    cb.__race_footprint__ = lambda args: (frozenset({key}), frozenset())
    return cb


class TestRaceSanitizer:
    def test_planted_write_write_race_detected(self):
        loop = RaceCheckLoop()
        loop.at(5.0, _writer(("wr", 1)))
        loop.at(5.0, _writer(("wr", 1)))
        loop.run()
        loop.flush()
        assert len(loop.reports) == 1
        assert "conflict" in loop.reports[0]

    def test_read_write_race_detected(self):
        loop = RaceCheckLoop()
        loop.at(5.0, _writer(("wr", 1)))
        loop.at(5.0, _reader(("wr", 1)))
        loop.run()
        loop.flush()
        assert len(loop.reports) == 1

    def test_read_read_is_not_a_race(self):
        loop = RaceCheckLoop()
        loop.at(5.0, _reader(("wr", 1)))
        loop.at(5.0, _reader(("wr", 1)))
        loop.run()
        loop.flush()
        assert loop.reports == []

    def test_different_times_never_conflict(self):
        loop = RaceCheckLoop()
        loop.at(5.0, _writer(("wr", 1)))
        loop.at(6.0, _writer(("wr", 1)))
        loop.run()
        loop.flush()
        assert loop.reports == []

    def test_disjoint_keys_never_conflict(self):
        loop = RaceCheckLoop()
        loop.at(5.0, _writer(("wr", 1)))
        loop.at(5.0, _writer(("wr", 2)))
        loop.run()
        loop.flush()
        assert loop.reports == []

    def test_unknown_callbacks_are_tallied(self):
        loop = RaceCheckLoop()
        loop.at(5.0, lambda: None)
        loop.run()
        loop.flush()
        assert sum(loop.unknown_callbacks.values()) == 1
        assert loop.reports == []

    def test_generic_footprint_from_block_argument(self):
        blk = types.SimpleNamespace(
            tr_id=1, round_id=0, index=2,
            transfer=types.SimpleNamespace(tid=3))
        (reads, writes), known = footprint_of(lambda b: None, (blk,))
        assert known
        assert reads == frozenset()
        assert writes == {("block", 3, 2)}

    def test_config_opt_in(self):
        fab = Fabric.build(FabricConfig(n_nodes=2, race_check=True))
        assert isinstance(fab.loop, RaceCheckLoop)
        plain = Fabric.build(FabricConfig(n_nodes=2))
        assert not isinstance(plain.loop, RaceCheckLoop)
        assert isinstance(plain.loop, EventLoop)

    def test_env_opt_in(self, monkeypatch):
        monkeypatch.setenv("REPRO_RACE_CHECK", "1")
        fab = Fabric.build(FabricConfig(n_nodes=2))
        assert isinstance(fab.loop, RaceCheckLoop)

    @pytest.mark.parametrize("topology", ["all_to_all", "ring"])
    def test_soak_transparent_and_clean(self, topology):
        """Instrumented soaks report zero conflicts AND stay
        byte-identical to uninstrumented ones — the sanitizer observes,
        never perturbs."""
        cfg = FabricConfig(n_nodes=3, topology=topology)
        plain = soak(17, config=cfg)
        raced = soak(17, config=dataclasses.replace(cfg, race_check=True))
        assert plain.violations == []
        assert raced.violations == []
        assert (json.dumps(plain.stats, sort_keys=True)
                == json.dumps(raced.stats, sort_keys=True))


# ---------------------------------------------------------------------------
# the CLI (the build gate itself)
# ---------------------------------------------------------------------------
class TestCli:
    def test_repo_is_lint_clean(self, capsys):
        assert cli.main(["src", "tests", "benchmarks",
                         "--root", str(ROOT), "-q"]) == 0

    def test_findings_exit_nonzero(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text("X = 1  " + ALLOW + "(no-such-rule): whatever\n")
        assert cli.main(["bad.py", "--root", str(tmp_path),
                         "-q", "--no-model"]) == 1

    def test_no_files_exits_2(self, tmp_path, capsys):
        assert cli.main(["nothing-here", "--root", str(tmp_path),
                         "-q", "--no-model"]) == 2
