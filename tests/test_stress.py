"""Stress/soak harness: invariants under fault storms, determinism."""

import dataclasses

import pytest

import json

from repro.api import BufferPrep, FabricConfig, ServiceClass, Strategy
from repro.testing import FaultInjection, TenantSpec, soak

CHURN = FaultInjection(khugepaged_period_us=600.0,
                       reclaim_period_us=900.0, reclaim_pages=16)


class TestSoakInvariants:
    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_default_mix_zero_violations(self, seed):
        """The acceptance bar: randomized multi-tenant fault storms with
        khugepaged + reclaim churn uphold every invariant (block
        conservation, pinned pages resident, stats sums, DRR bounds)."""
        r = soak(seed, injection=CHURN)
        assert r.violations == []
        for t in r.stats["tenants"]:
            assert t["completed"] == t["posted"]

    @pytest.mark.parametrize("seed", [11, 12, 13])
    def test_quota_tenant_backpressured_but_live(self, seed):
        """An open-loop tenant pushing past its block quota gets posts
        rejected (and retried) yet still completes everything."""
        tenants = [
            TenantSpec(pd=1, name="greedy", mode="open",
                       arrival_period_us=5.0, n_requests=12,
                       size_choices=(65536,),
                       dst_prep=BufferPrep.FAULTING, fresh_dst=True,
                       max_outstanding_blocks=4),
            TenantSpec(pd=2, name="victim",
                       service_class=ServiceClass.LATENCY,
                       mode="closed", inflight=2, n_requests=8,
                       size_choices=(4096,),
                       dst_prep=BufferPrep.TOUCHED),
        ]
        r = soak(seed, tenants=tenants, injection=CHURN)
        assert r.violations == []
        greedy = r.stats["tenants"][0]
        assert greedy["rejected"] > 0
        assert greedy["completed"] == greedy["posted"] == 12

    def test_single_node_loopback_mix(self):
        """Loopback traffic (src node == dst node) soaks clean too."""
        tenants = [
            TenantSpec(pd=1, mode="closed", inflight=2, n_requests=6,
                       src_node=0, dst_node=0,
                       dst_prep=BufferPrep.FAULTING),
        ]
        r = soak(5, tenants=tenants,
                 config=FabricConfig(n_nodes=1))
        assert r.violations == []

    @pytest.mark.parametrize("pd", [224, 1025])
    def test_high_pd_faulting_tenant_completes(self, pd):
        """Regression (found by the 1024-node soak tier): a faulting
        tenant whose pd-strided VA window lay beyond 1 TB overflowed the
        fault FIFO's 28-bit IOVA field, so the driver resolved a
        truncated VPN forever while the real page stayed non-resident —
        every such tenant livelocked in NACK/RAPF rounds.  The tenant VA
        layout now wraps windows inside the 39-bit VA space
        (``repro.testing.traffic.VA_SLOTS``)."""
        tenants = [
            TenantSpec(pd=pd, name="high-pd-fault", mode="closed",
                       inflight=2, n_requests=4, size_choices=(65536,),
                       dst_prep=BufferPrep.FAULTING, fresh_dst=True),
        ]
        r = soak(9, tenants=tenants, config=FabricConfig(n_nodes=2),
                 max_events=200_000)
        assert r.violations == []
        t = r.stats["tenants"][0]
        assert t["completed"] == t["posted"] == 4


class TestDeterminism:
    """Guards the event loop against wall-clock / iteration-order
    nondeterminism: a soak is a pure function of (specs, seed)."""

    def test_same_seed_byte_identical(self):
        a = soak(7, injection=CHURN)
        b = soak(7, injection=CHURN)
        assert a.json() == b.json()
        assert a.json().encode() == b.json().encode()   # byte-identical

    def test_different_seeds_differ(self):
        a = soak(7, injection=CHURN)
        b = soak(8, injection=CHURN)
        assert a.json() != b.json()

    def test_seed_changes_traffic_not_conservation(self):
        for seed in (21, 22):
            r = soak(seed)
            assert r.violations == []

    def test_deterministic_with_weights_and_quotas(self):
        tenants = [
            TenantSpec(pd=1, arb_weight=3, mode="closed", inflight=3,
                       n_requests=6, dst_prep=BufferPrep.FAULTING,
                       max_outstanding_blocks=16),
            TenantSpec(pd=2, arb_weight=1, mode="open",
                       arrival_period_us=60.0, n_requests=6,
                       dst_prep=BufferPrep.FAULTING),
        ]
        a = soak(31, tenants=tenants, injection=CHURN)
        b = soak(31, tenants=[dataclasses.replace(t) for t in tenants],
                 injection=CHURN)
        assert a.json() == b.json()
        assert a.violations == [] and b.violations == []


def _npr_churn_tenants():
    """NP-RDMA tenants whose warm MTT entries race reclaim/khugepaged:
    re-used (non-fresh) destinations keep translations cached so churn
    invalidations hit *in-flight* speculative transfers."""
    return [
        TenantSpec(pd=1, name="npr-warm", strategy=Strategy.NP_RDMA,
                   mode="closed", inflight=2, n_requests=10,
                   size_choices=(16384, 65536),
                   dst_prep=BufferPrep.TOUCHED, fresh_dst=False,
                   region_slots=2),
        TenantSpec(pd=2, name="npr-cold", strategy=Strategy.NP_RDMA,
                   mode="closed", inflight=2, n_requests=8,
                   dst_prep=BufferPrep.FAULTING),
        TenantSpec(pd=3, name="thesis", mode="closed", inflight=2,
                   n_requests=8, dst_prep=BufferPrep.FAULTING),
    ]


class TestNPRChurnSoak:
    """MTT invalidation under churn: reclaim/khugepaged race in-flight
    speculative transfers; no stale translation may ever complete."""

    @pytest.mark.parametrize("seed", [40, 48, 49])
    def test_zero_stale_completions_under_churn(self, seed):
        r = soak(seed, tenants=_npr_churn_tenants(), injection=CHURN)
        assert r.violations == []
        for t in r.stats["tenants"]:
            assert t["completed"] == t["posted"]
        npr = r.stats["npr"]
        assert npr                            # NPR engines were active
        for node_stats in npr.values():
            assert node_stats["stale_completions"] == 0
        # the race actually happened: churn invalidated cached entries,
        # and at least one invalidation landed on an in-flight round
        # (verification caught it as a stale hit)
        assert sum(s["mtt_invalidations"] for s in npr.values()) > 0
        assert sum(s["mtt_stale_hits"] for s in npr.values()) > 0

    def test_churn_soak_byte_identical_per_seed(self):
        a = soak(47, tenants=_npr_churn_tenants(), injection=CHURN)
        b = soak(47, tenants=_npr_churn_tenants(), injection=CHURN)
        assert a.json().encode() == b.json().encode()
        assert json.loads(a.json())["npr"] == json.loads(b.json())["npr"]
