"""Per-kernel shape/dtype sweeps: pallas (interpret=True) vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.page_pack.ops import gather_pages, scatter_pages
from repro.kernels.page_pack.ref import page_gather_ref, page_scatter_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

# full model/kernel/device sweeps: minutes of work, deselected in the
# CI fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestPagedAttentionKernel:
    @pytest.mark.parametrize("B,H,KVH,D,ps,NP", [
        (1, 4, 4, 16, 4, 2),     # MHA
        (2, 8, 2, 32, 8, 3),     # GQA
        (3, 8, 1, 64, 8, 4),     # MQA
        (2, 16, 8, 128, 16, 2),  # production-like head_dim
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, H, KVH, D, ps, NP, dtype):
        P = B * NP
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, D), dtype)
        kp = jax.random.normal(ks[1], (P, ps, KVH, D), dtype)
        vp = jax.random.normal(ks[2], (P, ps, KVH, D), dtype)
        pt = jnp.arange(P, dtype=jnp.int32).reshape(B, NP)
        lengths = jnp.asarray(
            np.linspace(1, NP * ps, B).astype(np.int32))
        out = paged_attention(q, kp, vp, pt, lengths, interpret=True)
        ref = paged_attention_ref(
            q.reshape(B, KVH, H // KVH, D), kp.transpose(2, 0, 1, 3),
            vp.transpose(2, 0, 1, 3), pt, lengths).reshape(B, H, D)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))

    def test_window_masking(self):
        B, H, KVH, D, ps, NP = 2, 8, 2, 32, 8, 4
        P = B * NP
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (P, ps, KVH, D))
        vp = jax.random.normal(ks[2], (P, ps, KVH, D))
        pt = jnp.arange(P, dtype=jnp.int32).reshape(B, NP)
        lengths = jnp.array([NP * ps, NP * ps // 2], jnp.int32)
        for w in (8, 16):
            out = paged_attention(q, kp, vp, pt, lengths, window=w,
                                  interpret=True)
            ref = paged_attention_ref(
                q.reshape(B, KVH, H // KVH, D), kp.transpose(2, 0, 1, 3),
                vp.transpose(2, 0, 1, 3), pt, lengths,
                window=w).reshape(B, H, D)
            np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_unmapped_pages_masked(self):
        """-1 page-table entries (non-resident, thesis terms) contribute 0."""
        B, H, KVH, D, ps = 1, 4, 4, 16, 4
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (4, ps, KVH, D))
        vp = jax.random.normal(ks[2], (4, ps, KVH, D))
        lengths = jnp.array([8], jnp.int32)
        a = paged_attention(q, kp, vp, jnp.array([[0, 1, -1, -1]], jnp.int32),
                            lengths, interpret=True)
        b = paged_attention(q, kp, vp, jnp.array([[0, 1, 2, 3]], jnp.int32),
                            lengths, interpret=True)
        np.testing.assert_allclose(a, b, atol=1e-6)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,S,H,KVH,D", [
        (1, 32, 4, 4, 16),
        (2, 48, 4, 2, 32),    # GQA + padded seq (48 % 16 != 0 w/ block 32)
        (1, 128, 8, 1, 64),   # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, B, S, H, KVH, D, dtype):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dtype)
        k = jax.random.normal(ks[1], (B, S, KVH, D), dtype)
        v = jax.random.normal(ks[2], (B, S, KVH, D), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        ref = flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("window", [8, 24])
    def test_sliding_window(self, window):
        B, S, H, KVH, D = 1, 64, 4, 2, 16
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, KVH, D))
        v = jax.random.normal(ks[2], (B, S, KVH, D))
        out = flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_k=16, interpret=True)
        ref = flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True,
            window=window).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_non_causal(self):
        B, S, H, D = 2, 32, 4, 16
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, H, D))
        v = jax.random.normal(ks[2], (B, S, H, D))
        out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16,
                              interpret=True)
        ref = flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=False).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


class TestPagePackKernels:
    @pytest.mark.parametrize("P,n,elems", [(8, 4, 32), (64, 16, 128),
                                           (16, 16, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    def test_gather(self, P, n, elems, dtype):
        if dtype == jnp.int32:
            pool = jax.random.randint(KEY, (P, elems), 0, 100, dtype)
        else:
            pool = jax.random.normal(KEY, (P, elems), dtype)
        idx = jax.random.permutation(KEY, P)[:n].astype(jnp.int32)
        out = gather_pages(pool, idx, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(page_gather_ref(pool, idx)))

    def test_scatter_preserves_untouched_rows(self):
        pool = jax.random.normal(KEY, (16, 32))
        idx = jnp.array([2, 9, 14], jnp.int32)
        blk = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
        ref = page_scatter_ref(pool, idx, blk)
        out = scatter_pages(pool.copy(), idx, blk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref))

    def test_gather_scatter_roundtrip(self):
        pool = jax.random.normal(KEY, (32, 8, 16))
        idx = jnp.array([5, 1, 30, 7], jnp.int32)
        pages = gather_pages(pool, idx, interpret=True)
        pool2 = scatter_pages(jnp.zeros_like(pool), idx, pages,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(pool2[np.asarray(idx)]),
                                   np.asarray(pool[np.asarray(idx)]))
