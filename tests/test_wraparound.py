"""tr_ID/seq_num wraparound regression suite (ISSUE-5 tentpole).

The wire protocol's 14-bit tr_ID (Table 3.2) makes ID reuse a protocol
property: these tests pin the free-list allocator (recycle ONLY on
completion), the host-side generation tags that keep RAPF matching and
driver dedup correct across incarnations, the O(1) per-(pd, vpn) fault
index, typed TrIdExhausted backpressure, and the satellite fixes
(completion-timestamp skew, phantom-timeout accounting, pin dedup).

Most tests shrink the ID space via ``FabricConfig.tr_id_space`` — a
host-side scale-model knob; the wire encoding is untouched — so wraps
happen in milliseconds.  One test drives a genuine >2^14-block wrap
through a node while an early block sits paused across the boundary.
"""

import numpy as np
import pytest

from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy, TrIdExhausted, WorkQueueFull, WROpcode)
from repro.core import addresses as A
from repro.core.addresses import RAPFMessage
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.fault_fifo import FaultFIFO, FIFOEntry
from repro.core.resolver import DriverDedupCache
from repro.testing import (FaultInjection, TenantSpec,
                           check_tr_id_lifecycle, soak)

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
UNMAPPED_DST = 0x66_0000_0000


def make_fabric(**kw):
    return Fabric.build(FabricConfig(n_nodes=2, **kw))


def paused_write(fab, pd, nbytes=4096, src=SRC):
    """A write whose destination VA is never mmap'd: every round NACKs,
    the Touch-A-Page resolver SEGFAULTs (recovered), the block pauses and
    retries on timeout forever — its tr_ID stays pending indefinitely."""
    dom = fab.domains[pd]
    mr = dom.register_memory(0, src, nbytes, prep=BufferPrep.TOUCHED)
    cq = fab.create_cq()
    cq.on_post()
    t = fab._start_write(pd, 0, src, 1, UNMAPPED_DST, nbytes)
    return fab._track(fab._next_wr_id(), WROpcode.WRITE, cq, t), mr


class TestFullSpaceWrap:
    """The honest >2^14-block test: no shrunken ID space."""

    @pytest.mark.slow
    def test_paused_block_survives_wrap_and_no_aliasing(self):
        fab = make_fabric(default_policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        fab.open_domain(1)
        fab.open_domain(2)
        # tenant A: one block that pauses (unmapped dst) and holds its
        # early tr_ID across the whole wrap
        wr_a, _ = paused_write(fab, pd=1)
        fab.progress(until=5_000.0)
        r5 = fab.nodes[0].r5
        a_block = wr_a.transfer.blocks[0]
        assert a_block.tr_id >= 0 and r5.pending[a_block.tr_id] is a_block
        assert wr_a.stats.dst_faults > 0         # it faulted and paused

        # tenant B: >2^14 clean blocks through the same node.  On the
        # seed, launch 16384 + a_block.tr_id would alias A's pending
        # entry and orphan the paused block forever.
        dom_b = fab.domains[2]
        cq = fab.create_cq(depth=512)
        blocks_per_wr = 256                       # 4 MB -> 256 blocks
        n_wr = (A.TR_ID_SPACE // blocks_per_wr) + 2       # 16.9k blocks
        for i in range(n_wr):
            size = blocks_per_wr * A.BLOCK_SIZE
            s = dom_b.register_memory(0, SRC + 0x1000_0000 + i * 0x80_0000,
                                      size, prep=BufferPrep.TOUCHED)
            d = dom_b.register_memory(1, DST + 0x1000_0000 + i * 0x80_0000,
                                      size, prep=BufferPrep.TOUCHED)
            wc = dom_b.post_write(s, d, cq=cq).result(deadline_us=1e9)
            assert wc.stats.retransmissions == 0
        st = r5.id_stats
        assert st.fresh == A.TR_ID_SPACE          # full space issued once
        assert st.allocated > A.TR_ID_SPACE       # and wrapped
        assert st.recycled == st.allocated - st.fresh
        assert st.wraps >= 1
        # A's ID was never recycled out from under the paused block
        assert r5.pending.get(a_block.tr_id) is a_block
        assert a_block.tr_id not in list(r5._free)

        # resolve A: map the destination, then displace A's entry from
        # the driver's last-2 dedup cache with an unrelated faulting
        # write (as real mixed traffic would) so the next NACK round is
        # handled, touched in, RAPF'd — and the transfer lands
        fab.nodes[1].pt(1).mmap(UNMAPPED_DST, 4096)
        for j in range(2):                       # 2 keys evict A's from
            s = dom_b.register_memory(0, SRC + 0x7000_0000 + j * 0x100000,
                                      4096, prep=BufferPrep.TOUCHED)
            d = dom_b.register_memory(1, DST + 0x7000_0000 + j * 0x100000,
                                      4096, prep=BufferPrep.FAULTING)
            dom_b.post_write(s, d, cq=cq).result(deadline_us=1e7)
        wc_a = wr_a.result(deadline_us=1e7)
        assert wc_a.stats.rapf_retransmits >= 1
        assert r5.pending == {}
        assert check_tr_id_lifecycle(fab) == []


class TestShrunkenSpace:
    def test_exhaustion_defers_and_conserves(self):
        """Launches beyond the ID space defer (FIFO) and drain to
        completion as IDs free — nothing lost, nothing duplicated."""
        fab = make_fabric(tr_id_space=4)
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        wrs = []
        for i in range(3):                       # 3 x 4 blocks, 4 IDs
            s = dom.register_memory(0, SRC + i * 0x100000, 65536,
                                    prep=BufferPrep.TOUCHED)
            d = dom.register_memory(1, DST + i * 0x100000, 65536,
                                    prep=BufferPrep.TOUCHED)
            wrs.append(dom.post_write(s, d, cq=cq))
        for wr in wrs:
            wr.result(deadline_us=1e7)
        st = fab.nodes[0].r5.id_stats
        assert st.stalls > 0                     # deferral really happened
        assert st.max_in_flight <= 4
        assert st.recycled > 0
        assert check_tr_id_lifecycle(fab) == []

    def test_deferred_launch_redeemed_fifo_before_self_refill(self):
        """A freed ID goes to the earlier-deferred tenant, not straight
        back to the completing transfer's own next block — deferral
        tickets are redeemed in launch order."""
        fab = make_fabric(tr_id_space=2)
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        big_s = dom.register_memory(0, SRC, 16 * A.BLOCK_SIZE,
                                    prep=BufferPrep.TOUCHED)
        big_d = dom.register_memory(1, DST, 16 * A.BLOCK_SIZE,
                                    prep=BufferPrep.TOUCHED)
        wr_a = dom.post_write(big_s, big_d, cq=cq)   # claims both IDs
        s = dom.register_memory(0, SRC + 0x100000, 4096,
                                prep=BufferPrep.TOUCHED)
        d = dom.register_memory(1, DST + 0x100000, 4096,
                                prep=BufferPrep.TOUCHED)
        wr_b = dom.post_write(s, d, cq=cq)           # launch defers
        wr_b.result(deadline_us=1e6)
        assert not wr_a.done          # B overtook A's remaining backlog
        assert fab.nodes[0].r5.id_stats.stalls >= 1
        wr_a.result(deadline_us=1e7)
        assert check_tr_id_lifecycle(fab) == []

    def test_post_raises_typed_trid_exhausted(self):
        fab = make_fabric(tr_id_space=2,
                          default_policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        dom = fab.open_domain(1)
        paused_write(fab, 1, src=SRC)
        paused_write(fab, 1, src=SRC + 0x100000)
        fab.progress(until=3_000.0)              # both IDs now pending
        assert fab.nodes[0].r5.tr_ids_free() == 0
        s = dom.register_memory(0, SRC + 0x200000, 4096,
                                prep=BufferPrep.TOUCHED)
        d = dom.register_memory(1, DST + 0x200000, 4096,
                                prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        with pytest.raises(TrIdExhausted) as ei:
            dom.post_write(s, d, cq=cq)
        assert isinstance(ei.value, WorkQueueFull)   # generic backpressure
        assert fab.nodes[0].r5.id_stats.exhausted_posts == 1

    def test_stale_rapf_generation_dropped(self):
        """A RAPF addressed to a previous incarnation of a recycled tr_ID
        must not retransmit the block that inherited the ID."""
        fab = make_fabric(tr_id_space=1,
                          default_policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        dom = fab.open_domain(1)
        # incarnation 1: completes cleanly, recycling ID 0
        s = dom.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        d = dom.register_memory(1, DST, 4096, prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        dom.post_write(s, d, cq=cq).result(deadline_us=1e7)
        # incarnation 2: pends forever on ID 0
        wr2, _ = paused_write(fab, 1, src=SRC + 0x100000)
        fab.progress(until=8_000.0)
        r5 = fab.nodes[0].r5
        block = wr2.transfer.blocks[0]
        assert block.tr_id == 0 and block.gen == 2
        before = wr2.stats.rapf_retransmits
        msg = RAPFMessage(wired_pdid=1, rcved_pdid=1, tr_id=0, seq_num=0)
        r5.on_mailbox(msg, None, gen=1)          # stale incarnation
        fab.progress(until=fab.now + 10.0)
        assert wr2.stats.rapf_retransmits == before
        assert r5.id_stats.stale_rapf_drops == 1
        r5.on_mailbox(msg, None, gen=2)          # current incarnation
        fab.progress(until=fab.now + 10.0)
        assert wr2.stats.rapf_retransmits == before + 1
        # untagged RAPFs (legacy/forged path) still pass the gen check
        r5.on_mailbox(msg, None)
        fab.progress(until=fab.now + 10.0)
        assert wr2.stats.rapf_retransmits == before + 2

    def test_wrapped_soak_with_faults_and_churn_holds_invariants(self):
        """Recycled-ID regime under fault storms + reclaim churn: every
        soak invariant (conservation, arbiter, tr_id lifecycle) holds and
        the run is seed-deterministic."""
        tenants = [
            TenantSpec(pd=1, name="fault", mode="closed", inflight=3,
                       n_requests=24, size_choices=(65536,),
                       dst_prep=BufferPrep.FAULTING, fresh_dst=True),
            TenantSpec(pd=2, name="clean", mode="closed", inflight=2,
                       n_requests=24, size_choices=(16384,),
                       dst_prep=BufferPrep.TOUCHED),
        ]
        churn = FaultInjection(khugepaged_period_us=500.0,
                               reclaim_period_us=700.0, reclaim_pages=8)
        cfg = FabricConfig(n_nodes=2, tr_id_space=8)
        a = soak(99, tenants=tenants, config=cfg, injection=churn)
        assert a.violations == []
        hot = a.fabric.nodes[0].r5.id_stats
        assert hot.wraps >= 2 and hot.recycled > 0
        b = soak(99, tenants=tenants,
                 config=FabricConfig(n_nodes=2, tr_id_space=8),
                 injection=churn)
        assert a.json() == b.json()              # byte-identical


class TestSrcFaultIndex:
    def test_index_matches_linear_scan_mid_flight(self):
        """The O(1) (pd, vpn) index answers exactly what the seed's
        O(pending) scan did, at every point of a faulting run."""

        def ref_scan(r5, pd, vpn):
            for block in r5.pending.values():
                if block.transfer.pd != pd:
                    continue
                first = block.src_va >> 12
                last = (block.src_va + block.nbytes - 1) >> 12
                if first <= vpn <= last:
                    return block
            return None

        fab = make_fabric()
        dom1 = fab.open_domain(1)
        dom2 = fab.open_domain(2)
        cqs = []
        for i, dom in enumerate((dom1, dom2, dom1, dom2)):
            s = dom.register_memory(0, SRC + i * 0x100000, 65536,
                                    prep=BufferPrep.TOUCHED)
            d = dom.register_memory(1, DST + i * 0x100000, 65536,
                                    prep=BufferPrep.FAULTING)
            cq = fab.create_cq()
            dom.post_write(s, d, cq=cq)
            cqs.append(cq)
        checked = 0
        while fab.loop.step():
            if fab.loop.events_processed % 40 == 0:
                for node in fab.nodes:
                    r5 = node.r5
                    for block in r5.pending.values():
                        pd = block.transfer.pd
                        first = block.src_va >> 12
                        last = (block.src_va + block.nbytes - 1) >> 12
                        for vpn in (first, last, first - 1, last + 1):
                            assert (r5.find_block_by_src_page(pd, vpn)
                                    is ref_scan(r5, pd, vpn))
                            checked += 1
        assert checked > 100
        assert check_tr_id_lifecycle(fab) == []


class TestIndexNeutrality:
    def test_soak_byte_identical_with_reference_scan(self, monkeypatch):
        """The per-(pd, vpn) index is a pure lookup-structure swap: a
        same-seed soak with the seed's O(pending) linear scan patched
        back in produces byte-identical stats."""
        from repro.core.node import R5Scheduler

        def linear_scan(self, pd, vpn):
            for block in self.pending.values():
                if block.transfer.pd != pd:
                    continue
                first = block.src_va >> 12
                last = (block.src_va + block.nbytes - 1) >> 12
                if first <= vpn <= last:
                    return block
            return None

        churn = FaultInjection(khugepaged_period_us=600.0,
                               reclaim_period_us=900.0, reclaim_pages=16)
        fast = soak(7, injection=churn)
        monkeypatch.setattr(R5Scheduler, "find_block_by_src_page",
                            linear_scan)
        slow = soak(7, injection=churn)
        assert fast.json() == slow.json()
        assert fast.violations == []


class TestGenerationDedup:
    def test_fifo_dedup_is_generation_aware(self):
        fifo = FaultFIFO()
        e = FIFOEntry(src_id=3, tr_id=0, seq_num=0, pdid=1, iova_field=42)
        assert fifo.push(e, gen=1)
        assert not fifo.push(e, gen=1)           # hardware dedup
        assert fifo.stats.dedup_skips == 1
        assert fifo.push(e, gen=2)               # new incarnation logs
        assert fifo.pop_entry() == e
        assert fifo.last_popped_gen == 1
        assert fifo.pop_entry() == e
        assert fifo.last_popped_gen == 2

    def test_fifo_wire_words_unchanged_by_gen(self):
        """The generation sidecar never reaches the 128-bit entry."""
        e = FIFOEntry(src_id=5, tr_id=77, seq_num=9, pdid=2, iova_field=7)
        a, b = FaultFIFO(), FaultFIFO()
        a.push(e)                                # untagged
        b.push(e, gen=12345)
        assert (a.read64(0), a.read64(1)) == (b.read64(0), b.read64(1))

    def test_driver_dedup_cache_distinguishes_incarnations(self):
        cache = DriverDedupCache()
        key = (3, 0, 0, 42)
        cache.note(key + (1,))
        assert cache.seen(key + (1,))
        assert not cache.seen(key + (2,))        # fresh incarnation handled


class TestSatelliteFixes:
    def test_completion_callback_runs_at_t_complete(self):
        """on_complete fires AT stats.t_complete (the status-poll return),
        not completion_poll_us earlier with a future timestamp."""
        fab = make_fabric()
        dom = fab.open_domain(1)
        s = dom.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        d = dom.register_memory(1, DST, 4096, prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        wr = dom.post_write(s, d, cq=cq)
        seen = {}
        inner = wr.transfer.on_complete

        def probe(t):
            seen["now"] = fab.now
            seen["t_complete"] = t.stats.t_complete
            inner(t)

        wr.transfer.on_complete = probe
        wr.result(deadline_us=1e6)
        assert seen["now"] == pytest.approx(seen["t_complete"])

    def test_phantom_timeout_accounting(self):
        """A round that pauses PAUSED_SRC before any packet leaves counts
        a phantom timeout; its re-dispatch is NOT a retransmission (there
        was nothing on the wire to re-send).  Total `timeouts` keeps the
        thesis' Fig 4.6 semantics (every fired R5 timer)."""
        fab = make_fabric(default_policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        dom = fab.open_domain(1)
        s = dom.register_memory(0, SRC, 4096, prep=BufferPrep.FAULTING)
        d = dom.register_memory(1, DST, 4096, prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        wc = dom.post_write(s, d, cq=cq).result(deadline_us=1e7)
        assert wc.stats.src_faults == 1
        assert wc.stats.timeouts == 1            # thesis-calibrated count
        assert wc.stats.phantom_timeouts == 1    # ...but zero-byte round
        assert wc.stats.retransmissions == 0     # nothing was re-sent

    def test_streamed_round_timeout_not_phantom(self):
        """Faults beyond the first page stream bytes first: those rounds'
        timeouts are real and their re-dispatches are retransmissions."""
        fab = make_fabric(default_policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        dom = fab.open_domain(1)
        # 2 pages: page 0 resident, page 1 faulting at the source
        pt = fab.nodes[0].pt(1)
        s = dom.register_memory(0, SRC, 8192, prep=BufferPrep.FAULTING)
        pt.touch(SRC >> 12)                      # only page 0 resident
        d = dom.register_memory(1, DST, 8192, prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        wc = dom.post_write(s, d, cq=cq).result(deadline_us=1e7)
        assert wc.stats.timeouts == 1
        assert wc.stats.phantom_timeouts == 0    # page 0 hit the wire
        assert wc.stats.retransmissions == 1

    def test_pin_duplicates_counted_once(self):
        from repro.vmem import HostFramePool, Pager
        pool = HostFramePool(4, 8)
        pager = Pager(pool, policy=FaultPolicy(
            Strategy.TOUCH_A_PAGE, pin_limit_bytes=1 * 4096))
        sp = pager.create_space(8, name="t")
        for v in range(8):
            sp.write(v, np.zeros(8, np.float32))
        base = pager.stats.simulated_us
        sp.pin([3, 3])                           # one page of headroom: OK
        assert bool(sp.pinned[3])
        charged = pager.stats.simulated_us - base
        assert charged == pytest.approx(DEFAULT_COST_MODEL.pin_us(4096))
        assert pager.stats.pin_violations == 0
        with pytest.raises(MemoryError):
            sp.pin([4])                          # budget genuinely full
