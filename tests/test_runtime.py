"""Runtime layers: paged store, KV manager, offloaded optimizer, serving
engine, trainer+checkpoint restart, data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.core.resolver import Strategy
from repro.data.pipeline import PackedFileDataset, ShardInfo, SyntheticLM, \
    write_packed_file
from repro.distributed.checkpoint import Checkpointer
from repro.memory.kv_cache import PagedKVManager
from repro.memory.offload import PagedAdamW
from repro.memory.paged_store import PagedTensorStore
from repro.models.config import reduced
from repro.models.registry import model_for
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.serving.engine import ServingEngine
from repro.training.trainer import TrainConfig, Trainer

# full model/kernel/device sweeps: minutes of work, deselected in the
# CI fast tier (pytest -m "not slow")
pytestmark = pytest.mark.slow


class TestPagedTensorStore:
    def test_fault_and_touch_ahead(self):
        st = PagedTensorStore(page_elems=8, n_device_frames=4, n_host_pages=16,
                              strategy=Strategy.TOUCH_AHEAD, lookahead=4)
        for v in range(16):
            st.write_host(v, np.full(8, v, np.float32))
        out = st.access([0])
        assert st.stats.faults == 1
        assert st.resident_pages() == 4          # touched ahead
        np.testing.assert_array_equal(np.asarray(out[0]), np.zeros(8))
        st.access([1, 2, 3])
        assert st.stats.faults == 1              # prefetched, no new faults
        assert st.stats.prefetch_hits == 3

    def test_touch_a_page_faults_per_page(self):
        st = PagedTensorStore(8, 8, 16, strategy=Strategy.TOUCH_A_PAGE)
        for v in range(16):
            st.write_host(v, np.full(8, v, np.float32))
        st.access([0, 1, 2, 3])
        assert st.stats.faults == 4

    def test_eviction_writeback_roundtrip(self):
        st = PagedTensorStore(4, 2, 8, strategy=Strategy.TOUCH_A_PAGE)
        st.write_host(0, np.zeros(4, np.float32))
        st.access([0])
        # mutate the device copy, then force eviction by touching others
        f = int(st.page_table[0])
        st.frames = st.frames.at[f].set(jnp.full(4, 7.0))
        st.access([1])
        st.access([2])                            # evicts page 0 (LRU)
        assert not st.is_resident(0)
        out = st.access([0])                      # faults back in
        np.testing.assert_array_equal(np.asarray(out[0]), np.full(4, 7.0))

    def test_pinned_never_evicted(self):
        st = PagedTensorStore(4, 2, 8)
        st.pin([0])
        st.access([1])
        with pytest.raises(MemoryError):
            st.pin([1]) or st.access([2]) if False else (
                st.pin([1]), st.access([2]))


class TestPagedKVManager:
    def test_spill_and_touch_ahead_fault(self):
        kv = PagedKVManager(n_frames=8, page_tokens=4, max_pages_per_seq=8,
                            strategy=Strategy.TOUCH_AHEAD)
        kv.add_sequence(1)
        kv.add_sequence(2)
        kv.append_tokens(1, 32)                   # all 8 frames to seq 1
        assert kv.frames_used == 8
        kv.append_tokens(2, 8, spill_candidates=[1])   # forces spills
        assert kv.stats.spills == 2
        assert len(kv.spilled[1]) == 2
        n = kv.ensure_resident(1, spill_candidates=[2])
        assert n == 2
        assert not kv.spilled[1]
        assert kv.stats.fault_events == 1         # one block fault (T-A)

    def test_touch_a_page_pays_per_page(self):
        kv = PagedKVManager(8, 4, 8, strategy=Strategy.TOUCH_A_PAGE)
        kv.add_sequence(1)
        kv.add_sequence(2)
        kv.append_tokens(1, 32)
        kv.append_tokens(2, 12, spill_candidates=[1])
        n = kv.ensure_resident(1, spill_candidates=[2])
        assert n == 3
        assert kv.stats.fault_events == 3         # one per page

    def test_device_table_masks_spilled(self):
        kv = PagedKVManager(4, 4, 4)
        kv.add_sequence(1)
        kv.append_tokens(1, 16)
        tbl = kv.device_table([1])
        assert (tbl >= 0).all()
        kv.add_sequence(2)
        kv.append_tokens(2, 4, spill_candidates=[1])
        tbl = kv.device_table([1])
        assert (tbl == -1).sum() == 1             # spilled slot unmapped


class TestOffloadedOptimizer:
    def test_matches_reference_adamw(self):
        cfg = AdamWConfig(lr=1e-2, grad_clip=0.0, weight_decay=0.01)
        key = jax.random.PRNGKey(0)
        params = {"a": jax.random.normal(key, (33, 7)),
                  "b": jnp.ones((11,))}
        grads = {"a": jax.random.normal(jax.random.PRNGKey(1), (33, 7)),
                 "b": jnp.full((11,), 0.5)}
        ref_state = adamw.init(cfg, params)
        ref_p = params
        po = PagedAdamW(cfg, params, block_elems=64)
        pg_p = params
        for _ in range(3):
            ref_p, ref_state, _ = adamw.update(cfg, ref_state, ref_p, grads)
            pg_p = po.update(pg_p, grads)
        for k in params:
            np.testing.assert_allclose(np.asarray(pg_p[k]),
                                       np.asarray(ref_p[k]), atol=1e-5)
        assert po.stats.prefetch_overlapped > 0

    def test_device_residency_bounded(self):
        cfg = AdamWConfig()
        params = {"w": jnp.zeros((1 << 16,))}
        po = PagedAdamW(cfg, params, block_elems=1 << 10)
        assert po.device_bytes_resident() == 2 * (1 << 10) * 8
        # full f32 moments would be 2 * 4 bytes * 65536 = 512 KiB; the
        # paged working set is 16 KiB
        assert po.device_bytes_resident() < 2 * 4 * (1 << 16) // 8


class TestServingEngine:
    def _engine(self, **kw):
        cfg = reduced(all_configs()["h2o_danube_1_8b"], n_layers=2)
        model = model_for(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, ServingEngine(cfg, params, max_batch=2, max_len=64, **kw)

    def test_continuous_batching_completes(self):
        _, eng = self._engine()
        rng = np.random.default_rng(0)
        reqs = [eng.submit(rng.integers(0, 100, size=4), max_new_tokens=5)
                for _ in range(4)]
        eng.run_until_done()
        assert all(r.done for r in reqs)
        assert all(len(r.generated) == 5 for r in reqs)
        assert eng.stats.decode_steps > 0

    def test_greedy_deterministic(self):
        _, e1 = self._engine()
        _, e2 = self._engine()
        prompt = np.array([5, 6, 7], np.int32)
        r1 = e1.submit(prompt, 6)
        r2 = e2.submit(prompt, 6)
        e1.run_until_done()
        e2.run_until_done()
        assert r1.generated == r2.generated


class TestTrainerCheckpointRestart:
    def test_restart_resumes_identically(self, tmp_path):
        cfg = reduced(all_configs()["starcoder2_3b"], n_layers=2)
        model = model_for(cfg)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        ds = SyntheticLM(cfg.vocab_size, 16, 4)
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
        ck = Checkpointer()

        tr = Trainer(cfg, tcfg, params, ds, checkpoint_dir=str(tmp_path),
                     checkpoint_every=5, checkpointer=ck)
        tr.run(10, log_every=0)
        loss_10 = tr.history[-1]["loss"]

        # "crash" and restore from step 10, run 5 more
        tr2 = Trainer(cfg, tcfg, model.init_params(cfg, jax.random.PRNGKey(9)),
                      ds, checkpoint_dir=str(tmp_path), checkpointer=ck)
        restored = ck.restore_latest(str(tmp_path), tr2.params, tr2.opt_state)
        assert restored is not None
        tr2.params, tr2.opt_state, tr2.step = restored
        assert tr2.step == 10
        tr2.run(5, log_every=0)

        # uninterrupted reference
        tr3 = Trainer(cfg, tcfg, model.init_params(cfg, jax.random.PRNGKey(0)),
                      ds)
        tr3.run(15, log_every=0)
        assert tr2.history[-1]["loss"] == pytest.approx(
            tr3.history[-1]["loss"], rel=1e-4)


class TestDataPipeline:
    def test_synthetic_deterministic_and_learnable(self):
        ds1 = SyntheticLM(100, 32, 4, seed=7)
        ds2 = SyntheticLM(100, 32, 4, seed=7)
        t1, l1 = ds1.batch_at(3)
        t2, l2 = ds2.batch_at(3)
        np.testing.assert_array_equal(t1, t2)
        assert (l1[:, -1] == -1).all()

    def test_shards_disjoint(self):
        a = SyntheticLM(100, 16, 4, ShardInfo(0, 2)).batch_at(0)[0]
        b = SyntheticLM(100, 16, 4, ShardInfo(1, 2)).batch_at(0)[0]
        assert not np.array_equal(a, b)

    def test_packed_file_resume_arithmetic(self, tmp_path):
        path = str(tmp_path / "tokens.bin")
        write_packed_file(path, np.arange(10_000) % 500)
        ds = PackedFileDataset(path, 500, 32, 2, ShardInfo(1, 4))
        t1, _ = ds.batch_at(5)
        ds2 = PackedFileDataset(path, 500, 32, 2, ShardInfo(1, 4))
        t2, _ = ds2.batch_at(5)          # resume is pure arithmetic
        np.testing.assert_array_equal(t1, t2)
        labels = ds.batch_at(0)
        np.testing.assert_array_equal(labels[0][0, 1:], labels[1][0, :-1])
