"""HLO analyzer + logical-sharding-rule units (roofline correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo, shape_bytes, shape_elems
from repro.distributed.logical import logical_rules, spec_for, constrain
from repro.launch.mesh import axis_types_kwargs


class TestShapeParsing:
    def test_shape_bytes(self):
        assert shape_bytes("f32[256,512]") == 256 * 512 * 4
        assert shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
        assert shape_bytes("(f32[4], s32[2,2])") == 16 + 16
        assert shape_bytes("pred[]") == 1

    def test_shape_elems(self):
        assert shape_elems("f32[3,5,7]") == 105


class TestAnalyzeHLO:
    def test_scan_flops_scale_with_trip_count(self):
        """The core roofline fix: while bodies × known_trip_count."""
        def f(x, ws):
            def step(c, w):
                return c @ w, None
            return jax.lax.scan(step, x, ws)[0]

        B, D = 64, 32
        for L in (2, 4, 8):
            c = jax.jit(f).lower(jnp.zeros((B, D)),
                                 jnp.zeros((L, D, D))).compile()
            res = analyze_hlo(c.as_text())
            analytic = L * 2 * B * D * D
            assert res.dot_flops == pytest.approx(analytic, rel=0.01), L

    def test_plain_matmul_flops_exact(self):
        c = jax.jit(lambda a, b: a @ b).lower(
            jnp.zeros((128, 64)), jnp.zeros((64, 32))).compile()
        res = analyze_hlo(c.as_text())
        assert res.dot_flops == pytest.approx(2 * 128 * 64 * 32, rel=0.01)

    def test_nested_scan_multiplies(self):
        def f(x, ws):
            def outer(c, w):
                def inner(ci, _):
                    return ci @ w, None
                return jax.lax.scan(inner, c, None, length=3)[0], None
            return jax.lax.scan(outer, x, ws)[0]

        B, D, L = 16, 8, 4
        c = jax.jit(f).lower(jnp.zeros((B, D)),
                             jnp.zeros((L, D, D))).compile()
        res = analyze_hlo(c.as_text())
        assert res.dot_flops == pytest.approx(L * 3 * 2 * B * D * D, rel=0.01)

    def test_no_collectives_on_single_device(self):
        c = jax.jit(lambda a: a @ a.T).lower(jnp.zeros((32, 32))).compile()
        res = analyze_hlo(c.as_text())
        assert res.collective_bytes == 0.0


class TestLogicalRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"),
                             **axis_types_kwargs(2))

    def test_noop_without_policy(self):
        x = jnp.ones((4, 8))
        assert constrain(x, "batch", "embed") is x

    def test_divisibility_drops_axis(self):
        mesh = jax.make_mesh(
            (1, 1), ("data", "model"), **axis_types_kwargs(2))
        with logical_rules(mesh, {"heads": "model", "batch": "data"}):
            # heads=24 % model size 1 == 0 -> kept (size-1 axis trivially ok)
            spec = spec_for((2, 24), ("batch", "heads"))
            assert spec is not None

    def test_duplicate_axis_never_emitted(self):
        """The deepseek DuplicateSpecError regression."""
        mesh = self._mesh()
        with logical_rules(mesh, {"experts": ("model", "data"),
                                  "moe_ff": "model"}):
            spec = spec_for((4, 8, 16), ("experts", "capacity", "moe_ff"))
            flat = []
            for s in spec:
                flat.extend(s if isinstance(s, tuple) else [s])
            named = [a for a in flat if a]
            assert len(named) == len(set(named))

    def test_wrong_rank_is_noop(self):
        mesh = self._mesh()
        with logical_rules(mesh, {"batch": "data"}):
            x = jnp.ones((4, 8, 2))
            assert constrain(x, "batch", "embed") is x
