"""Wheel ↔ heap event-kernel equivalence (the frozen tie-break contract).

The bucketed wheel (:class:`repro.core.simulator.EventLoop`) and the
legacy binary heap (:class:`~repro.core.simulator.HeapEventLoop`,
``REPRO_EVENT_LOOP=heap``) must be observationally identical: same fire
order, same ``now`` trace, same ``idle`` answers, same live-event
counts — under any interleaving of ``schedule`` / ``cancel`` / ``at`` /
``step`` / ``run_batch`` / ``peek_time`` / ``run``, including handlers
that schedule more work while firing.

The property test drives both kernels with one randomized op sequence.
It uses ``hypothesis`` when the environment has it and falls back to a
seeded ``random.Random`` sweep otherwise (the container this repo grew
in ships no hypothesis), so the contract is exercised either way.
"""

import os
import random
import subprocess
import sys

import pytest

from repro.core.simulator import (EventLoop, HeapEventLoop, WHEEL_BUCKET_US,
                                  WHEEL_SPAN, make_event_loop)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _fire(log, loop, tag):
    log.append((loop.now, tag))


def _chain(log, loop, tag, delay):
    # a handler that schedules more work while firing: the schedule-order
    # seq allocated *during* the run must tie-break identically too
    log.append((loop.now, tag))
    loop.schedule(delay, _fire, log, loop, -tag)


def _live(loop):
    if isinstance(loop, HeapEventLoop):
        return len(loop._heap) - loop._n_cancelled
    return loop._n_queued - loop._n_cancelled


def _random_ops_trial(rng, n_ops=300):
    wheel = EventLoop()
    heap = HeapEventLoop()
    loops = (wheel, heap)
    logs = ([], [])
    handles = []          # parallel (wheel_event, heap_event) pairs
    tag = 0

    def check():
        assert wheel.now == heap.now
        assert logs[0] == logs[1]
        assert wheel.idle == heap.idle
        assert _live(wheel) == _live(heap)

    for _ in range(n_ops):
        op = rng.random()
        if op < 0.40:
            tag += 1
            kind = rng.random()
            if kind < 0.25:          # same-timestamp burst
                delay = 0.0
            elif kind < 0.60:        # in-bucket / near-wheel
                delay = rng.uniform(0.0, 4 * WHEEL_BUCKET_US)
            elif kind < 0.90:        # mid-wheel
                delay = rng.uniform(0.0, WHEEL_SPAN * WHEEL_BUCKET_US * 0.9)
            else:                    # far-future heap tier (overflow)
                delay = rng.uniform(WHEEL_SPAN * WHEEL_BUCKET_US,
                                    8 * WHEEL_SPAN * WHEEL_BUCKET_US)
            if rng.random() < 0.2:
                chain_delay = rng.uniform(0.0, 2 * WHEEL_BUCKET_US)
                pair = tuple(
                    lp.schedule(delay, _chain, lg, lp, tag, chain_delay)
                    for lp, lg in zip(loops, logs))
            else:
                pair = tuple(lp.schedule(delay, _fire, lg, lp, tag)
                             for lp, lg in zip(loops, logs))
            handles.append(pair)
        elif op < 0.50 and handles:
            we, he = handles[rng.randrange(len(handles))]
            we.cancel()
            he.cancel()
            we.cancel()              # double-cancel must not double-count
            he.cancel()
        elif op < 0.58:
            tag += 1
            t = wheel.now + rng.uniform(-10.0, 100.0)   # may clamp to now
            for lp, lg in zip(loops, logs):
                lp.at(t, _fire, lg, lp, tag)
        elif op < 0.74:
            k = rng.randrange(1, 8)
            assert wheel.run_batch(k) == heap.run_batch(k)
            check()
        elif op < 0.82:
            assert wheel.step() == heap.step()
            check()
        elif op < 0.92:
            assert wheel.peek_time() == heap.peek_time()
        else:
            until = wheel.now + rng.uniform(0.0, 200.0)
            wheel.run(until=until)
            heap.run(until=until)
            check()

    wheel.run()
    heap.run()
    check()
    assert wheel.events_processed == heap.events_processed
    assert _live(wheel) == 0
    assert wheel.idle and heap.idle
    # bulk sweeps may or may not have triggered, but never negative
    # bookkeeping: accounting drained exactly
    assert wheel.compactions >= 0 and heap.compactions >= 0
    assert wheel._n_cancelled == 0


if HAVE_HYPOTHESIS:                                   # pragma: no cover
    @settings(max_examples=60, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_wheel_heap_equivalence_property(seed):
        _random_ops_trial(random.Random(seed))
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_wheel_heap_equivalence_property(seed):
        _random_ops_trial(random.Random(seed))


def test_same_timestamp_fires_in_schedule_order():
    """The frozen (time, seq) contract, directly: a same-time burst
    fires in schedule order on both kernels."""
    for loop in (EventLoop(), HeapEventLoop()):
        log = []
        for i in range(50):
            loop.schedule(5.0, log.append, i)
        loop.schedule(0.0, log.append, -1)
        loop.run()
        assert log == [-1] + list(range(50))


def test_cancelled_overflow_and_bucket_entries_are_swept():
    """Cancelled events parked in a future bucket (and in the overflow
    tier) are reclaimed in bulk when the bucket activates, and the
    accounting (live = queued - cancelled) stays exact."""
    loop = EventLoop()
    keep = []
    span_us = WHEEL_SPAN * WHEEL_BUCKET_US
    evs = [loop.schedule(100.0 + (i % 7) * 1e-3, keep.append, i)
           for i in range(64)]
    far = [loop.schedule(2 * span_us + i, keep.append, 1000 + i)
           for i in range(8)]
    for ev in evs[::2] + far[:4]:
        ev.cancel()
    assert _live(loop) == 36
    loop.run()
    assert loop.compactions >= 1
    assert sorted(keep) == sorted([i for i in range(64) if i % 2]
                                  + [1000 + i for i in range(4, 8)])
    assert loop.idle and loop._n_cancelled == 0


@pytest.mark.parametrize("cls", [EventLoop, HeapEventLoop])
def test_run_max_events_budget_is_per_call(cls):
    """Satellite regression: ``run(max_events=)`` bounds THIS call, not
    the loop's lifetime — a long first run must not poison a later one;
    a genuine zero-delay livelock still trips it."""
    loop = cls()
    for i in range(500):
        loop.schedule(float(i), lambda: None)
    loop.run(until=300.0)                 # fires 301 events
    loop.run(max_events=250)              # 199 left: must NOT trip
    assert loop.events_processed == 500

    def livelock():
        loop.schedule(0.0, livelock)

    loop.schedule(0.0, livelock)
    with pytest.raises(RuntimeError, match="event budget"):
        loop.run(max_events=100)


def test_make_event_loop_env_dispatch():
    code = ("from repro.core.simulator import make_event_loop;"
            "print(type(make_event_loop()).__name__)")
    env = dict(os.environ)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.stdout.strip() == "EventLoop"
    env["REPRO_EVENT_LOOP"] = "heap"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.stdout.strip() == "HeapEventLoop"
    env["REPRO_EVENT_LOOP"] = "bogus"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert out.returncode != 0 and "REPRO_EVENT_LOOP" in out.stderr
