"""Tests for the topology-aware interconnect (``repro.net``): topologies,
deterministic routing, per-link contention/QoS, the control-packet
distance-accounting bugfixes, context-bank collision errors, and the
topology soak invariants (determinism, per-link packet conservation).
"""

import pytest

from repro.api import (BufferPrep, Fabric, FabricConfig, FabricError,
                       ServiceClass, TopologyError, TopologyKind)
from repro.core import addresses as A
from repro.core.costmodel import DEFAULT_COST_MODEL
from repro.core.simulator import EventLoop
from repro.net import (Interconnect, Link, Router, build_topology)
from repro.testing import (TenantSpec, check_link_conservation,
                           check_route_sanity, soak)

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
HOP = DEFAULT_COST_MODEL.hop_latency_us


def build(n_nodes=2, **kw):
    return Fabric.build(FabricConfig(n_nodes=n_nodes, **kw))


def write_rtt(fab, nbytes=16, dst_prep=BufferPrep.TOUCHED,
              src_node=0, dst_node=1):
    dom = fab.domain(1) or fab.open_domain(1)
    i = getattr(fab, "_rtt_calls", 0)
    fab._rtt_calls = i + 1
    src = dom.register_memory(src_node, SRC + i * 0x100000, nbytes,
                              prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(dst_node, DST + i * 0x100000, nbytes,
                              prep=dst_prep)
    cq = fab.create_cq()
    wc = dom.post_write(src, dst, cq=cq).result(deadline_us=1e7)
    return wc


# ---------------------------------------------------------------- topology
class TestTopology:
    def test_all_to_all_adjacency(self):
        t = build_topology("all_to_all", 4)
        assert t.neighbors(2) == (0, 1, 3)

    def test_ring_adjacency(self):
        t = build_topology(TopologyKind.RING, 5)
        assert t.neighbors(0) == (1, 4)
        assert t.neighbors(3) == (2, 4)

    def test_mesh_vs_torus_edges(self):
        mesh = build_topology("mesh_2d", 6, (2, 3))
        torus = build_topology("torus_2d", 6, (2, 3))
        # corner node 0 = (0, 0): mesh has right + down only
        assert mesh.neighbors(0) == (1, 3)
        # torus adds the wraparound column neighbor (0, 2) = node 2
        assert torus.neighbors(0) == (1, 2, 3)

    def test_torus_2x2_quad(self):
        """A 2x2 torus: both axis partners adjacent (wrap collapses onto
        the direct link), the diagonal two hops away."""
        t = build_topology("torus_2d", 4, (2, 2))
        assert t.neighbors(0) == (1, 2)
        assert t.neighbors(3) == (1, 2)
        assert Router(t).route(0, 3) == (0, 1, 3)

    def test_dragonfly_intra_group_complete(self):
        t = build_topology("dragonfly", 6, (3, 2))
        g0 = {0, 1}
        for n in g0:
            assert (g0 - {n}) <= set(t.neighbors(n))

    def test_dragonfly_one_global_link_per_group_pair(self):
        t = build_topology("dragonfly", 8, (4, 2))
        for a in range(4):
            for b in range(4):
                if a != b:
                    gw = t.gateway(a, b)
                    assert t.gateway(b, a) in t.neighbors(gw)

    def test_bad_dims_rejected(self):
        with pytest.raises(TopologyError):
            build_topology("torus_2d", 6, (2, 2))
        with pytest.raises(TopologyError):
            build_topology("ring", 4, (5,))
        with pytest.raises(TopologyError):
            build_topology("nonsense", 4)

    def test_config_rejects_hops_on_routed_topology(self):
        """hops= is the ALL_TO_ALL back-compat alias only."""
        with pytest.raises(ValueError, match="back-compat alias"):
            FabricConfig(n_nodes=4, topology="ring", hops=3)
        FabricConfig(n_nodes=4, topology="ring")           # fine
        FabricConfig(n_nodes=4, hops=3)                    # fine


# ------------------------------------------------------------------ router
class TestRouter:
    @pytest.mark.parametrize("kind,n,dims", [
        ("all_to_all", 5, None), ("ring", 7, None), ("mesh_2d", 6, (2, 3)),
        ("torus_2d", 9, (3, 3)), ("dragonfly", 8, (4, 2)),
    ])
    def test_routes_valid_and_symmetric(self, kind, n, dims):
        fab = build(n, topology=kind, dims=dims)
        assert check_route_sanity(fab) == []

    def test_route_deterministic(self):
        r1 = Router(build_topology("torus_2d", 16, (4, 4)))
        r2 = Router(build_topology("torus_2d", 16, (4, 4)))
        for s in range(16):
            for d in range(16):
                assert r1.route(s, d) == r2.route(s, d)
                assert r1.route(s, d) is r1.route(s, d)    # memoized

    def test_dimension_order_column_first(self):
        r = Router(build_topology("mesh_2d", 9, (3, 3)))
        # 0=(0,0) -> 8=(2,2): columns first, then rows
        assert r.route(0, 8) == (0, 1, 2, 5, 8)

    def test_torus_takes_shorter_wrap(self):
        r = Router(build_topology("torus_2d", 16, (4, 4)))
        # 0=(0,0) -> 3=(0,3): wrapping left is 1 hop, walking right is 3
        assert r.route(0, 3) == (0, 3)
        assert r.route(0, 12) == (0, 12)                  # row wrap too

    def test_ring_shorter_direction(self):
        r = Router(build_topology("ring", 6, None))
        assert r.route(0, 2) == (0, 1, 2)
        assert r.route(0, 4) == (0, 5, 4)
        assert r.route(0, 3) == (0, 1, 2, 3)              # tie -> forward

    def test_loopback_route(self):
        r = Router(build_topology("ring", 4, None))
        assert r.route(2, 2) == (2, 2)


# ----------------------------------------- control-packet distance (bugfix)
class TestControlDistanceAccounting:
    """ISSUE-4 regression: ACK/NACK/RAPF/read-request must charge the full
    routed distance.  The seed charged one ``hop_latency_us`` flat, so a
    clean write's RTT grew only 1 x hop_latency per extra hop (the data
    one-way) instead of 2 x (data + ACK)."""

    def test_clean_write_control_rtt_matches_data_rtt_per_hop(self):
        base = write_rtt(build(hops=1)).latency_us
        for h in (2, 4, 8):
            rtt = write_rtt(build(hops=h)).latency_us
            # data one-way + ACK return, both charged h hops
            assert rtt - base == pytest.approx(2 * (h - 1) * HOP), \
                f"hops={h}: control path not charged per routed hop"

    def test_fault_resolution_charges_every_leg_per_hop(self):
        """One cold 4 KB block: the critical path crosses the wire four
        times — stream (h) + RAPF (h) + retransmit (h) + ACK (h); the
        NACK (also charged h now) overlaps the driver's FIFO drain, so
        the RTT grows by 4 legs per extra hop.  Pre-fix it grew by 2:
        only the data legs were charged per hop."""
        base = write_rtt(build(hops=1), nbytes=4096,
                         dst_prep=BufferPrep.FAULTING)
        assert base.stats.rapf_retransmits == 1
        for h in (2, 8):
            wc = write_rtt(build(hops=h), nbytes=4096,
                           dst_prep=BufferPrep.FAULTING)
            assert wc.stats.rapf_retransmits == 1
            assert wc.latency_us - base.latency_us == pytest.approx(
                4 * (h - 1) * HOP)

    def test_remote_read_request_charged_per_hop(self):
        def read_rtt(h):
            fab = build(hops=h)
            dom = fab.open_domain(1)
            tgt = dom.register_memory(1, SRC, 4096, prep=BufferPrep.TOUCHED)
            loc = dom.register_memory(0, DST, 4096, prep=BufferPrep.TOUCHED)
            cq = fab.create_cq()
            return dom.post_read(tgt, loc, cq=cq).result(
                deadline_us=1e6).latency_us
        base = read_rtt(1)
        # request leg + data leg + ACK leg all charged per routed hop
        assert read_rtt(4) - base == pytest.approx(3 * 3 * HOP)

    def test_routed_topology_charges_path_length(self):
        """On a ring, 0->2 is two physical hops: a clean write's RTT must
        exceed the adjacent 0->1 RTT by one extra hop each way (data +
        ACK) plus the data packet's serialization on the second link
        (store-and-forward per routed hop)."""
        near = write_rtt(build(4, topology="ring"), dst_node=1).latency_us
        far = write_rtt(build(4, topology="ring"), dst_node=2).latency_us
        extra_wire = DEFAULT_COST_MODEL.packet_wire_us(16)
        assert far - near == pytest.approx(2 * HOP + extra_wire)


# ------------------------------------------------- context-bank collisions
class TestContextBankCollision:
    """With ``bank_overcommit=False`` the seed's hard pd % 16 ceiling is
    back: two live domains may never map to one SMMU context bank.  (The
    default, ``bank_overcommit=True``, virtualizes the banks instead —
    covered in test_tenancy.py.)"""

    def test_open_domain_collision_is_fabric_error(self):
        fab = build(bank_overcommit=False)
        fab.open_domain(1)
        with pytest.raises(FabricError, match="context bank"):
            fab.open_domain(1 + A.NUM_CONTEXT_BANKS)

    def test_seventeenth_domain_collides(self):
        """All 16 banks live -> the 17th concurrent domain must raise a
        clear FabricError instead of silently corrupting bank 0's page
        table (the seed's pd % NUM_CONTEXT_BANKS aliasing)."""
        fab = build(bank_overcommit=False)
        for pd in range(A.NUM_CONTEXT_BANKS):
            fab.open_domain(pd)
        with pytest.raises(FabricError, match="context bank"):
            fab.open_domain(A.NUM_CONTEXT_BANKS)      # pd 16 -> bank 0

    def test_node_level_create_domain_guards_too(self):
        """The guard lives in Node.create_domain itself, so direct core
        users (not just Fabric.open_domain) cannot alias a live bank —
        including the reverse direction (low pd onto a high pd's bank)."""
        fab = build(bank_overcommit=False)
        node = fab.nodes[0]
        node.create_domain(3 + A.NUM_CONTEXT_BANKS)
        with pytest.raises(FabricError, match="context bank"):
            node.create_domain(3)
        # the failed create left no partial state behind
        assert 3 not in node.page_tables
        node.create_domain(4)                         # other banks fine

    def test_collision_is_typed_bank_collision(self):
        """ISSUE-7 satellite: the clash raises the typed BankCollision
        subclass, not a bare FabricError."""
        from repro.api import BankCollision
        fab = build(bank_overcommit=False)
        fab.open_domain(1)
        with pytest.raises(BankCollision):
            fab.open_domain(1 + A.NUM_CONTEXT_BANKS)

    def test_overcommit_lifts_the_ceiling(self):
        """Default config: the same pd pair coexists, the second domain
        simply shares the bank pool under LRU stealing."""
        fab = build()
        fab.open_domain(1)
        fab.open_domain(1 + A.NUM_CONTEXT_BANKS)      # no raise
        assert fab.domain(1 + A.NUM_CONTEXT_BANKS) is not None

    def test_fabric_error_is_value_error(self):
        """Back-compat: callers catching ValueError keep working."""
        assert issubclass(FabricError, ValueError)


# ------------------------------------------------------- link-level checks
class TestLinkBehavior:
    def make_link(self, qos=False):
        loop = EventLoop()
        return loop, Link(loop, DEFAULT_COST_MODEL, 0, 1, qos=qos)

    def test_last_user_cleared_when_link_drains(self):
        """ISSUE-4 satellite: a stream that finished long ago must not
        flag a later stream as interleaved.  Pre-fix, ``last_user``
        persisted across idle periods; if anything re-busied the wire
        (e.g. a control booking) the next stream was falsely flagged."""
        loop, link = self.make_link(qos=True)
        end, il = link.stream_page(4096, block_key=111, earliest=0.0)
        assert not il and link.last_user == 111
        # drain the wire, then advance time well past the drain point
        loop.schedule(end + 50.0, lambda: None)
        loop.run()
        # a control booking re-busies the idle wire (and, post-fix,
        # forgets the finished stream)
        link.send_ctrl(8, earliest=loop.now)
        assert link.last_user is None
        # the next stream starts while the ctrl booking still occupies
        # the wire: pre-fix it was flagged interleaved with stream 111
        _, il2 = link.stream_page(4096, block_key=222, earliest=loop.now)
        assert il2 is False
        assert link.stats.interleaves == 0

    def test_live_streams_still_flag_interleave(self):
        loop, link = self.make_link()
        link.stream_page(4096, block_key=1, earliest=0.0)
        _, il = link.stream_page(4096, block_key=2, earliest=0.0)
        assert il is True
        assert link.stats.interleaves == 1

    def test_back_to_back_idle_transfers_no_dedup_break_inflation(self):
        """End-to-end: two faulting transfers separated by idle time must
        not interleave on the wire — no inflated FIFO dedup-break
        pushes, and identical fault footprints for both transfers."""
        fab = build()
        wc1 = write_rtt(fab, nbytes=4096, dst_prep=BufferPrep.FAULTING)
        wc2 = write_rtt(fab, nbytes=4096, dst_prep=BufferPrep.FAULTING)
        link = fab.interconnect.link(0, 1)
        assert link.stats.interleaves == 0
        assert (wc1.stats.fifo_entries_handled
                == wc2.stats.fifo_entries_handled)
        assert (wc1.stats.fifo_entries_skipped
                == wc2.stats.fifo_entries_skipped)

    def test_latency_class_overtakes_bulk_backlog(self):
        loop, link = self.make_link(qos=True)
        # build a BULK backlog
        for _ in range(8):
            link.reserve(10.0, earliest=0.0, latency_class=False)
        assert link.busy_until == pytest.approx(80.0)
        # a LATENCY packet starts NOW, not after the backlog...
        start, end = link.reserve(1.0, earliest=0.0, latency_class=True)
        assert start == pytest.approx(0.0)
        assert link.stats.latency_overtakes == 1
        # ...and the backlog is pushed back by the stolen wire time
        assert link.busy_until == pytest.approx(81.0)

    def test_without_qos_all_classes_share_one_fifo(self):
        loop, link = self.make_link(qos=False)
        link.reserve(10.0, earliest=0.0, latency_class=False)
        start, _ = link.reserve(1.0, earliest=0.0, latency_class=True)
        assert start == pytest.approx(10.0)
        assert link.stats.latency_overtakes == 0


# ------------------------------------------------------- topology invariants
def crossing_tenants(n_requests=6):
    """Two tenants whose routes share links on small routed topologies."""
    return [
        TenantSpec(pd=1, name="serving", service_class=ServiceClass.LATENCY,
                   mode="closed", inflight=2, n_requests=n_requests,
                   size_choices=(4096,), src_node=0, dst_node=1,
                   dst_prep=BufferPrep.TOUCHED),
        TenantSpec(pd=2, name="storm", service_class=ServiceClass.BULK,
                   mode="closed", inflight=4, n_requests=n_requests,
                   size_choices=(65536,), src_node=0, dst_node=2,
                   dst_prep=BufferPrep.FAULTING, fresh_dst=True),
    ]


class TestTopologySoaks:
    @pytest.mark.parametrize("topo,n,dims", [
        ("ring", 4, None),
        ("torus_2d", 8, (2, 4)),
    ])
    def test_same_seed_byte_identical(self, topo, n, dims):
        cfg = dict(n_nodes=n, topology=topo, dims=dims)
        a = soak(7, tenants=crossing_tenants(),
                 config=FabricConfig(**cfg))
        b = soak(7, tenants=crossing_tenants(),
                 config=FabricConfig(**cfg))
        assert a.violations == []
        assert a.json() == b.json()
        assert a.json().encode() == b.json().encode()

    @pytest.mark.parametrize("topo,n,dims", [
        ("ring", 4, None),
        ("torus_2d", 8, (2, 4)),
        ("dragonfly", 6, (3, 2)),
    ])
    def test_per_link_packet_conservation(self, topo, n, dims):
        r = soak(11, tenants=crossing_tenants(),
                 config=FabricConfig(n_nodes=n, topology=topo, dims=dims))
        assert r.violations == []
        assert check_link_conservation(r.fabric) == []
        assert check_route_sanity(r.fabric) == []
        # multi-hop routes genuinely traversed shared links
        net = r.stats["net"]["totals"]
        assert net["data_packets"] > 0 and net["ctrl_packets"] > 0

    def test_net_stats_in_soak_report(self):
        r = soak(3, tenants=crossing_tenants(n_requests=3),
                 config=FabricConfig(n_nodes=4, topology="ring"))
        links = r.stats["net"]["links"]
        assert "0->1" in links
        assert links["0->1"]["data_packets"] > 0

    def test_all_to_all_unchanged_by_default(self):
        """The default config still builds the seed's dedicated-pair
        fabric: no qos, hops honored, loopback present."""
        fab = build(hops=3)
        ic = fab.interconnect
        assert ic.qos is False
        assert ic.topology.kind is TopologyKind.ALL_TO_ALL
        assert ic.link(0, 1).hops == 3
        assert ic.link(0, 0).hops == 1


# ------------------------------------------------------------ interconnect
class TestInterconnect:
    def test_conservation_catches_tampering(self):
        loop = EventLoop()
        ic = Interconnect(loop, DEFAULT_COST_MODEL, "ring", n_nodes=4)
        ic.path(0, 2).stream_page(4096, block_key=1)
        assert ic.conservation_violations() == []
        ic.link(0, 1).stats.data_packets += 1          # tamper
        assert ic.conservation_violations() != []

    def test_loopback_paths(self):
        loop = EventLoop()
        ic = Interconnect(loop, DEFAULT_COST_MODEL, "torus_2d", n_nodes=4,
                          dims=(2, 2))
        p = ic.path(3, 3)
        assert p.route == (3, 3)
        assert p.n_hops == 1

    def test_net_importable_standalone(self):
        """repro.net is the bottom layer: importing it first (in a fresh
        interpreter, before repro.core/repro.api) must not hit the
        core -> engine -> api -> net import cycle."""
        import os
        import subprocess
        import sys
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env = dict(os.environ, PYTHONPATH=src)
        r = subprocess.run(
            [sys.executable, "-c",
             "import repro.net; print(repro.net.TopologyKind.RING.value)"],
            capture_output=True, text=True, env=env)
        assert r.returncode == 0, r.stderr
        assert r.stdout.strip() == "ring"

    def test_link_stats_rejects_non_adjacent_pairs(self):
        fab = build(8, topology="torus_2d", dims=(2, 4))
        assert fab.link_stats(0, 1).data_packets == 0
        with pytest.raises(FabricError, match="neighbours"):
            fab.link_stats(0, 2)
