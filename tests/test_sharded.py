"""Sharded per-node event processing (``FabricConfig(shards=)``).

The sharded executor (:mod:`repro.core.shards`) merges per-shard wheels
in global ``(time, seq)`` order under the conservative-lookahead
contract, so a sharded fabric must be **byte-identical** to the
single-wheel fabric — these tests assert that on all-to-all, torus and
ring tiers, plus the config-validation surface and the lookahead bound
itself.
"""

import pytest

from repro.api import FabricConfig
from repro.core.shards import ShardedEventLoop
from repro.errors import ConfigError
from repro.testing import scale_mix, soak


def _soak_json(seed, n_nodes, shards, **cfg):
    specs = scale_mix(n_nodes, total_blocks=1500 * n_nodes // 8,
                      hot_blocks=256)
    config = FabricConfig(n_nodes=n_nodes, frames_per_node=1 << 14,
                          shards=shards, **cfg)
    return soak(seed, tenants=specs, config=config,
                max_events=50_000_000).json()


@pytest.mark.parametrize("shards", [2, 4, 7])
def test_a2a_byte_identical(shards):
    base = _soak_json(11, 8, 1)
    assert _soak_json(11, 8, shards) == base


def test_torus_byte_identical_and_deterministic():
    base = _soak_json(23, 16, 1, topology="torus_2d", dims=(4, 4))
    sharded = _soak_json(23, 16, 4, topology="torus_2d", dims=(4, 4))
    assert sharded == base
    # same seed, second build: the sharded executor is deterministic
    assert _soak_json(23, 16, 4, topology="torus_2d", dims=(4, 4)) == sharded


def test_ring_byte_identical():
    assert (_soak_json(5, 8, 3, topology="ring")
            == _soak_json(5, 8, 1, topology="ring"))


def test_config_validation():
    with pytest.raises(ConfigError, match="shards must be >= 1"):
        FabricConfig(n_nodes=4, shards=0)
    with pytest.raises(ConfigError, match="exceeds n_nodes"):
        FabricConfig(n_nodes=4, shards=5)
    with pytest.raises(ConfigError, match="race_check"):
        FabricConfig(n_nodes=4, shards=2, race_check=True)
    FabricConfig(n_nodes=4, shards=4)       # boundary: one node per shard


def test_lookahead_and_horizon():
    loop = ShardedEventLoop(2, lookahead_us=0.1)
    assert loop.safe_horizon() is None      # drained
    fired = []
    loop.handle_for(0).schedule(5.0, fired.append, "a")
    loop.handle_for(1).schedule(3.0, fired.append, "b")
    assert loop.peek_time() == 3.0
    assert loop.safe_horizon() == 3.0 + 0.1
    loop.run()
    assert fired == ["b", "a"] and loop.now == 5.0
    assert loop.idle and loop.events_processed == 2
    with pytest.raises(ValueError, match="lookahead_us"):
        ShardedEventLoop(2, lookahead_us=0.0)
    with pytest.raises(ValueError, match="n_shards"):
        ShardedEventLoop(0, lookahead_us=0.1)


def test_global_tie_break_across_shards():
    """Same-time events in different shards fire in schedule order —
    the (time, seq) contract is global, not per shard."""
    loop = ShardedEventLoop(3, lookahead_us=0.1)
    log = []
    for i in range(30):
        loop.handle_for(i).schedule(7.0, log.append, i)
    loop.run()
    assert log == list(range(30))


def test_cross_shard_cancel_and_idle():
    loop = ShardedEventLoop(2, lookahead_us=0.1)
    log = []
    evs = [loop.handle_for(i % 2).schedule(1.0 + i, log.append, i)
           for i in range(6)]
    evs[1].cancel()
    evs[4].cancel()
    assert not loop.idle
    assert loop.run_batch(10) == 4
    assert log == [0, 2, 3, 5]
    assert loop.idle and loop.peek_time() is None
    assert loop.step() is False


def test_handle_routing():
    loop = ShardedEventLoop(4, lookahead_us=0.1)
    assert loop.handle_for(1) is loop.handle_for(5)     # node_id % shards
    assert loop.handle_for(0) is not loop.handle_for(1)
