"""repro.vmem: unified pager, pluggable pools/eviction/prefetch, the
remote (fabric-backed) frame pool, and the legacy-kwarg deprecation."""

import warnings

import numpy as np
import pytest

from repro.api import FaultPolicy, Strategy, WROpcode
from repro.vmem import (ClockEviction, DeviceFramePool, FrameIdPool,
                        HostFramePool, LRUEviction, Pager, PagingStats,
                        PinAwareLRU, RemoteFramePool, StreamPrefetch,
                        TouchAheadPrefetch, coerce_policy, predictor_for)


def _pager(n_frames=4, n_pages=16, page_elems=8, **kw):
    pool = kw.pop("pool", None) or DeviceFramePool(n_frames, page_elems)
    pager = Pager(pool, **kw)
    space = pager.create_space(n_pages, name="t0")
    for v in range(n_pages):
        space.write(v, np.full(page_elems, v, np.float32))
    return pager, space


class TestPagerCore:
    def test_fault_resolve_map_roundtrip(self):
        pager, sp = _pager(policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        out = sp.access([3])
        assert sp.is_resident(3)
        np.testing.assert_array_equal(np.asarray(out[0]), np.full(8, 3.0))
        assert pager.stats.faults == 1
        assert pager.stats.pages_in == 1
        assert pager.stats.simulated_us > 0

    def test_touch_ahead_prefetch_and_hits(self):
        pager, sp = _pager(policy=FaultPolicy(Strategy.TOUCH_AHEAD,
                                              lookahead=4))
        sp.access([0])
        assert sp.resident_pages() == 4
        sp.access([1, 2, 3])
        assert pager.stats.faults == 1
        assert pager.stats.prefetch_hits == 3

    def test_stream_predictor_warms_next_block(self):
        pager, sp = _pager(n_frames=8,
                           policy=FaultPolicy(Strategy.STREAM, lookahead=4))
        sp.access([0])
        # block 0-3 plus the streamed first page of the next block
        assert sp.resident_pages() == 5
        assert sp.is_resident(4)

    def test_host_pool_backend(self):
        pool = HostFramePool(4, 8)
        pager = Pager(pool)
        sp = pager.create_space(8)
        sp.write(5, np.arange(8))
        out = sp.access([5])
        np.testing.assert_array_equal(np.asarray(out[0]), np.arange(8.0))

    def test_writeback_on_eviction(self):
        pager, sp = _pager(n_frames=2,
                           policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        sp.access([0])
        f = int(sp.page_table[0])
        pager.pool.load(f, np.full(8, 99.0))
        sp.access([1])
        sp.access([2])                        # evicts page 0 (LRU)
        assert not sp.is_resident(0)
        np.testing.assert_array_equal(sp.backing[0], np.full(8, 99.0))


class TestEvictionUnderPins:
    def test_pinned_pages_never_evicted(self):
        pager, sp = _pager(n_frames=4,
                           policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        sp.pin([0, 1])
        for v in (2, 3, 4, 5, 6):             # cycle the unpinned frames
            sp.access([v])
        assert sp.is_resident(0) and sp.is_resident(1)
        assert pager.stats.evictions == 3
        assert not sp.pinned[[2, 3, 4, 5, 6]].any()

    def test_all_pinned_raises_with_violation(self):
        pager, sp = _pager(n_frames=2,
                           policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        sp.pin([0, 1])
        with pytest.raises(MemoryError):
            sp.access([2])
        assert pager.stats.pin_violations == 1
        assert sp.stats.pin_violations == 1

    def test_fault_policy_pin_budget(self):
        pol = FaultPolicy(Strategy.TOUCH_A_PAGE,
                          pin_limit_bytes=2 * 4096)
        pager, sp = _pager(n_frames=4, policy=pol)
        sp.pin([0, 1])                        # exactly the budget
        with pytest.raises(MemoryError):
            sp.pin([2])
        assert pager.stats.pin_violations == 1

    def test_clock_eviction_second_chance(self):
        pager, sp = _pager(n_frames=2, eviction=ClockEviction(),
                           policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        sp.access([0])
        sp.access([1])
        sp.access([0])                        # re-reference page 0
        sp.access([2])                        # clock skips hot 0 eventually
        assert sp.resident_pages() == 2
        assert pager.stats.evictions == 1


class TestMultiTenantSharedPool:
    def test_two_spaces_one_pool_contention(self):
        pool = DeviceFramePool(8, 4)
        pager = Pager(pool, policy=FaultPolicy(Strategy.TOUCH_A_PAGE),
                      eviction=PinAwareLRU())
        a = pager.create_space(16, name="a")
        b = pager.create_space(16, name="b")
        for v in range(8):                    # tenant A hogs the pool
            a.access([v])
        assert a.resident_pages() == 8
        for v in range(2):                    # B faults: fairness — A pays
            b.access([v])
        assert b.resident_pages() == 2
        assert a.resident_pages() == 6
        assert pager.stats.spills == 2        # cross-tenant evictions
        assert b.stats.spills == 2            # charged to the requester
        assert a.stats.pages_out == 2         # paid by the hog
        assert pager.stats.evictions == 2

    def test_pinning_tenant_cannot_be_robbed(self):
        pool = DeviceFramePool(4, 4)
        pager = Pager(pool, policy=FaultPolicy(Strategy.TOUCH_A_PAGE),
                      eviction=PinAwareLRU())
        a = pager.create_space(8, name="a")
        b = pager.create_space(8, name="b")
        a.pin([0, 1, 2])
        b.access([0])
        b.access([1])                         # must evict b's own page
        assert a.resident_pages() == 3
        assert b.resident_pages() == 1
        pager.pin(b, [1])                     # now everything is pinned
        with pytest.raises(MemoryError):
            b.access([2])
        assert pager.stats.pin_violations == 1

    def test_per_space_policy_override(self):
        pool = DeviceFramePool(8, 4)
        pager = Pager(pool, policy=FaultPolicy(Strategy.TOUCH_AHEAD,
                                               lookahead=4))
        a = pager.create_space(16, name="a")
        b = pager.create_space(16, name="b",
                               policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        a.access([0])
        b.access([0])
        assert a.resident_pages() == 4        # block fault
        assert b.resident_pages() == 1        # single-page fault

    def test_shared_pool_across_separate_pagers(self):
        # consumers that share a pool via pool= get separate Pagers; the
        # pool-wide space registry still lets them contend for frames
        from repro.memory.paged_store import PagedTensorStore
        pool = DeviceFramePool(4, 16)
        a = PagedTensorStore(16, 4, 8, pool=pool,
                             policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        b = PagedTensorStore(16, 4, 8, pool=pool,
                             policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        b.access([0, 1, 2, 3])                # b fills the shared pool
        a.access([0])                         # must evict one of b's pages
        assert a.resident_pages() == 1
        assert b.resident_pages() == 3
        assert a.stats.spills == 1

    def test_injected_pager_policy_governs(self):
        from repro.memory.paged_store import PagedTensorStore
        pager = Pager(DeviceFramePool(8, 16),
                      policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        st = PagedTensorStore(16, 8, 8, pager=pager)
        st.access([0])
        assert st.resident_pages() == 1       # TOUCH_A_PAGE, not the
        assert st.strategy is Strategy.TOUCH_A_PAGE   # coerced default

    def test_frame_id_pool_is_control_plane_only(self):
        pager = Pager(FrameIdPool(4))
        sp = pager.create_space(8)
        assert sp.backing is None
        pager.map_fresh(sp, 0)
        assert sp.is_resident(0)
        with pytest.raises(NotImplementedError):
            sp.access([0])


class TestRemoteFramePool:
    def _remote_pager(self, policy=None, n_frames=4, n_pages=16):
        pool = RemoteFramePool.build(n_frames=n_frames, page_elems=8,
                                     n_pages=n_pages, policy=policy)
        pager = Pager(pool, policy=policy or FaultPolicy(
            Strategy.TOUCH_AHEAD, lookahead=4))
        sp = pager.create_space(n_pages, name="remote-tenant")
        for v in range(n_pages):
            sp.write(v, np.full(8, v, np.float32))
        return pool, pager, sp

    def test_page_ins_complete_on_cq(self):
        pool, pager, sp = self._remote_pager()
        sp.access([0])                        # one block fault -> one read
        wcs = pool.cq.poll(max_entries=16)
        assert len(wcs) == 1
        assert wcs[0].opcode is WROpcode.READ
        assert wcs[0].nbytes == 4 * 4096      # the whole touched-ahead run
        assert pager.stats.remote_reads == 1
        assert pager.stats.remote_bytes_in == 4 * 4096

    def test_rapf_stats_surface_in_paging_stats(self):
        pool, pager, sp = self._remote_pager()
        sp.access([0])
        # the local landing region is FAULTING: cold page-ins take
        # destination faults whose RAPF retransmits are surfaced
        assert pager.stats.remote_dst_faults > 0
        assert pager.stats.rapf_retransmits > 0
        assert sp.stats.rapf_retransmits > 0
        assert pager.stats.simulated_us > 0

    def test_data_plane_still_real(self):
        pool, pager, sp = self._remote_pager(
            policy=FaultPolicy(Strategy.TOUCH_A_PAGE))
        out = sp.access([7])
        np.testing.assert_array_equal(np.asarray(out[0]), np.full(8, 7.0))
        assert pager.stats.remote_reads == 1

    def test_refault_after_eviction_posts_again(self):
        pool, pager, sp = self._remote_pager(
            policy=FaultPolicy(Strategy.TOUCH_A_PAGE), n_frames=2)
        sp.access([0])
        sp.access([1])
        sp.access([2])                        # evicts 0
        sp.access([0])                        # pages 0 back in remotely
        assert pager.stats.remote_reads == 4
        drained = pool.cq.poll(max_entries=16)
        assert len(drained) + len(pool.completions) == 4


class TestUnifiedStats:
    def test_reset_zeroes_everything(self):
        s = PagingStats()
        s.faults = 7
        s.simulated_us = 3.5
        s.rapf_retransmits = 2
        s.reset()
        assert s == PagingStats()

    def test_legacy_aliases(self):
        s = PagingStats(faults=3, pages_in=9)
        assert s.fault_events == 3
        assert s.fault_page_ins == 9

    def test_merge(self):
        a = PagingStats(faults=1, simulated_us=2.0)
        b = PagingStats(faults=2, simulated_us=0.5)
        a.merge(b)
        assert a.faults == 3
        assert a.simulated_us == 2.5


class TestPolicyCompat:
    def test_legacy_kwargs_warn_once_place(self):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            pol = coerce_policy("X", None, Strategy.TOUCH_A_PAGE, 2)
        assert pol.strategy is Strategy.TOUCH_A_PAGE
        assert pol.lookahead == 2

    def test_policy_wins_and_both_is_an_error(self):
        pol = FaultPolicy(Strategy.STREAM)
        assert coerce_policy("X", pol) is pol
        with pytest.raises(TypeError):
            coerce_policy("X", pol, Strategy.TOUCH_A_PAGE)

    def test_no_kwargs_no_warning(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            pol = coerce_policy("X", None)
        assert pol.strategy is Strategy.TOUCH_AHEAD

    def test_predictors_match_policies(self):
        assert isinstance(
            predictor_for(FaultPolicy(Strategy.STREAM)), StreamPrefetch)
        assert isinstance(
            predictor_for(FaultPolicy(Strategy.KERNEL_RAPF)),
            TouchAheadPrefetch)
