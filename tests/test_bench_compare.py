"""The BENCH regression gate (``benchmarks/run.py --compare``)."""

import json

import pytest

from benchmarks.run import REGRESSION_PCT, compare


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def test_compare_flags_only_deep_events_per_sec_drops(tmp_path, capsys):
    old = {"scale/64n_torus_events_per_sec": 100.0,
           "scale/128n_dragonfly_events_per_sec": 100.0,
           "scale/64n_torus_wall_s": 10.0,
           "fig4.1/ideal/16B": 4.0}
    new = {"scale/64n_torus_events_per_sec": 79.0,     # -21%: regression
           "scale/128n_dragonfly_events_per_sec": 81.0,  # -19%: within band
           "scale/64n_torus_wall_s": 99.0,    # not a throughput key: free
           "fig4.1/ideal/16B": 400.0}         # ditto
    n = compare(_write(tmp_path, "old.json", old),
                _write(tmp_path, "new.json", new))
    assert n == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out
    assert f"{REGRESSION_PCT:.0f}%" in out


def test_compare_handles_added_and_removed_keys(tmp_path, capsys):
    old = {"scale/64n_torus_events_per_sec": 100.0,
           "tenancy/retired_key": 5.0}
    new = {"scale/64n_torus_events_per_sec": 120.0,
           "scale/1024n_torus_events_per_sec": 50.0}
    n = compare(_write(tmp_path, "old.json", old),
                _write(tmp_path, "new.json", new))
    assert n == 0      # a brand-new slow tier must not trip the gate
    out = capsys.readouterr().out
    assert "ADDED" in out and "REMOVED" in out
    assert "no throughput regressions" in out


def test_compare_identical_files_is_clean(tmp_path):
    payload = {"scale/64n_torus_events_per_sec": 100.0}
    p = _write(tmp_path, "same.json", payload)
    assert compare(p, p) == 0


@pytest.mark.parametrize("drop,expected", [(19.9, 0), (20.1, 1)])
def test_compare_threshold_boundary(tmp_path, drop, expected):
    old = {"x_events_per_sec": 1000.0}
    new = {"x_events_per_sec": 1000.0 * (1 - drop / 100.0)}
    assert compare(_write(tmp_path, "o.json", old),
                   _write(tmp_path, "n.json", new)) == expected
