"""CompletionQueue edge cases: backpressure at depth, partial drains,
deadline expiry against a permanently paused transfer, overflow counters."""

import pytest

from repro.api import (BufferPrep, Fabric, FabricConfig, FaultPolicy,
                       Strategy, WorkQueueFull, WROpcode)

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
UNMAPPED_DST = 0x7F_0000_0000     # never mmap'd: faults can never resolve


def make_fabric(**over):
    return Fabric.build(FabricConfig(n_nodes=1, **over))


def touched_pair(dom, i, size=4096):
    src = dom.register_memory(0, SRC + i * (1 << 20), size,
                              prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(0, DST + i * (1 << 20), size,
                              prep=BufferPrep.TOUCHED)
    return src, dst


class TestBackpressureAtDepth:
    def test_posts_beyond_depth_raise_and_count(self):
        fab = make_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=3)      # max_outstanding defaults to depth
        for i in range(3):
            src, dst = touched_pair(dom, i)
            dom.post_write(src, dst, cq=cq)
        src, dst = touched_pair(dom, 3)
        with pytest.raises(WorkQueueFull):
            dom.post_write(src, dst, cq=cq)
        with pytest.raises(WorkQueueFull):
            dom.post_write(src, dst, cq=cq)
        assert cq.stats.rejected_posts == 2
        assert cq.stats.posted == 3
        # draining frees outstanding slots; posting works again
        assert len(cq.wait(3)) == 3
        dom.post_write(src, dst, cq=cq).result()

    def test_max_outstanding_cannot_exceed_depth(self):
        fab = make_fabric()
        with pytest.raises(ValueError):
            fab.create_cq(depth=4, max_outstanding=8)

    def test_queued_completions_hold_their_slots(self):
        """Completions occupy CQ slots until drained: len(cq) can never
        exceed max_outstanding, and posting stays blocked until poll."""
        fab = make_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=2)
        for i in range(2):
            src, dst = touched_pair(dom, i)
            dom.post_write(src, dst, cq=cq)
        fab.progress()                    # both completions queued, undrained
        assert len(cq) == 2 == cq.stats.max_queued
        src, dst = touched_pair(dom, 2)
        with pytest.raises(WorkQueueFull):
            dom.post_write(src, dst, cq=cq)


class TestPartialDrain:
    def test_poll_max_entries_partial(self):
        fab = make_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=8)
        for i in range(6):
            src, dst = touched_pair(dom, i)
            dom.post_write(src, dst, cq=cq)
        fab.progress()
        first = cq.poll(max_entries=4)
        assert len(first) == 4
        assert cq.outstanding == 2        # 4 drained slots freed
        second = cq.poll(max_entries=4)
        assert len(second) == 2
        assert cq.poll(max_entries=4) == []
        assert cq.stats.empty_polls == 1
        assert cq.stats.polls == 3
        wr_ids = [wc.wr_id for wc in first + second]
        assert len(set(wr_ids)) == 6      # no duplicates across drains

    def test_wait_returns_at_most_n(self):
        fab = make_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq(depth=8)
        for i in range(5):
            src, dst = touched_pair(dom, i)
            dom.post_write(src, dst, cq=cq)
        got = cq.wait(2)
        assert len(got) == 2
        assert len(cq.wait(3)) == 3


class TestDeadlineExpiry:
    def _permanently_paused(self, fab, cq):
        """A write whose destination VA is never mmap'd: every round
        NACKs, the resolver's touch SEGFAULTs (recovered), the block
        pauses and retries forever — the transfer can never complete."""
        dom = fab.domains[1]
        src = dom.register_memory(0, SRC, 4096, prep=BufferPrep.TOUCHED)
        cq.on_post()
        t = fab._start_write(1, 0, SRC, 0, UNMAPPED_DST, 4096)
        return fab._track(fab._next_wr_id(), WROpcode.WRITE, cq, t)

    def test_wait_deadline_expires_and_counts(self):
        fab = make_fabric(default_policy=FaultPolicy(
            strategy=Strategy.TOUCH_A_PAGE))
        fab.open_domain(1)
        cq = fab.create_cq()
        wr = self._permanently_paused(fab, cq)
        got = cq.wait(1, deadline_us=25_000.0)
        assert got == []
        assert cq.stats.deadline_expiries == 1
        assert not wr.done
        assert fab.now <= 25_100.0        # the clock stopped at the deadline
        assert wr.stats.segfaults_recovered > 0
        assert wr.stats.timeouts > 0      # it kept retrying, never completed

    def test_result_times_out_on_paused_transfer(self):
        fab = make_fabric(default_policy=FaultPolicy(
            strategy=Strategy.TOUCH_A_PAGE))
        fab.open_domain(1)
        cq = fab.create_cq()
        wr = self._permanently_paused(fab, cq)
        with pytest.raises(TimeoutError):
            wr.result(deadline_us=25_000.0)

    def test_wait_success_does_not_count_expiry(self):
        fab = make_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = touched_pair(dom, 0)
        dom.post_write(src, dst, cq=cq)
        assert len(cq.wait(1)) == 1
        assert cq.stats.deadline_expiries == 0

    def test_drained_loop_is_not_a_deadline_expiry(self):
        """Waiting for more completions than were ever posted drains the
        loop long before the deadline: that is not an expiry."""
        fab = make_fabric()
        dom = fab.open_domain(1)
        cq = fab.create_cq()
        src, dst = touched_pair(dom, 0)
        dom.post_write(src, dst, cq=cq)
        got = cq.wait(4, deadline_us=1e9)     # only 1 WR exists
        assert len(got) == 1
        assert cq.stats.deadline_expiries == 0
