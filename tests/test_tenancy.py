"""Tenancy control plane (ISSUE-7): SMMU context-bank virtualization
(BankManager overcommit + LRU stealing), domain lifecycle
(``Fabric.close_domain``), SRQ/QP multiplexing with quota backpressure,
SLO classes, admission control, and the typed error taxonomy
(``DomainExists`` / ``BankCollision`` / ``DomainClosed`` /
``TenantQuotaExceeded``).
"""

import pytest

from repro.api import (BankCollision, BankManager, BufferPrep, DomainClosed,
                       DomainExists, Fabric, FabricConfig, FabricError,
                       FaultPolicy, SLOClass, ServiceClass, Strategy,
                       TenantQuotaExceeded, WorkQueueFull, coerce_slo)
from repro.core import addresses as A
from repro.tenancy.banks import NoBankAvailable
from repro.tenancy.qp import QPMux, SRQ
from repro.testing import check_bank_conservation, check_tenant_isolation

SRC = 0x10_0000_0000
DST = 0x20_0000_0000
CAP = A.NUM_CONTEXT_BANKS


def build(n_nodes=2, **kw):
    return Fabric.build(FabricConfig(n_nodes=n_nodes, **kw))


def write(fab, dom, nbytes=16384, dst_prep=BufferPrep.FAULTING,
          src_node=0, dst_node=1):
    """One completed write on ``dom``; regions strided per call."""
    i = getattr(fab, "_w", 0)
    fab._w = i + 1
    src = dom.register_memory(src_node, SRC + i * 0x100000, nbytes,
                              prep=BufferPrep.TOUCHED)
    dst = dom.register_memory(dst_node, DST + i * 0x100000, nbytes,
                              prep=dst_prep)
    cq = fab.create_cq()
    return dom.post_write(src, dst, cq=cq).result(deadline_us=1e7)


# ===================================================== BankManager units
class TestBankManager:
    def test_register_duplicate_rejected(self):
        mgr = BankManager()
        mgr.register(5)
        with pytest.raises(ValueError, match="already registered"):
            mgr.register(5)

    def test_eager_bind_prefers_seed_bank(self):
        """pds that fit the 16 banks bind exactly like the seed's
        pd % 16 — byte-identical timing for legacy workloads."""
        mgr = BankManager()
        for pd in (3, 19, 7):        # 19 % 16 == 3 is taken -> lowest free
            mgr.register(pd)
        assert mgr.try_bind(3) == 3
        assert mgr.try_bind(19) == 0          # fallback: lowest free bank
        assert mgr.try_bind(7) == 7
        assert mgr.stats.binds == 3 and mgr.stats.steals == 0

    def test_try_bind_never_steals(self):
        mgr = BankManager()
        for pd in range(CAP + 1):
            mgr.register(pd)
        assert all(mgr.try_bind(pd) is not None for pd in range(CAP))
        assert mgr.try_bind(CAP) is None      # full: defer, don't steal
        assert mgr.stats.steals == 0
        assert mgr.bound_count() == CAP

    def test_bind_hit_touches_lru(self):
        mgr = BankManager()
        mgr.register(1)
        b1 = mgr.bind(1)
        b2 = mgr.bind(1)
        assert not b1.hit and b2.hit and b2.bank == b1.bank
        assert mgr.stats.hits == 1

    def test_steal_evicts_lru(self):
        mgr = BankManager()
        for pd in range(CAP + 1):
            mgr.register(pd)
        for pd in range(CAP):
            mgr.bind(pd)
        mgr.bind(0)                            # refresh pd 0: pd 1 is LRU now
        b = mgr.bind(CAP)
        assert b.stolen and b.victim_pd == 1 and b.bank == 1
        assert mgr.bank_of(1) is None
        assert mgr.stats.steals == 1
        # the victim re-binding later counts as a rebind
        rb = mgr.bind(1)
        assert rb.stolen and mgr.stats.rebinds == 1

    def test_immune_victims_stolen_last(self):
        mgr = BankManager(capacity=2)
        mgr.register(0, steal_immune=True)     # GOLD, bound first = LRU
        mgr.register(1)
        mgr.register(2)
        mgr.bind(0)
        mgr.bind(1)
        b = mgr.bind(2)
        # LRU is pd 0, but it is immune: pd 1 is evicted instead
        assert b.stolen and b.victim_pd == 1
        assert mgr.stats.immune_steals == 0

    def test_all_immune_still_makes_progress(self):
        mgr = BankManager(capacity=1)
        mgr.register(0, steal_immune=True)
        mgr.register(1)
        mgr.bind(0)
        b = mgr.bind(1)
        assert b.stolen and b.victim_pd == 0
        assert mgr.stats.immune_steals == 1

    def test_fault_active_banks_stolen_last(self):
        mgr = BankManager(capacity=2)
        for pd in range(3):
            mgr.register(pd)
        mgr.bind(0)
        mgr.bind(1)
        # bank 0 (pd 0, the LRU) is mid-fault: pd 1 is evicted instead
        b = mgr.bind(2, fault_active=lambda bank: bank == 0)
        assert b.stolen and b.victim_pd == 1

    def test_nothing_bound_raises(self):
        mgr = BankManager(capacity=0)
        mgr.register(1)
        with pytest.raises(NoBankAvailable):
            mgr.bind(1)

    def test_release_frees_bank(self):
        mgr = BankManager()
        mgr.register(4)
        mgr.bind(4)
        assert mgr.release(4) == 4
        assert mgr.pd_for_bank(4) is None and not mgr.registered(4)
        assert mgr.release(4) is None          # idempotent

    def test_bindings_bijection(self):
        mgr = BankManager()
        for pd in range(40):
            mgr.register(pd)
            mgr.bind(pd)
        snap = mgr.bindings()
        assert len(snap) == CAP
        assert len(set(snap.values())) == CAP  # no pd holds two banks


# ============================================================= SLO units
class TestSLO:
    def test_coerce_accepts_member_name_value(self):
        assert coerce_slo(SLOClass.GOLD) is SLOClass.GOLD
        assert coerce_slo("GOLD") is SLOClass.GOLD
        assert coerce_slo("gold") is SLOClass.GOLD
        assert coerce_slo(None) is None
        with pytest.raises(ValueError, match="GOLD"):
            coerce_slo("platinum")

    def test_tier_derivations(self):
        assert SLOClass.GOLD.service_class is ServiceClass.LATENCY
        assert SLOClass.SILVER.service_class is ServiceClass.BULK
        assert (SLOClass.GOLD.arb_weight, SLOClass.SILVER.arb_weight,
                SLOClass.BEST_EFFORT.arb_weight) == (4, 2, 1)
        assert SLOClass.GOLD.steal_immune
        assert not SLOClass.SILVER.steal_immune

    def test_policy_slo_derives_arbiter_params(self):
        p = FaultPolicy(slo="gold")
        assert p.service_class is ServiceClass.LATENCY
        assert p.arb_weight == 4
        # explicit values beat the derivation
        q = FaultPolicy(slo=SLOClass.GOLD, service_class=ServiceClass.BULK,
                        arb_weight=7)
        assert q.service_class is ServiceClass.BULK and q.arb_weight == 7

    def test_open_domain_slo_makes_bank_immune(self):
        fab = build()
        fab.open_domain(1, slo="gold")
        fab.open_domain(2)
        assert fab.nodes[0].tenancy.banks.is_immune(1)
        assert not fab.nodes[0].tenancy.banks.is_immune(2)
        assert fab.domain(1).slo is SLOClass.GOLD


# ======================================================== SRQ/QPMux units
class TestSRQ:
    def test_unbounded_always_admits(self):
        srq = SRQ()
        assert srq.try_acquire(10 ** 6)
        assert srq.stats.admitted == 10 ** 6

    def test_backpressure_and_release(self):
        srq = SRQ(entries=4)
        assert srq.try_acquire(3)
        assert not srq.try_acquire(2)          # 3 + 2 > 4
        assert srq.stats.rejected == 1
        srq.release(3)
        assert srq.try_acquire(4)
        assert srq.stats.peak_held == 4

    def test_gold_reserve(self):
        srq = SRQ(entries=4, gold_reserve=2)
        assert srq.try_acquire(2)              # best-effort limit: 4 - 2
        assert not srq.try_acquire(1)
        assert srq.try_acquire(2, gold=True)   # GOLD reaches the full 4

    def test_qpmux_shares_physical_qps(self):
        mux = QPMux(phys_qps=4)
        for pd in range(9):
            mux.attach(pd)
        assert mux.virtual_qps == 9
        assert mux.qp_of(5) == 1
        assert mux.max_share == 3              # ceil(9 / 4)
        mux.detach(5)
        assert mux.qp_of(5) is None and mux.virtual_qps == 8


# ==================================================== typed error taxonomy
class TestTypedErrors:
    def test_domain_exists_is_typed(self):
        fab = build()
        fab.open_domain(1)
        with pytest.raises(DomainExists):
            fab.open_domain(1)
        assert issubclass(DomainExists, FabricError)
        assert issubclass(DomainExists, ValueError)   # back-compat

    def test_bank_collision_only_without_overcommit(self):
        strict = build(bank_overcommit=False)
        strict.open_domain(1)
        with pytest.raises(BankCollision):
            strict.open_domain(1 + CAP)
        loose = build()                         # default: virtualized banks
        loose.open_domain(1)
        loose.open_domain(1 + CAP)              # no raise
        assert issubclass(BankCollision, FabricError)

    def test_domain_closed_on_post_and_register(self):
        fab = build()
        dom = fab.open_domain(1)
        src = dom.register_memory(0, SRC, 16384, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 16384, prep=BufferPrep.TOUCHED)
        fab.close_domain(1)
        cq = fab.create_cq()
        with pytest.raises(DomainClosed):
            dom.post_write(src, dst, cq=cq)
        with pytest.raises(DomainClosed):
            dom.register_memory(0, SRC + 0x100000, 4096)


# ======================================================= domain lifecycle
class TestCloseDomain:
    def test_close_releases_frames_bank_and_pd(self):
        fab = build()
        dom = fab.open_domain(1)
        write(fab, dom)
        n0, n1 = fab.nodes
        assert any(o[0] == 1 for o in n1.allocator.owner.values())
        fab.close_domain(1)
        for node in (n0, n1):
            assert 1 not in node.page_tables
            assert node.tenancy.banks.bank_of(1) is None
            assert not any(o[0] == 1 for o in node.allocator.owner.values())
        # the pd is immediately reusable
        dom2 = fab.open_domain(1)
        assert write(fab, dom2).latency_us > 0

    def test_close_marks_regions_deregistered(self):
        fab = build()
        dom = fab.open_domain(1)
        mr = dom.register_memory(0, SRC, 16384, prep=BufferPrep.TOUCHED)
        fab.close_domain(1)
        assert mr.registered is False

    def test_close_unknown_pd_raises(self):
        fab = build()
        with pytest.raises(FabricError, match="not open"):
            fab.close_domain(9)

    def test_close_drains_in_flight_work(self):
        fab = build()
        dom = fab.open_domain(1)
        src = dom.register_memory(0, SRC, 65536, prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 65536, prep=BufferPrep.FAULTING)
        cq = fab.create_cq()
        wr = dom.post_write(src, dst, cq=cq)    # in flight, faulting
        fab.close_domain(1)                     # must drain, not strand
        assert fab.nodes[1].arbiter.outstanding(1) == 0
        # the completion was delivered, not lost with the domain
        wc = wr.result(deadline_us=1e7)
        assert wc.latency_us > 0 and wc.stats.dst_faults > 0


# =========================================== admission + SRQ backpressure
class TestAdmission:
    def test_tenants_per_node_cap(self):
        fab = build(tenants_per_node=2)
        fab.open_domain(1)
        fab.open_domain(2)
        with pytest.raises(TenantQuotaExceeded):
            fab.open_domain(3)
        # rejected atomically: no half-open node state anywhere
        assert all(3 not in n.page_tables for n in fab.nodes)
        fab.close_domain(2)
        fab.open_domain(3)                      # slot freed by close

    def test_gold_cap_keeps_one_bank_stealable(self):
        fab = build()
        for pd in range(CAP - 1):
            fab.open_domain(pd, slo="gold")
        with pytest.raises(TenantQuotaExceeded, match="GOLD"):
            fab.open_domain(CAP - 1, slo="gold")
        fab.open_domain(CAP - 1, slo="silver")  # non-GOLD still admitted

    def test_srq_backpressure_raises_typed_error(self):
        fab = build(srq_entries=2)
        dom = fab.open_domain(1)
        src = dom.register_memory(0, SRC, 64 * 1024,
                                  prep=BufferPrep.TOUCHED)
        dst = dom.register_memory(1, DST, 64 * 1024,
                                  prep=BufferPrep.TOUCHED)
        cq = fab.create_cq()
        with pytest.raises(TenantQuotaExceeded) as ei:
            dom.post_write(src, dst, cq=cq)     # 4 blocks > 2 entries
        assert isinstance(ei.value, WorkQueueFull)   # catchable as before
        assert fab.nodes[1].tenancy.srq.stats.rejected == 1
        assert fab.nodes[1].tenancy.srq.held == 0    # nothing leaked

    def test_srq_entries_released_on_completion(self):
        fab = build(srq_entries=4)
        dom = fab.open_domain(1)
        write(fab, dom, nbytes=4 * A.BLOCK_SIZE,
              dst_prep=BufferPrep.TOUCHED)
        srq = fab.nodes[1].tenancy.srq
        assert srq.stats.admitted == 4
        assert srq.stats.released == 4 and srq.held == 0
        # and the fabric can keep posting forever at this size
        write(fab, dom, nbytes=4 * A.BLOCK_SIZE,
              dst_prep=BufferPrep.TOUCHED)
        assert srq.held == 0

    def test_gold_reserve_admits_gold_only(self):
        fab = build(srq_entries=4, srq_gold_reserve=2)
        be = fab.open_domain(1, slo="best_effort")
        gold = fab.open_domain(2, slo="gold")
        cq = fab.create_cq()
        mk = lambda dom, off: (
            dom.register_memory(0, SRC + off, 3 * A.BLOCK_SIZE,
                                prep=BufferPrep.TOUCHED),
            dom.register_memory(1, DST + off, 3 * A.BLOCK_SIZE,
                                prep=BufferPrep.TOUCHED))
        s1, d1 = mk(be, 0)
        with pytest.raises(TenantQuotaExceeded):
            be.post_write(s1, d1, cq=cq)        # 3 > 4 - 2 reserved
        s2, d2 = mk(gold, 0x100000)
        gold.post_write(s2, d2, cq=cq).result(deadline_us=1e7)


# ==================== steal -> shootdown -> rebind -> refault (satellite)
class TestStealDatapath:
    def test_tlb_invalidate_all_counts_per_entry(self):
        """Satellite: SMMU.tlb_invalidate_all(bank) telemetry — one
        invalidation counted per cached walk, none for other banks."""
        fab = build()
        dom = fab.open_domain(1)
        write(fab, dom, nbytes=4 * 4096, dst_prep=BufferPrep.TOUCHED)
        smmu = fab.nodes[0].smmu
        bank = fab.nodes[0].tenancy.banks.bank_of(1)
        cached = sum(1 for k in smmu._tlb if k >> 32 == bank)
        assert cached > 0
        before = smmu.stats.tlb_invalidations
        smmu.tlb_invalidate_all(bank)
        assert smmu.stats.tlb_invalidations == before + cached
        assert not any(k >> 32 == bank for k in smmu._tlb)
        smmu.tlb_invalidate_all(bank)           # empty bank: no-op
        assert smmu.stats.tlb_invalidations == before + cached

    def test_steal_shootdown_rebind_refault(self):
        """17 live domains on one node: the 17th transfer steals a bank
        (LRU), shoots down its TLB, rebinds, and the victim re-faults
        cleanly on its next use — with every step visible in the
        counters and the cost model's penalty in the latency."""
        fab = build()
        doms = [fab.open_domain(pd) for pd in range(CAP + 1)]
        # bind the first 16 eagerly (create_domain) and give each a
        # little TLB state on node 0
        for dom in doms[:-1]:
            write(fab, dom, nbytes=4096, dst_prep=BufferPrep.TOUCHED)
        mgr = fab.nodes[0].tenancy.banks
        assert mgr.bound_count() == CAP and mgr.stats.steals == 0
        # the 17th domain's first transfer must steal on node 0
        write(fab, doms[-1], nbytes=4096, dst_prep=BufferPrep.TOUCHED)
        assert mgr.stats.steals >= 1
        assert mgr.stats.shootdowns == mgr.stats.steals
        victim_pd = next(pd for pd in range(CAP)
                         if mgr.bank_of(pd) is None)
        # the victim's next transfer re-binds (stealing back) and works
        wc = write(fab, doms[victim_pd], nbytes=4096,
                   dst_prep=BufferPrep.TOUCHED)
        assert wc.latency_us > 0
        assert mgr.stats.rebinds >= 1
        assert mgr.bank_of(victim_pd) is not None
        assert check_bank_conservation(fab) == []
        assert check_tenant_isolation(fab) == []

    def test_steal_penalty_in_latency(self):
        """The shootdown + rebind microseconds show up in the stolen
        domain's transfer latency, not just in CPU accounting."""
        fab = build()
        base_dom = fab.open_domain(0)
        base = write(fab, base_dom, nbytes=4096,
                     dst_prep=BufferPrep.TOUCHED).latency_us
        for pd in range(1, CAP + 1):
            fab.open_domain(pd)
        # pd CAP was never bound on node 0 or 1: its first transfer
        # pays a steal on both the source and destination node
        stolen = write(fab, fab.domain(CAP), nbytes=4096,
                       dst_prep=BufferPrep.TOUCHED).latency_us
        cost = fab.config.cost
        penalty = cost.bank_rebind_us + cost.bank_shootdown_us
        assert stolen >= base + penalty

    def test_steal_invalidates_npr_mtt(self):
        """An NP-RDMA domain losing its bank must lose its cached NIC
        translations too — zero stale completions afterwards."""
        fab = build()
        npr_dom = fab.open_domain(
            0, policy=FaultPolicy(strategy=Strategy.NP_RDMA))
        # warm the MTT with a completed transfer
        write(fab, npr_dom, nbytes=4 * 4096, dst_prep=BufferPrep.TOUCHED)
        node0 = fab.nodes[0]
        fresh = [e for _, e in node0.npr.mtt.entries() if not e.stale]
        assert fresh
        # fill the remaining banks and force a steal of pd 0's bank
        for pd in range(1, CAP + 1):
            fab.open_domain(pd)
        # evict pd 0 on node 0: make every other domain warmer, then
        # bind the bankless 17th
        for pd in range(1, CAP):
            node0.tenancy.banks.touch(pd)
        bank, _ = node0.bank_of_pd(CAP)
        assert node0.tenancy.banks.bank_of(0) is None
        assert all(e.stale for (pd, _), e in node0.npr.mtt.entries()
                   if pd == 0)
        # and the domain still completes transfers after re-binding
        wc = write(fab, npr_dom, nbytes=4 * 4096,
                   dst_prep=BufferPrep.TOUCHED)
        assert wc.latency_us > 0
        assert node0.npr.stats.stale_completions == 0
        assert check_bank_conservation(fab) == []
